"""Logical-axis -> mesh-axis sharding rules (t5x-style), per architecture.

Every parameter/activation carries a tuple of *logical* axis names (assigned by the
model code via :mod:`repro.models.param`).  A rule table maps logical names to mesh
axes; unlisted names are replicated.  This keeps DP/TP/EP/FSDP/SP decisions in ONE
place per arch and makes §Perf sharding hillclimbs a one-line change.

Mesh axes (production): ``("pod", "data", "model")`` multi-pod or ``("data",
"model")`` single pod.  Smoke tests use a 1-device mesh with the same axis names so
the same code paths run everywhere.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import constraint_sharding, get_abstract_mesh

PyTree = Any
MeshAxes = Union[None, str, Tuple[str, ...]]

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Baseline rules: tensor-parallel over "model", batch over ("pod","data").
# fsdp=True additionally shards the big weight matrices' embed/ff axes over "data"
# (ZeRO-3 style: XLA all-gathers them per layer under scan).
def make_rules(
    *,
    fsdp: bool = False,
    seq_shard: bool = False,
    extra: Optional[Dict[str, MeshAxes]] = None,
) -> Dict[str, MeshAxes]:
    rules: Dict[str, MeshAxes] = {
        # -- weights --
        "layers": None,            # stacked-layer leading dim: never sharded
        "embed": "data" if fsdp else None,   # d_model rows of big matrices
        "vocab": "model",          # embedding/logit vocab dim
        "heads": "model",          # query heads
        "kv_heads": "model",       # kv heads (GSPMD pads if < |model|)
        "head_dim": None,
        "mlp": "model",            # ffn hidden
        "experts": "model",        # MoE expert dim (EP)
        "expert_mlp": None,        # per-expert ffn hidden
        "lru": "model",            # RG-LRU / RWKV channel blocks
        "conv": None,
        "pos": None,
        "norm": None,
        # -- activations --
        "batch": ("pod", "data"),
        "seq": "data" if seq_shard else None,  # SP for long-context decode
        "act_embed": None,
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "act_experts": "model",
        "kv_seq": "data" if seq_shard else None,  # KV-cache seq dim (SP)
    }
    if extra:
        rules.update(extra)
    return rules


def spec_for(axes: Tuple[Optional[str], ...], rules: Dict[str, MeshAxes]) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    parts = []
    used: set = set()

    def _usable(m: MeshAxes):
        if m is None:
            return None
        if isinstance(m, str):
            return None if m in used else m
        got = tuple(a for a in m if a not in used)
        return got if got else None

    for name in axes:
        mesh_axes = rules.get(name) if name is not None else None
        mesh_axes = _usable(mesh_axes)
        if mesh_axes is None:
            parts.append(None)
        else:
            if isinstance(mesh_axes, str):
                used.add(mesh_axes)
            else:
                used.update(mesh_axes)
            parts.append(mesh_axes)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def specs_for_tree(axes_tree: PyTree, rules: Dict[str, MeshAxes]) -> PyTree:
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree_util.tree_map(
        lambda axes: spec_for(axes, rules),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, str) or e is None for e in x),
    )


def shardings_for_tree(
    axes_tree: PyTree, rules: Dict[str, MeshAxes], mesh: Mesh
) -> PyTree:
    specs = specs_for_tree(axes_tree, rules)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _divisible_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes whose product does not divide the dim size.

    jit ARGUMENT shardings must divide exactly (GSPMD pads only intermediate
    constraints), so e.g. a 56-head weight on a 16-way model axis falls back
    to replicated on that dim — its memory footprint is then carried by the
    other (FSDP/vocab/mlp) dims, and the *compute* still shards through the
    uneven activation constraints in the model code.
    """
    parts = []
    for i, part in enumerate(spec):
        if part is None or i >= len(shape):
            parts.append(None)
            continue
        axes = (part,) if isinstance(part, str) else tuple(part)
        keep = []
        prod = 1
        for a in axes:
            if a not in mesh.shape:
                continue  # axis absent in this (smaller) mesh
            n = mesh.shape[a]
            if shape[i] % (prod * n) == 0:
                keep.append(a)
                prod *= n
        parts.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def arg_shardings_for_tree(
    axes_tree: PyTree, shapes_tree: PyTree, rules: Dict[str, MeshAxes], mesh: Mesh
) -> PyTree:
    """NamedShardings for jit arguments: size-aware (divisibility-safe).

    ``shapes_tree`` carries the leaf shapes (arrays or ShapeDtypeStructs in
    the same structure as ``axes_tree``).
    """
    specs = specs_for_tree(axes_tree, rules)
    is_spec = lambda x: isinstance(x, P)
    shapes = jax.tree_util.tree_leaves(shapes_tree)
    flat_specs = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    assert len(shapes) == len(flat_specs), (len(shapes), len(flat_specs))
    fixed = [
        NamedSharding(mesh, _divisible_spec(s, tuple(l.shape), mesh))
        for s, l in zip(flat_specs, shapes)
    ]
    treedef = jax.tree_util.tree_structure(specs, is_leaf=is_spec)
    return jax.tree_util.tree_unflatten(treedef, fixed)


# ---------------------------------------------------------------------------
# Activation constraint helper
# ---------------------------------------------------------------------------

_CURRENT_RULES: Dict[str, MeshAxes] = make_rules()
_CONSTRAIN = True


def set_rules(rules: Dict[str, MeshAxes], constrain: bool = True) -> None:
    global _CURRENT_RULES, _CONSTRAIN
    _CURRENT_RULES = rules
    _CONSTRAIN = constrain


def get_rules() -> Dict[str, MeshAxes]:
    return _CURRENT_RULES


def with_logical_constraint(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` by logical axis names; no-op outside a mesh."""
    if not _CONSTRAIN:
        return x
    mesh = get_abstract_mesh()
    if mesh is None:
        return x
    axis_names = set(mesh.axis_names)
    spec = spec_for(tuple(axes), _CURRENT_RULES)
    # Drop references to mesh axes that don't exist in the current (small) mesh.
    clean = []
    for part in spec:
        if part is None:
            clean.append(None)
        elif isinstance(part, str):
            clean.append(part if part in axis_names else None)
        else:
            kept = tuple(a for a in part if a in axis_names)
            clean.append(kept if kept else None)
    try:
        return jax.lax.with_sharding_constraint(
            x, constraint_sharding(mesh, P(*clean))
        )
    except Exception:
        return x
