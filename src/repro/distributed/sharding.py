"""Logical-axis -> mesh-axis sharding rules (t5x-style), per architecture.

Every parameter/activation carries a tuple of *logical* axis names (assigned by the
model code via :mod:`repro.models.param`).  A rule table maps logical names to mesh
axes; unlisted names are replicated.  This keeps DP/TP/EP/FSDP/SP decisions in ONE
place per arch and makes §Perf sharding hillclimbs a one-line change.

Mesh axes (production): ``("pod", "data", "model")`` multi-pod or ``("data",
"model")`` single pod.  Smoke tests use a 1-device mesh with the same axis names so
the same code paths run everywhere.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import constraint_sharding, get_abstract_mesh

PyTree = Any
MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """The resolved placement contract between data, params, and the step.

    ``Session.shard()`` resolves the logical-axis rule table below against
    the live mesh ONCE (see :func:`repro.train.steps.build_sharding_plan`),
    yielding ``NamedSharding`` trees for every jit argument.  Everything
    downstream consumes this artifact instead of re-deriving layouts:

      * ``Session.compile()`` passes ``params``/``opt``/``batch`` as
        explicit ``in_shardings`` (and ``params``/``opt``/``replicated`` as
        ``out_shardings``) — the step is sharding-explicit, not
        GSPMD-implicit.
      * model init is jitted with ``out_shardings=plan.params`` so parameters
        materialize directly as mesh shards (never host-replicated).
      * the meshfeed storage backend lands batch rows with ``plan.batch``
        instead of rebuilding its own layout.
      * checkpoint restore places leaves straight onto ``params``/``opt``
        for ANY mesh shape (elastic save-at-dp=8 / restore-at-dp=4).

    The plan is keyed by ``global_rows``: an elastic event that changes the
    row count resizes the mesh, which invalidates (and re-derives) the plan.
    """

    mesh: Any                 # the live jax.sharding.Mesh
    rules: Dict[str, Any]     # logical axis -> mesh axes, as resolved
    params: PyTree            # NamedSharding tree matching the param pytree
    opt: Any                  # OptState of NamedShardings (step replicated)
    batch: Dict[str, Any]     # NamedSharding per batch key (tokens/labels/..)
    replicated: Any           # NamedSharding(mesh, P()) — metrics/out prefix
    global_rows: int
    data_axis: int            # |mesh["data"]| — how many ways rows shard

    @property
    def n_devices(self) -> int:
        return int(self.mesh.devices.size)

    def signature(self) -> Tuple[int, int, int]:
        return (self.global_rows, self.data_axis, self.n_devices)

    def describe(self) -> str:
        return (
            f"ShardingPlan(mesh={dict(self.mesh.shape)}, "
            f"rows={self.global_rows}, data_axis={self.data_axis})"
        )

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Baseline rules: tensor-parallel over "model", batch over ("pod","data").
# fsdp=True additionally shards the big weight matrices' embed/ff axes over "data"
# (ZeRO-3 style: XLA all-gathers them per layer under scan).
def make_rules(
    *,
    fsdp: bool = False,
    seq_shard: bool = False,
    extra: Optional[Dict[str, MeshAxes]] = None,
) -> Dict[str, MeshAxes]:
    rules: Dict[str, MeshAxes] = {
        # -- weights --
        "layers": None,            # stacked-layer leading dim: never sharded
        "embed": "data" if fsdp else None,   # d_model rows of big matrices
        "vocab": "model",          # embedding/logit vocab dim
        "heads": "model",          # query heads
        "kv_heads": "model",       # kv heads (GSPMD pads if < |model|)
        "head_dim": None,
        "mlp": "model",            # ffn hidden
        "experts": "model",        # MoE expert dim (EP)
        "expert_mlp": None,        # per-expert ffn hidden
        "lru": "model",            # RG-LRU / RWKV channel blocks
        "conv": None,
        "pos": None,
        "norm": None,
        # -- activations --
        "batch": ("pod", "data"),
        "seq": "data" if seq_shard else None,  # SP for long-context decode
        "act_embed": None,
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "act_experts": "model",
        "kv_seq": "data" if seq_shard else None,  # KV-cache seq dim (SP)
    }
    if extra:
        rules.update(extra)
    return rules


def spec_for(axes: Tuple[Optional[str], ...], rules: Dict[str, MeshAxes]) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    parts = []
    used: set = set()

    def _usable(m: MeshAxes):
        if m is None:
            return None
        if isinstance(m, str):
            return None if m in used else m
        got = tuple(a for a in m if a not in used)
        return got if got else None

    for name in axes:
        mesh_axes = rules.get(name) if name is not None else None
        mesh_axes = _usable(mesh_axes)
        if mesh_axes is None:
            parts.append(None)
        else:
            if isinstance(mesh_axes, str):
                used.add(mesh_axes)
            else:
                used.update(mesh_axes)
            parts.append(mesh_axes)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def specs_for_tree(axes_tree: PyTree, rules: Dict[str, MeshAxes]) -> PyTree:
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree_util.tree_map(
        lambda axes: spec_for(axes, rules),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, str) or e is None for e in x),
    )


def shardings_for_tree(
    axes_tree: PyTree, rules: Dict[str, MeshAxes], mesh: Mesh
) -> PyTree:
    specs = specs_for_tree(axes_tree, rules)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _divisible_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes whose product does not divide the dim size.

    jit ARGUMENT shardings must divide exactly (GSPMD pads only intermediate
    constraints), so e.g. a 56-head weight on a 16-way model axis falls back
    to replicated on that dim — its memory footprint is then carried by the
    other (FSDP/vocab/mlp) dims, and the *compute* still shards through the
    uneven activation constraints in the model code.
    """
    parts = []
    for i, part in enumerate(spec):
        if part is None or i >= len(shape):
            parts.append(None)
            continue
        axes = (part,) if isinstance(part, str) else tuple(part)
        keep = []
        prod = 1
        for a in axes:
            if a not in mesh.shape:
                continue  # axis absent in this (smaller) mesh
            n = mesh.shape[a]
            if shape[i] % (prod * n) == 0:
                keep.append(a)
                prod *= n
        parts.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def arg_shardings_for_tree(
    axes_tree: PyTree, shapes_tree: PyTree, rules: Dict[str, MeshAxes], mesh: Mesh
) -> PyTree:
    """NamedShardings for jit arguments: size-aware (divisibility-safe).

    ``shapes_tree`` carries the leaf shapes (arrays or ShapeDtypeStructs in
    the same structure as ``axes_tree``).
    """
    specs = specs_for_tree(axes_tree, rules)
    is_spec = lambda x: isinstance(x, P)
    shapes = jax.tree_util.tree_leaves(shapes_tree)
    flat_specs = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    assert len(shapes) == len(flat_specs), (len(shapes), len(flat_specs))
    fixed = [
        NamedSharding(mesh, _divisible_spec(s, tuple(l.shape), mesh))
        for s, l in zip(flat_specs, shapes)
    ]
    treedef = jax.tree_util.tree_structure(specs, is_leaf=is_spec)
    return jax.tree_util.tree_unflatten(treedef, fixed)


# ---------------------------------------------------------------------------
# Activation constraint helper
# ---------------------------------------------------------------------------

_CURRENT_RULES: Dict[str, MeshAxes] = make_rules()
_CONSTRAIN = True


def set_rules(rules: Dict[str, MeshAxes], constrain: bool = True) -> None:
    global _CURRENT_RULES, _CONSTRAIN
    _CURRENT_RULES = rules
    _CONSTRAIN = constrain


def get_rules() -> Dict[str, MeshAxes]:
    return _CURRENT_RULES


@contextlib.contextmanager
def use_rules(rules: Dict[str, MeshAxes], constrain: bool = True):
    """Temporarily install a rule table (and restore the previous one).

    ``Session.compile()`` traces the step under the ShardingPlan's rules so
    the in-model activation constraints (:func:`with_logical_constraint`)
    resolve against the SAME table that produced the argument shardings —
    including any ``FleetSpec.with_sharding`` overrides.
    """
    global _CURRENT_RULES, _CONSTRAIN
    prev_rules, prev_constrain = _CURRENT_RULES, _CONSTRAIN
    _CURRENT_RULES, _CONSTRAIN = rules, constrain
    try:
        yield
    finally:
        _CURRENT_RULES, _CONSTRAIN = prev_rules, prev_constrain


def with_logical_constraint(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` by logical axis names; no-op outside a mesh."""
    if not _CONSTRAIN:
        return x
    mesh = get_abstract_mesh()
    if mesh is None:
        return x
    axis_names = set(mesh.axis_names)
    spec = spec_for(tuple(axes), _CURRENT_RULES)
    # Drop references to mesh axes that don't exist in the current (small) mesh.
    clean = []
    for part in spec:
        if part is None:
            clean.append(None)
        elif isinstance(part, str):
            clean.append(part if part in axis_names else None)
        else:
            kept = tuple(a for a in part if a in axis_names)
            clean.append(kept if kept else None)
    try:
        return jax.lax.with_sharding_constraint(
            x, constraint_sharding(mesh, P(*clean))
        )
    except (ValueError, TypeError) as e:
        # Only the expected constraint failures (rank/axis mismatches) are
        # tolerable — and even those get ONE warning per (spec, mesh) so a
        # rule-table typo can't silently replicate a tensor forever.
        _warn_constraint_skipped(tuple(axes), clean, mesh, e)
        return x


_WARNED_CONSTRAINTS: set = set()


def reset_constraint_warnings() -> None:
    """Clear the warn-once cache of :func:`with_logical_constraint`.

    The cache is process-global by design (a production run warns once per
    (spec, mesh), ever), which makes the WARNING itself order-dependent in
    a test suite: whichever test first triggers a given key eats the
    warning for everyone after it.  Tests that assert the warning call this
    first so the assertion holds under any test ordering.
    """
    _WARNED_CONSTRAINTS.clear()


def _warn_constraint_skipped(axes, clean, mesh, err) -> None:
    key = (
        tuple(axes),
        tuple(tuple(p) if isinstance(p, tuple) else p for p in clean),
        tuple(mesh.axis_names),
        tuple(int(mesh.shape[a]) for a in mesh.axis_names),
    )
    if key in _WARNED_CONSTRAINTS:
        return
    _WARNED_CONSTRAINTS.add(key)
    warnings.warn(
        f"sharding constraint for logical axes {tuple(axes)} "
        f"(spec {P(*clean)}) skipped on mesh "
        f"{dict(mesh.shape)}: {type(err).__name__}: {err}",
        RuntimeWarning,
        stacklevel=3,
    )
