"""Explicit gradient allreduce schedules over shard_map + lax collectives.

The paper's synchronization layer is Horovod's NCCL ring allreduce.  On TPU,
XLA/GSPMD already emits near-optimal ICI collectives for a plain ``psum`` —
that is our BASELINE.  This module provides the Horovod-faithful explicit
ring (reduce-scatter ring + all-gather ring via ``lax.ppermute``) plus the
beyond-paper variants the perf loop iterates on:

  * ``ring_allreduce``          — bandwidth-optimal 2(n-1)/n ring, bit-compatible
                                  with psum (validated in tests).
  * ``hierarchical_allreduce``  — intra-pod reduce-scatter -> inter-pod
                                  allreduce on shards -> intra-pod all-gather;
                                  crosses the (slow) pod link only once with
                                  1/n_pod-sized shards.
  * ``compressed_allreduce``    — int8-quantized ring with error feedback
                                  (residual carried by the caller), 4x less
                                  ICI traffic for bandwidth-bound layers.

All functions are written per-shard (inside shard_map); `axis` names refer to
mesh axes.  They operate on a single flat vector — the caller flattens the
grad pytree (bucketing is in :func:`bucketize`).
"""
from __future__ import annotations

import functools
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ops as kops

PyTree = jax.Array  # flat vectors in this module


# ---------------------------------------------------------------------------
# Ring allreduce (Horovod-faithful)
# ---------------------------------------------------------------------------


def ring_allreduce(x: jax.Array, axis: str) -> jax.Array:
    """Reduce-scatter ring + all-gather ring along ``axis``.

    Inside shard_map: every device holds an identical-shape ``x``; the result
    is the elementwise sum across the axis (== lax.psum(x, axis)), moved in
    2(n-1) ring hops of 1/n-size chunks — each device sends/receives
    2(n-1)/n of the payload, the bandwidth-optimal schedule the paper's
    Horovod uses.
    """
    n = axis_size(axis)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis)
    size = x.shape[0]
    pad = (-size) % n
    if pad:
        x = jnp.pad(x, (0, pad))
    chunks = x.reshape(n, -1)                       # chunk c lives at row c
    fwd = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: after n-1 hops, device d owns the full sum of chunk
    # (d+1) mod n.  Each hop sends the chunk we just accumulated.
    def rs_body(k, chunks):
        # at hop k, device d sends chunk (d - k) mod n, receives (d - k - 1)
        send_ix = (idx - k) % n
        recv_ix = (idx - k - 1) % n
        sent = jax.lax.ppermute(chunks[send_ix], axis, fwd)
        return chunks.at[recv_ix].add(sent)

    chunks = jax.lax.fori_loop(0, n - 1, rs_body, chunks)

    # all-gather ring: device d owns the reduced chunk (d+1) mod n; circulate
    def ag_body(k, chunks):
        send_ix = (idx + 1 - k) % n
        recv_ix = (idx - k) % n
        sent = jax.lax.ppermute(chunks[send_ix], axis, fwd)
        return chunks.at[recv_ix].set(sent)

    chunks = jax.lax.fori_loop(0, n - 1, ag_body, chunks)
    out = chunks.reshape(-1)
    return out[:size] if pad else out


# ---------------------------------------------------------------------------
# Hierarchical (multi-pod) allreduce
# ---------------------------------------------------------------------------


def hierarchical_allreduce(
    x: jax.Array, *, intra_axis: str, inter_axis: str
) -> jax.Array:
    """reduce_scatter(intra) -> psum(inter) on 1/n shards -> all_gather(intra).

    The inter-pod link (DCN / optical, ~10x slower than ICI) carries only
    ``bytes / n_intra`` per device instead of full ``bytes`` — the standard
    fleet-scale schedule, here explicit so the roofline's collective term can
    attribute bytes to the right fabric.
    """
    n_intra = axis_size(intra_axis)
    size = x.shape[0]
    pad = (-size) % n_intra
    if pad:
        x = jnp.pad(x, (0, pad))
    shard = jax.lax.psum_scatter(
        x.reshape(n_intra, -1), intra_axis, scatter_dimension=0, tiled=False
    )                                               # (chunk,) partial sums
    shard = jax.lax.psum(shard, inter_axis)         # cross-pod on 1/n bytes
    out = jax.lax.all_gather(shard, intra_axis, axis=0, tiled=False).reshape(-1)
    return out[:size] if pad else out


# ---------------------------------------------------------------------------
# Compressed ring (int8 + error feedback)
# ---------------------------------------------------------------------------


def compressed_allreduce(
    x: jax.Array,
    residual: jax.Array,
    noise: jax.Array,
    *,
    axis: str,
    rows: int = 256,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Quantized allreduce with error feedback.

    q = int8(x + residual); allreduce the int8 payload (here: psum over the
    dequantized values — on hardware the int8 tensor rides the wire and is
    summed in int32); new_residual = (x + residual) - dequant(q).
    Returns (summed dequantized gradient, new residual).
    """
    y = x + residual
    size = y.shape[0]
    pad = (-size) % rows
    if pad:
        y2 = jnp.pad(y, (0, pad))
        noise = jnp.pad(noise, (0, pad))
    else:
        y2 = y
    mat = y2.reshape(rows, -1)
    q, scale = kops.quantize_int8(
        mat, noise.reshape(rows, -1), interpret=interpret
    )
    deq = kops.dequantize_int8(q, scale).reshape(-1)[:size]
    new_residual = y - deq
    total = jax.lax.psum(deq, axis)
    return total, new_residual


# ---------------------------------------------------------------------------
# Bucketing (Horovod-style fusion buffers)
# ---------------------------------------------------------------------------


def flatten_grads(grads) -> Tuple[jax.Array, Callable]:
    """Concatenate a grad pytree into one f32 vector + unflattener."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    shapes = [l.shape for l in leaves]
    sizes = [int(l.size) for l in leaves]
    dtypes = [l.dtype for l in leaves]
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])

    def unflatten(vec: jax.Array):
        out, off = [], 0
        for shape, size, dt in zip(shapes, sizes, dtypes):
            out.append(vec[off : off + size].reshape(shape).astype(dt))
            off += size
        return jax.tree_util.tree_unflatten(treedef, out)

    return flat, unflatten


def bucketize(flat: jax.Array, bucket_bytes: int = 64 * 1024 * 1024) -> List[jax.Array]:
    """Split a flat f32 vector into Horovod-style fusion buckets."""
    per = max(1, bucket_bytes // 4)
    return [flat[i : i + per] for i in range(0, flat.shape[0], per)]


# ---------------------------------------------------------------------------
# shard_map drivers (what the trainer/pjit integrates)
# ---------------------------------------------------------------------------


def make_ring_psum(mesh: Mesh, axis: str = "data") -> Callable:
    """Returns f(grads_pytree) -> summed pytree using the explicit ring.

    Applied inside shard_map over ``axis``; every other mesh axis must be
    replicated for the grads (DP gradients are replicated over model).
    """
    from jax.experimental.shard_map import shard_map

    def allreduce(grads):
        flat, unflatten = flatten_grads(grads)

        ring = shard_map(
            lambda v: ring_allreduce(v, axis),
            mesh=mesh,
            in_specs=P(),     # replicated input (per-device local grads differ
            out_specs=P(),    #  only mathematically — shapes are identical)
            check_rep=False,
        )
        return unflatten(ring(flat))

    return allreduce
