"""GPipe-style pipeline parallelism over shard_map + ppermute (optional PP).

The 40 dry-run cells use DP x TP (x EP/FSDP/SP) which fit the 16 GB budget;
PP is provided as a first-class feature for deeper-than-memory models and is
tested on small configs.  Schedule: GPipe fill-drain with M microbatches over
S stages; bubble fraction (S-1)/(M+S-1).

Implementation: one SPMD program over a ``stage`` mesh axis.  Every device
holds its stage's parameter shard (stacked leading ``stage`` dim, sharded).
The time loop runs M + S - 1 ticks; each tick every stage
  1. computes on its current microbatch (garbage during fill/drain — masked),
  2. ppermutes its activation to the next stage.
Stage 0 injects microbatch t at tick t; stage S-1 emits microbatch t at tick
t + S - 1.  All control flow is lax.scan — one compiled program, no Python
per-tick dispatch.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def pipeline_apply(
    stage_fn: Callable[[PyTree, jax.Array], jax.Array],
    stage_params: PyTree,        # leaves stacked (S, ...) — sharded over "stage"
    micro_in: jax.Array,         # (M, mb, ...) microbatched input activations
    *,
    mesh: Mesh,
    axis: str = "stage",
) -> jax.Array:
    """Run the GPipe schedule; returns (M, mb, ...) final-stage outputs."""
    from jax.experimental.shard_map import shard_map

    S = mesh.shape[axis]
    M = micro_in.shape[0]

    def per_stage(params, xs):
        # params: (1, ...) local slice; xs: (M, mb, ...) only stage 0 uses it
        params = jax.tree_util.tree_map(lambda a: a[0], params)
        sid = jax.lax.axis_index(axis)
        n_ticks = M + S - 1
        buf = jnp.zeros_like(xs[0])                 # in-flight activation
        outs = jnp.zeros_like(xs)                   # collected at last stage

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t while t < M
            inj = xs[jnp.minimum(t, M - 1)]
            x_in = jnp.where(sid == 0, inj, buf)
            y = stage_fn(params, x_in)
            # last stage collects microbatch t - (S - 1)
            out_ix = t - (S - 1)
            valid = (sid == S - 1) & (out_ix >= 0)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_ix, 0), 0
                ),
                lambda o: o,
                outs,
            )
            # shift activations one stage forward
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % S) for i in range(S)]
            )
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # ``outs`` is zeros everywhere except the last stage -> psum broadcasts
        return jax.lax.psum(outs, axis) if S > 1 else outs

    fn = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, micro_in)


def split_microbatches(x: jax.Array, n_micro: int) -> jax.Array:
    """(B, ...) -> (M, B/M, ...)"""
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    return x.reshape(n_micro, B // n_micro, *x.shape[1:])


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
