"""Worker classes and the fleet performance/energy model.

STANNIS's hardware is a heterogeneous fleet: one Xeon host + N Newport CSDs
(ARM A53 ISP engines).  We generalize that to *worker classes*: each class has a
count, a relative compute throughput, a link bandwidth to the reduction fabric,
and a power envelope.  The paper's Table I/II numbers are reproduced by
instantiating the ``paper_fleet()`` profile; TPU-fleet profiles model mixed-pod
deployments (the technique's target at our scale).

Everything here is *accounting* — pure Python over dataclasses — so the tuner,
load balancer, energy benchmark, and trainer can share one consistent model.

Units: throughput in samples/s at a reference batch size, power in watts,
bandwidth in GB/s.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class WorkerClass:
    """One homogeneous group of workers (the paper has two: host, newport)."""

    name: str
    count: int
    # Peak useful training throughput for the reference net, samples/sec, at
    # saturating batch size.  The tuner *measures* this when real step
    # functions are provided; the analytic value seeds fleet-scale planning.
    peak_throughput: float
    # Batch size beyond which throughput saturates (paper: Newport ~16).
    saturation_batch: int
    # Max batch that fits DRAM (paper: Newport 8 GB shared -> small nets only).
    max_batch: int
    # Active power draw, watts (paper measures whole-rack; we model per-class).
    active_power: float
    idle_power: float = 0.0
    # Bandwidth of this worker's link into the allreduce ring, GB/s.
    link_bandwidth: float = 1.0

    def throughput_at(self, batch: int) -> float:
        """Ramp to peak by ``saturation_batch``, flat beyond (paper §V)."""
        if batch <= 0:
            return 0.0
        frac = min(1.0, batch / max(1, self.saturation_batch))
        # sub-linear ramp: small batches underutilize the engine
        return self.peak_throughput * frac ** 0.5 if frac < 1.0 else self.peak_throughput

    def step_time(self, batch: int) -> float:
        """Seconds to process one local batch."""
        tput = self.throughput_at(batch)
        return batch / tput if tput > 0 else math.inf


@dataclasses.dataclass(frozen=True)
class Fleet:
    """A heterogeneous fleet = ordered list of worker classes."""

    classes: Tuple[WorkerClass, ...]

    @property
    def n_workers(self) -> int:
        return sum(c.count for c in self.classes)

    def slowest(self) -> WorkerClass:
        return min(self.classes, key=lambda c: c.peak_throughput)

    def fastest(self) -> WorkerClass:
        return max(self.classes, key=lambda c: c.peak_throughput)

    def by_name(self, name: str) -> WorkerClass:
        for c in self.classes:
            if c.name == name:
                return c
        raise KeyError(name)

    def expand(self) -> List[WorkerClass]:
        """One entry per physical worker."""
        out: List[WorkerClass] = []
        for c in self.classes:
            out.extend([c] * c.count)
        return out

    # -- energy accounting (Table II methodology: wall power / throughput) ----
    def power(self, active: Optional[Dict[str, bool]] = None) -> float:
        total = 0.0
        for c in self.classes:
            on = True if active is None else active.get(c.name, True)
            total += c.count * (c.active_power if on else c.idle_power)
        return total

    def energy_per_sample(self, aggregate_throughput: float) -> float:
        """Joules per processed sample (paper Table II row 1)."""
        return self.power() / max(aggregate_throughput, 1e-9)


# ---------------------------------------------------------------------------
# Profiles
# ---------------------------------------------------------------------------


def paper_fleet(n_csds: int = 24, network: str = "mobilenetv2") -> Fleet:
    """The paper's AIC server: 1 Xeon Silver 4108 host + ``n_csds`` Newport CSDs.

    Throughputs from Table I (img/s): host 31.05 / CSD 3.08 for MobileNetV2 etc.
    Power: the paper reports whole-rack energy/image (Table II); we back out a
    per-class split consistent with those rows: with 0 CSDs the rack burns
    13.10 J/img * 31.05 img/s ~= 407 W; each Newport adds ~7 W active while
    contributing 3.08 img/s (energy/image *falls* to 4.02 J at 24 CSDs).
    """
    table1 = {
        #                 host img/s, csd img/s, csd saturation batch
        "mobilenetv2": (31.05, 3.08, 16),
        "nasnet": (47.31, 2.80, 12),
        "inceptionv3": (30.80, 1.85, 12),
        "squeezenet": (219.0, 16.3, 32),
    }
    h, c, sat = table1[network]
    host = WorkerClass(
        name="host", count=1, peak_throughput=h, saturation_batch=sat * 8,
        max_batch=4096, active_power=407.0, idle_power=100.0,
        link_bandwidth=8.0,
    )
    csd = WorkerClass(
        name="newport", count=n_csds, peak_throughput=c, saturation_batch=sat,
        max_batch=64, active_power=7.0, idle_power=1.5,
        link_bandwidth=2.0,  # TCP/IP-over-PCIe tunnel
    )
    return Fleet(classes=(host, csd))


def tpu_fleet(
    n_fast_pods: int = 1,
    n_slow_pods: int = 1,
    fast_tput: float = 1.0,
    slow_tput: float = 0.55,
    chips_per_pod: int = 256,
) -> Fleet:
    """A mixed-generation TPU fleet (e.g. v5e pods + older pods).

    Throughputs are *relative* (per-pod step rate for a fixed reference batch);
    the tuner works with relative numbers identically to absolute ones.
    v5e chip ~ 170 W + host share; links are ICI (~50 GB/s after efficiency).
    """
    fast = WorkerClass(
        name="pod-fast", count=n_fast_pods, peak_throughput=fast_tput,
        saturation_batch=8, max_batch=4096,
        active_power=200.0 * chips_per_pod, idle_power=60.0 * chips_per_pod,
        link_bandwidth=50.0,
    )
    slow = WorkerClass(
        name="pod-slow", count=n_slow_pods, peak_throughput=slow_tput,
        saturation_batch=8, max_batch=4096,
        active_power=160.0 * chips_per_pod, idle_power=50.0 * chips_per_pod,
        link_bandwidth=25.0,
    )
    return Fleet(classes=(fast, slow))


# ---------------------------------------------------------------------------
# Synchronization-cost model (paper §V-A: slowdown fades beyond 5-6 nodes)
# ---------------------------------------------------------------------------


def ring_allreduce_time(
    n_params: int,
    n_workers: int,
    min_link_gbs: float,
    bytes_per_param: int = 4,
) -> float:
    """Ring allreduce wall time: 2 (n-1)/n * bytes / slowest-link-bandwidth.

    Bandwidth-optimal (Horovod/NCCL): each worker sends and receives
    ``2 (n-1)/n * B`` bytes regardless of n, through its own link; the ring is
    paced by the *slowest* link — exactly why the paper's speedup converges
    after 5-6 nodes instead of degrading.
    """
    if n_workers <= 1:
        return 0.0
    vol = 2.0 * (n_workers - 1) / n_workers * n_params * bytes_per_param
    return vol / (min_link_gbs * 1e9)


def sync_stall(n_workers: int, stall_max: float = 0.12, tau: float = 2.5) -> float:
    """Per-node slowdown from synchronization partial stalls (paper §V-A).

    The paper observes every node slows down in distributed mode and the
    slowdown CONVERGES once the ring has more than 5-6 devices (each node
    only ever talks to two neighbours).  Saturating exponential fits that:
    0 at n=1, ~95% of stall_max by n~8.
    """
    if n_workers <= 1:
        return 0.0
    return stall_max * (1.0 - math.exp(-(n_workers - 1) / tau))


def distributed_step_time(
    fleet: Fleet,
    batches: Dict[str, int],
    n_params: int,
    bytes_per_param: int = 4,
    overlap: float = 0.0,
    stall_max: float = 0.12,
) -> float:
    """Synchronous-step wall time = max compute * (1 + stall) + (1-overlap) * allreduce.

    ``overlap``: fraction of the allreduce hidden under backprop (beyond-paper
    optimization; the paper's Horovod baseline has overlap ~ 0 for small nets).
    """
    active = [c for c in fleet.classes if batches.get(c.name, 0) > 0]
    if not active:
        return math.inf
    compute = max(c.step_time(batches[c.name]) for c in active)
    n_active = sum(c.count for c in active)
    min_link = min(c.link_bandwidth for c in active)
    comm = ring_allreduce_time(n_params, n_active, min_link, bytes_per_param)
    stall = sync_stall(n_active, stall_max=stall_max)
    return compute * (1.0 + stall) + (1.0 - overlap) * comm


def fleet_throughput(
    fleet: Fleet,
    batches: Dict[str, int],
    n_params: int,
    bytes_per_param: int = 4,
    overlap: float = 0.0,
    stall_max: float = 0.12,
) -> float:
    """Aggregate samples/s for one synchronous step (paper Fig. 6 y-axis)."""
    t = distributed_step_time(
        fleet, batches, n_params, bytes_per_param, overlap, stall_max
    )
    total = sum(c.count * batches.get(c.name, 0) for c in fleet.classes)
    return total / t if t > 0 and not math.isinf(t) else 0.0


# ---------------------------------------------------------------------------
# Cluster process topology
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ProcessMap:
    """dp-group -> worker-process assignment for multi-process execution.

    The Stannis global batch is group-major: group ``g`` owns rows
    ``[g*max_local, (g+1)*max_local)``.  A cluster of ``n_processes`` worker
    processes splits the groups into contiguous blocks (``g * P // G``), so a
    process's rows are one contiguous span of the global batch — exactly the
    slab its addressable mesh devices cover when the ``data`` axis is laid
    out process-major (jax's device order).  Each process provisions storage
    devices (shard custody) ONLY for its own groups; every other group is a
    remote record in the manifest.
    """

    group_workers: Tuple[str, ...]
    n_processes: int

    def __post_init__(self):
        if self.n_processes < 1:
            raise ValueError(f"n_processes must be >= 1, got {self.n_processes}")
        if self.n_processes > max(1, len(self.group_workers)):
            raise ValueError(
                f"{self.n_processes} processes but only "
                f"{len(self.group_workers)} dp-groups — a worker process with "
                f"no group custody has nothing to feed"
            )

    @property
    def n_groups(self) -> int:
        return len(self.group_workers)

    def process_of_group(self, g: int) -> int:
        if not 0 <= g < self.n_groups:
            raise IndexError(g)
        return g * self.n_processes // self.n_groups

    def process_of(self, worker: str) -> int:
        return self.process_of_group(self.group_workers.index(worker))

    def local_groups(self, process: int) -> range:
        g0 = math.ceil(process * self.n_groups / self.n_processes)
        g1 = math.ceil((process + 1) * self.n_groups / self.n_processes)
        return range(g0, g1)

    def local_workers(self, process: int) -> Tuple[str, ...]:
        return tuple(self.group_workers[g] for g in self.local_groups(process))

    def row_span(self, process: int, max_local: int) -> Tuple[int, int]:
        """This process's contiguous [start, stop) row window of the global
        batch (group-major layout, ``max_local`` rows per group)."""
        groups = self.local_groups(process)
        return groups.start * max_local, groups.stop * max_local


@dataclasses.dataclass(frozen=True)
class TransportSpec:
    """Gradient-reduction transport knobs for hostsync cluster execution.

    Three independently toggleable optimizations (all default off, so the
    default spec reproduces the classic full-f32 star reduction):

    * ``compression`` — ``"int8"`` per-chunk symmetric quantization
      (:mod:`repro.kernels.quantize`, deterministic round-half-up) or
      ``"topk"`` magnitude sparsification (``topk_ratio`` of entries kept).
      Both keep a per-host *error-feedback* residual so the dropped mass
      re-enters later steps; every worker decodes every peer's payload and
      sums in process-id order, so replicas stay bit-identical.
    * ``overlap`` — split the grad pytree into ``buckets`` flat f32 vectors
      and pipeline bucket *i*'s encode/reduce (background thread, double
      buffered) with bucket *i+1*'s compute.
    * ``topology`` — ``"ring"`` peer-to-peer allgather (workers listen on
      their own sockets; the coordinator is demoted to rendezvous +
      membership) or the ``"star"`` coordinator fallback.

    ``timeout`` bounds every blocking wire wait; a silent peer raises
    ``SyncPeerLost`` instead of hanging the step.
    """

    compression: str = "none"       # "none" | "int8" | "topk"
    topk_ratio: float = 0.01        # fraction of entries kept when "topk"
    chunk: int = 512                # int8 quantization chunk (one scale each)
    buckets: int = 1                # grad pytree split into this many vectors
    overlap: bool = False           # pipeline reduce(i) with compute(i+1)
    topology: str = "star"          # "star" | "ring"
    timeout: float = 120.0          # seconds before a wire wait raises

    def __post_init__(self):
        if self.compression not in ("none", "int8", "topk"):
            raise ValueError(f"unknown compression {self.compression!r}")
        if self.topology not in ("star", "ring"):
            raise ValueError(f"unknown topology {self.topology!r}")
        if self.buckets < 1:
            raise ValueError(f"buckets must be >= 1, got {self.buckets}")
        if not 0.0 < self.topk_ratio <= 1.0:
            raise ValueError(f"topk_ratio must be in (0, 1], got {self.topk_ratio}")
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        if self.timeout <= 0:
            raise ValueError(f"timeout must be > 0, got {self.timeout}")

    @classmethod
    def production(cls, **overrides) -> "TransportSpec":
        """The all-optimizations-on preset used by benches and smoke rigs."""
        kw = dict(compression="int8", buckets=2, overlap=True, topology="ring")
        kw.update(overrides)
        return cls(**kw)

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Declarative multi-process execution: how many worker processes, and
    how they find each other.  Carried by ``FleetSpec.with_cluster`` so one
    line turns a single-process session into a cluster launch.

    ``local_devices`` is the per-process accelerator count (0 = whatever
    the process already sees; smoke rigs force N fake CPU devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).  Ports of 0
    auto-pick free ones at launch.  ``membership_dir`` is where worker
    heartbeats land for the :class:`~repro.api.membership.MembershipWatcher`
    (a fresh tempdir when omitted).  ``transport`` selects the gradient
    reduction path (see :class:`TransportSpec`).  ``compile_cache_dir``
    points every worker at a shared persistent XLA compilation cache
    (``None`` = a stable per-user tempdir; repeated launches of the same
    shapes skip recompiles).
    """

    processes: int = 1
    local_devices: int = 0
    coordinator_port: int = 0
    sync_port: int = 0
    membership_dir: Optional[str] = None
    heartbeat_interval: float = 0.25
    transport: TransportSpec = dataclasses.field(default_factory=TransportSpec)
    compile_cache_dir: Optional[str] = None

    def __post_init__(self):
        if self.processes < 1:
            raise ValueError(
                f"cluster needs >= 1 process, got {self.processes}"
            )
        if isinstance(self.transport, dict):
            object.__setattr__(self, "transport", TransportSpec(**self.transport))
