"""Stannis core: the paper's contributions as composable modules.

C1 tuner.py         Algorithm 1 batch-size equalization
C2 load_balance.py  Eq. 1 epoch alignment + private-shard remedies
C3 privacy.py       placement manifests; private data never moves
C4 hetero.py        masked uniform batches + globally-weighted gradients
    topology.py     worker classes, fleet perf/energy model (Newport/host)
"""
from repro.core.hetero import BatchSchedule, masked_mean_loss, schedule_from_tune
from repro.core.load_balance import EpochPlan, eq1_dataset_size, plan_epoch
from repro.core.privacy import PlacementManifest, Shard, place
from repro.core.topology import Fleet, WorkerClass, paper_fleet, tpu_fleet
from repro.core.tuner import DriftMonitor, TuneResult, tune

__all__ = [
    "BatchSchedule", "masked_mean_loss", "schedule_from_tune",
    "EpochPlan", "eq1_dataset_size", "plan_epoch",
    "PlacementManifest", "Shard", "place",
    "Fleet", "WorkerClass", "paper_fleet", "tpu_fleet",
    "DriftMonitor", "TuneResult", "tune",
]
