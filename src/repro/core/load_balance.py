"""Epoch load balancing (paper Eq. 1) + the unequal-private-shard remedies.

Eq. 1:  steps_per_epoch = dataset / batchsize
        => dataset_host = dataset_card / batchsize_card * batchsize_host

i.e. after the tuner fixes per-class batch sizes, each worker's dataset share
is proportional to its batch size, so every worker finishes an epoch after the
SAME number of steps — no end-of-epoch stall of fast workers (paper §IV).

When private shards are unequal, the paper gives two remedies:
  * ``backfill``  — top up small-private workers with public data;
  * ``duplicate`` — replicate private data to reach the target share.
Both are implemented; the planner picks backfill while public data lasts, then
falls back to duplication (maximizing samples/sec as the paper prescribes).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class WorkerShare:
    worker: str                 # physical worker id, e.g. "newport/3"
    batch: int                  # tuned per-step batch size
    n_private: int              # private samples owned by (and pinned to) it
    n_public: int               # public samples assigned to it
    n_duplicated: int = 0       # private samples replayed to fill the share

    @property
    def total(self) -> int:
        return self.n_private + self.n_public + self.n_duplicated

    @property
    def steps(self) -> int:
        return self.total // max(1, self.batch)


@dataclasses.dataclass(frozen=True)
class EpochPlan:
    shares: Tuple[WorkerShare, ...]
    steps_per_epoch: int
    public_left: int             # public samples not assigned anywhere

    def share_for(self, worker: str) -> WorkerShare:
        for s in self.shares:
            if s.worker == worker:
                return s
        raise KeyError(worker)

    def imbalance_steps(self) -> int:
        """Max spread in steps across workers (0 = everyone stops together)."""
        st = [s.steps for s in self.shares]
        return max(st) - min(st) if st else 0


def eq1_dataset_size(dataset_card: int, batch_card: int, batch_host: int) -> int:
    """Literal paper Eq. 1 (kept for tests / the Table-I benchmark)."""
    return int(dataset_card / batch_card * batch_host)


def plan_epoch(
    batches: Dict[str, int],           # worker id -> tuned batch size
    private_sizes: Dict[str, int],     # worker id -> private samples it owns
    n_public: int,                     # shared/public pool size
    *,
    allow_duplication: bool = True,
) -> EpochPlan:
    """Assign data so all workers finish an epoch in the same number of steps.

    steps* is chosen as the largest step count such that every worker's share
    can be met from (its private data) + (its slice of the public pool),
    maximizing utilization; workers short on private data are backfilled from
    the public pool and, if that runs dry and duplication is allowed, replay
    their own private data (never anyone else's — privacy constraint).
    """
    workers = sorted(batches)
    total_batch = sum(batches[w] for w in workers)
    total_private = sum(private_sizes.get(w, 0) for w in workers)
    if total_batch <= 0:
        return EpochPlan(shares=(), steps_per_epoch=0, public_left=n_public)

    # upper bound: all data used, perfectly proportional
    steps_hi = (total_private + n_public) // total_batch

    def feasible(steps: int) -> Optional[List[WorkerShare]]:
        """Try to realize ``steps`` for every worker; None if impossible."""
        need_pub: Dict[str, int] = {}
        for w in workers:
            want = steps * batches[w]
            have = min(private_sizes.get(w, 0), want)
            need_pub[w] = want - have
        if sum(need_pub.values()) <= n_public:
            pub = dict(need_pub)
            dup = {w: 0 for w in workers}
        elif allow_duplication:
            # backfill public proportionally to need, duplicate the rest
            pub, dup = {}, {}
            remaining = n_public
            total_need = sum(need_pub.values())
            for w in workers:
                p = min(need_pub[w], int(n_public * need_pub[w] / max(1, total_need)))
                pub[w] = p
                remaining -= p
            # hand out the integer remainder greedily
            for w in sorted(workers, key=lambda w: -(need_pub[w] - pub[w])):
                take = min(remaining, need_pub[w] - pub[w])
                pub[w] += take
                remaining -= take
                if remaining <= 0:
                    break
            for w in workers:
                short = need_pub[w] - pub[w]
                if short > 0 and private_sizes.get(w, 0) == 0:
                    return None  # nothing to duplicate from
                dup[w] = short
        else:
            return None
        out = []
        for w in workers:
            want = steps * batches[w]
            have_priv = min(private_sizes.get(w, 0), want)
            out.append(
                WorkerShare(
                    worker=w, batch=batches[w], n_private=have_priv,
                    n_public=pub[w], n_duplicated=dup[w],
                )
            )
        return out

    # binary search the largest feasible step count
    lo, hi, best = 0, steps_hi, None
    while lo <= hi:
        mid = (lo + hi) // 2
        got = feasible(mid)
        if got is not None:
            best, lo = got, mid + 1
        else:
            hi = mid - 1
    shares = best or []
    used_pub = sum(s.n_public for s in shares)
    steps = shares[0].steps if shares else 0
    return EpochPlan(
        shares=tuple(shares), steps_per_epoch=steps, public_left=n_public - used_pub
    )
