"""Stannis tuning algorithm (paper Algorithm 1), faithful control flow.

The paper's pseudo-code:

    Function Tune(IP_newport, IP_host, C):
        for batch sizes in list of BS:
            run benchmark on Newport
            update BS_newport to the best one; update time_newport
        let E = margin scale
        while (time_host - time_newport) < (time_newport / E):
            BS_host += BS_host * (time_newport - time_host) / C
            run benchmark on host; get time_host
        return (BS_newport, BS_host)

Interpretation used here (validated against Table I):
  1. Sweep candidate batch sizes on the *slowest* class, pick the one with the
     best samples/sec that fits DRAM -> (BS_slow, time_slow).
  2. Grow every faster class's batch size by ``BS * Δtime / (time · C)``
     increments; the loop exits when ``time_fast - time_slow >= time_slow/E``,
     i.e. the fast class is deliberately loaded ~``1/E`` *beyond* equality.
     The margin absorbs the synchronization slowdown the fast engine suffers
     in distributed mode (it also runs the tunnel/aggregation processes).
     The paper fixes a 20% margin (E = 5); Table I confirms:
     MobileNetV2 host 315/31.05 = 10.14s vs Newport 25/3.08 = 8.12s (+25%),
     NASNet 6.87s vs 5.36s (+28%), our model reproduces 302/16 etc.
  3. C controls the update granularity: larger C = finer steps.

The benchmark callback abstracts "run benchmark on X": for real training it
times the jitted train step at the candidate batch size; for fleet planning it
evaluates the :class:`~repro.core.topology.WorkerClass` analytic model.  Both
paths share this exact loop.
"""
from __future__ import annotations

import dataclasses
import math
import time as _time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.topology import Fleet, WorkerClass

# benchmark(class_name, batch) -> seconds per step
BenchmarkFn = Callable[[str, int], float]


@dataclasses.dataclass(frozen=True)
class TuneResult:
    batches: Dict[str, int]          # class name -> tuned batch size
    step_times: Dict[str, float]     # measured step time at tuned batch
    throughputs: Dict[str, float]    # samples/s per *single* worker of class
    reference_class: str             # the slowest class that anchored the tune
    margin: float                    # 1/E sync margin actually applied

    @property
    def global_batch(self) -> int:
        return sum(self.batches.values())

    def imbalance(self) -> float:
        """Max relative step-time spread across classes (0 = perfect)."""
        ts = [t for t in self.step_times.values() if math.isfinite(t)]
        if len(ts) < 2:
            return 0.0
        return (max(ts) - min(ts)) / max(ts)


def default_candidate_batches(max_batch: int) -> List[int]:
    """The paper's 'list of BS': powers of two up to the DRAM limit."""
    out, b = [], 1
    while b <= max_batch:
        out.append(b)
        b *= 2
    return out or [1]


def analytic_benchmark(fleet: Fleet) -> BenchmarkFn:
    """Benchmark callback backed by the worker-class analytic model."""

    def bench(name: str, batch: int) -> float:
        return fleet.by_name(name).step_time(batch)

    return bench


def measured_benchmark(
    step_fns: Dict[str, Callable[[int], None]], repeats: int = 3
) -> BenchmarkFn:
    """Benchmark callback that times real (jitted) step functions.

    ``step_fns[name](batch)`` must run one full training step at ``batch``
    and block until complete (caller wraps block_until_ready).
    """

    def bench(name: str, batch: int) -> float:
        fn = step_fns[name]
        fn(batch)  # warmup / compile
        t0 = _time.perf_counter()
        for _ in range(repeats):
            fn(batch)
        return (_time.perf_counter() - t0) / repeats

    return bench


def tune(
    fleet: Fleet,
    benchmark: Optional[BenchmarkFn] = None,
    *,
    C: float = 10.0,
    E: float = 5.0,
    candidates: Optional[Dict[str, Sequence[int]]] = None,
    max_iters: int = 64,
) -> TuneResult:
    """Algorithm 1 generalized from (host, newport) to N worker classes.

    C: batch-size update granularity (paper: constant; larger = finer).
    E: margin scale; the target step time for fast classes is
       ``time_slow * (1 - 1/E)`` (paper: fixed 20% margin -> E = 5).
    """
    benchmark = benchmark or analytic_benchmark(fleet)
    candidates = candidates or {}

    # --- step 1: sweep the slowest class (the paper's "Newport" role) -------
    slow = fleet.slowest()
    best_bs, best_tput, best_time = 1, 0.0, math.inf
    for bs in candidates.get(slow.name, default_candidate_batches(slow.max_batch)):
        if bs > slow.max_batch:
            continue  # DRAM saturation: the paper rejects these outright
        t = benchmark(slow.name, bs)
        tput = bs / t if t > 0 else 0.0
        if tput > best_tput:
            best_bs, best_tput, best_time = bs, tput, t
    batches = {slow.name: best_bs}
    times = {slow.name: best_time}

    # --- step 2: grow every faster class until its time exceeds time_slow by
    # the 1/E sync margin (paper loop: while (t_fast - t_slow) < t_slow/E) ----
    target = best_time * (1.0 + 1.0 / E)
    for cls in fleet.classes:
        if cls.name == slow.name:
            continue
        bs = max(1, batches.get(cls.name, 1))
        t = benchmark(cls.name, bs)
        for _ in range(max_iters):
            if (t - best_time) >= best_time / E or bs >= cls.max_batch:
                break
            # paper: BS_host += BS_host * (time_newport - time_host) / C,
            # normalized by the current time so C is shape-independent.
            grow = max(1, int(bs * (target - t) / (max(t, 1e-9) * C)))
            bs = min(cls.max_batch, bs + grow)
            t = benchmark(cls.name, bs)
        # gross overshoot from a large discrete step: back off toward target
        while t > target * 1.25 and bs > 1:
            bs = max(1, bs - max(1, bs // 16))
            t = benchmark(cls.name, bs)
        batches[cls.name] = bs
        times[cls.name] = t

    tputs = {
        n: (batches[n] / times[n] if times[n] > 0 and math.isfinite(times[n]) else 0.0)
        for n in batches
    }
    return TuneResult(
        batches=batches,
        step_times=times,
        throughputs=tputs,
        reference_class=slow.name,
        margin=1.0 / E,
    )


# ---------------------------------------------------------------------------
# Online re-tuning (beyond paper: the paper tunes once, offline)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DriftMonitor:
    """EWMA per-class step-time monitor driving *online* re-tunes.

    The trainer feeds observed per-class step times; when the spread between
    the fastest and slowest class exceeds the tuner's ``1/E`` margin for
    ``patience`` consecutive steps, it requests a re-tune.  Because hetero
    batches are realized as masks over a fixed-shape global batch
    (:mod:`repro.core.hetero`), a re-tune never changes tensor shapes and so
    never triggers recompilation — that is what makes online re-tuning viable.
    """

    margin: float = 0.2
    alpha: float = 0.1
    patience: int = 10
    ewma: Dict[str, float] = dataclasses.field(default_factory=dict)
    _breach: int = 0

    def update(self, step_times: Dict[str, float]) -> bool:
        """Returns True when a re-tune should run."""
        for k, v in step_times.items():
            prev = self.ewma.get(k)
            self.ewma[k] = v if prev is None else (1 - self.alpha) * prev + self.alpha * v
        if len(self.ewma) < 2:
            return False
        ts = list(self.ewma.values())
        spread = (max(ts) - min(ts)) / max(max(ts), 1e-9)
        if spread > self.margin:
            self._breach += 1
        else:
            self._breach = 0
        if self._breach >= self.patience:
            self._breach = 0
            return True
        return False
