"""Heterogeneous synchronous data parallelism, SPMD-native (contribution C4).

The paper gives each worker a literally different batch size (Horovod processes
are independent programs).  Under ``pjit`` every device must run ONE program
with uniform shapes, so unequal batches are realized as a *mask* over a
fixed-shape global batch:

    global batch layout: (n_groups * max_local, ...)   # rows grouped by dp-group
    validity:            row r is valid iff (r mod max_local) < batch(group(r))

The loss is ``Σ mask·loss / Σ mask`` — summed and normalized GLOBALLY — so the
gradient equals exactly the gradient of the union of all valid samples.  That
makes masked-uniform batches *numerically identical* to true unequal batches
(property-tested), while remaining one XLA program whose shapes never change
when the tuner adjusts batch shares (only mask contents change -> no
recompilation, which is what makes online re-tuning free).

Padding cost: invalid rows still burn FLOPs.  The pad fraction is
``1 - mean(batch_g)/max(batch_g)``, i.e. exactly the heterogeneity spread —
and Algorithm 1 exists to keep the *time* spread near zero, so in a tuned
fleet the fast groups have full rows and slow groups have few, making the
wasted FLOPs the same FLOPs the hardware could not have used anyway (they
would be spent waiting at the allreduce barrier).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class BatchSchedule:
    """Fixed-shape realization of per-group tuned batch sizes.

    group_batches[g] = tuned batch for dp-group g (from Algorithm 1).
    max_local       = row capacity per group = max(group_batches) rounded up
                      to ``round_to`` (sharding-friendly).
    """

    group_batches: Tuple[int, ...]
    round_to: int = 1
    capacity: Optional[int] = None   # pinned row capacity (survives re-tunes)

    @property
    def n_groups(self) -> int:
        return len(self.group_batches)

    @property
    def max_local(self) -> int:
        m = max(self.group_batches) if self.group_batches else 0
        r = self.round_to
        m = ((m + r - 1) // r) * r
        return max(m, self.capacity or 0)

    @property
    def global_rows(self) -> int:
        """Padded global batch (rows in the SPMD program)."""
        return self.n_groups * self.max_local

    @property
    def valid_rows(self) -> int:
        return sum(self.group_batches)

    @property
    def pad_fraction(self) -> float:
        if self.global_rows == 0:
            return 0.0
        return 1.0 - self.valid_rows / self.global_rows

    def row_mask(self) -> np.ndarray:
        """(global_rows,) float32 validity mask, group-major layout."""
        m = np.zeros((self.n_groups, self.max_local), np.float32)
        for g, b in enumerate(self.group_batches):
            m[g, :b] = 1.0
        return m.reshape(-1)

    def with_batches(self, group_batches: Sequence[int]) -> "BatchSchedule":
        """Re-tune: new shares; the row capacity is pinned to the current
        ``max_local`` so shapes (and the compiled step) survive whenever the
        new batches fit.  Growth beyond capacity recompiles (rare by design)."""
        nb = tuple(int(b) for b in group_batches)
        return BatchSchedule(
            group_batches=nb, round_to=self.round_to,
            capacity=max(self.max_local,
                         BatchSchedule(nb, round_to=self.round_to).max_local),
        )


def masked_mean_loss(
    per_token_loss: jax.Array,   # (B, S) float
    loss_mask: jax.Array,        # (B, S) float — row validity x token validity
) -> jax.Array:
    """Global weighted mean: Σ mask·loss / Σ mask.

    Under pjit with batch sharded over dp, jnp.sum is a global (all-device)
    reduction — XLA inserts the psum — so the normalization is by the GLOBAL
    valid count, which is what makes unequal group batches exact.
    """
    num = jnp.sum(per_token_loss * loss_mask)
    den = jnp.maximum(jnp.sum(loss_mask), 1.0)
    return num / den


def apply_row_mask(loss_mask: jax.Array, row_mask: jax.Array) -> jax.Array:
    """Combine token-level mask (B, S) with row validity (B,)."""
    return loss_mask * row_mask[:, None]


def weighted_grad_union_equivalence(
    grad_fn,                    # params, batch_x, batch_mask -> grads (mean-normalized)
    params: PyTree,
    xs: Sequence[jax.Array],    # per-group inputs, group g has batch b_g rows
) -> Tuple[PyTree, PyTree]:
    """Test helper: (masked-padded grads, union-batch grads) for equivalence.

    Pads all groups to max batch, masks invalid rows, computes grads through
    ``grad_fn`` with global normalization; separately concatenates the true
    union batch.  Both must match to float tolerance.
    """
    bmax = max(x.shape[0] for x in xs)
    padded, mask = [], []
    for x in xs:
        b = x.shape[0]
        pad_width = [(0, bmax - b)] + [(0, 0)] * (x.ndim - 1)
        padded.append(jnp.pad(x, pad_width))
        mask.append(jnp.concatenate([jnp.ones(b), jnp.zeros(bmax - b)]))
    xp = jnp.concatenate(padded, axis=0)
    mp = jnp.concatenate(mask, axis=0)
    g_masked = grad_fn(params, xp, mp)

    xu = jnp.concatenate(list(xs), axis=0)
    mu = jnp.ones(xu.shape[0])
    g_union = grad_fn(params, xu, mu)
    return g_masked, g_union


# ---------------------------------------------------------------------------
# Group layout helpers for the trainer
# ---------------------------------------------------------------------------


def schedule_from_tune(
    tuned_batches: Dict[str, int],
    class_counts: Dict[str, int],
    *,
    round_to: int = 1,
) -> Tuple[BatchSchedule, List[str]]:
    """Expand per-CLASS tuned batches into per-GROUP schedule + group labels.

    Each physical worker of a class becomes one dp-group with that class's
    tuned batch (the paper's 24 CSDs are 24 identical groups + 1 host group).
    """
    group_batches: List[int] = []
    labels: List[str] = []
    for name in sorted(tuned_batches):
        for i in range(class_counts.get(name, 1)):
            group_batches.append(tuned_batches[name])
            labels.append(f"{name}/{i}")
    return BatchSchedule(tuple(group_batches), round_to=round_to), labels


def effective_batch_per_class(
    schedule: BatchSchedule, labels: Sequence[str]
) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for b, lab in zip(schedule.group_batches, labels):
        cls = lab.split("/")[0]
        out[cls] = out.get(cls, 0) + b
    return out
