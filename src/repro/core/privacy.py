"""Privacy-constrained data placement (paper contribution C3).

In the paper, private data lives on a CSD's flash and *never* crosses the
NVMe/host boundary; only public data is shared between host and CSDs.  On a
TPU fleet the analogue is pod-local (or dp-group-local) residency: a private
shard is pinned to its home dp-group and is only ever read by that group's
input pipeline.

This module produces an explicit, auditable *placement manifest*; the storage
layer (:mod:`repro.storage`) refuses to materialize a private shard on any
device other than its owner's — every backend's custody guard is the
enforcement point, mirroring how the paper's ISP engine is the only thing
that can touch flash.  Custody *changes* (re-homes after a node loss,
quarantines of a dead owner's privates) are logged as :class:`CustodyEvent`
records; :func:`audit_custody` is the machine check that no private shard
ever moved.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Shard:
    shard_id: str
    n_samples: int
    private: bool
    owner: Optional[str] = None      # required iff private

    def __post_init__(self):
        if self.private and self.owner is None:
            raise ValueError(f"private shard {self.shard_id!r} needs an owner")


@dataclasses.dataclass(frozen=True)
class Assignment:
    worker: str
    shard_id: str
    n_samples: int                   # samples drawn from this shard
    private: bool


@dataclasses.dataclass(frozen=True)
class PlacementManifest:
    assignments: Tuple[Assignment, ...]

    def for_worker(self, worker: str) -> List[Assignment]:
        return [a for a in self.assignments if a.worker == worker]

    def validate(self, shards: Mapping[str, Shard]) -> None:
        """Raise if any private shard is read by a non-owner (the invariant)."""
        for a in self.assignments:
            s = shards[a.shard_id]
            if s.private and a.worker != s.owner:
                raise PermissionError(
                    f"private shard {s.shard_id!r} (owner {s.owner!r}) "
                    f"assigned to {a.worker!r}"
                )

    def totals(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for a in self.assignments:
            out[a.worker] = out.get(a.worker, 0) + a.n_samples
        return out


def place(
    shards: Sequence[Shard],
    worker_targets: Mapping[str, int],   # worker -> samples/epoch (from Eq.1 plan)
) -> PlacementManifest:
    """Assign shards to workers honoring privacy.

    Private shards go whole to their owners (up to the owner's target).
    Public shards are split greedily across workers still short of target.
    """
    by_id = {s.shard_id: s for s in shards}
    remaining = dict(worker_targets)
    assigns: List[Assignment] = []

    # 1. private first — pinned, possibly truncated to the owner's target
    for s in shards:
        if not s.private:
            continue
        tgt = remaining.get(s.owner, 0)
        take = min(s.n_samples, tgt)
        if take > 0:
            assigns.append(Assignment(s.owner, s.shard_id, take, True))
            remaining[s.owner] = tgt - take

    # 2. public fills the gaps, split across workers
    for s in shards:
        if s.private:
            continue
        left = s.n_samples
        for w in sorted(remaining, key=lambda w: -remaining[w]):
            if left <= 0:
                break
            take = min(left, remaining[w])
            if take > 0:
                assigns.append(Assignment(w, s.shard_id, take, False))
                remaining[w] -= take
                left -= take

    manifest = PlacementManifest(assignments=tuple(assigns))
    manifest.validate(by_id)
    return manifest


@dataclasses.dataclass(frozen=True)
class CustodyEvent:
    """One auditable custody change in the device fleet.

    ``kind``: "provision" (a device came up holding the shard), "rehome"
    (a public shard's custodian died; a survivor took over), or
    "quarantine" (a private shard's owner died; the bytes are tombstoned).
    """

    kind: str
    shard_id: str
    private: bool
    src: Optional[str] = None     # previous custodian (None on provision)
    dst: Optional[str] = None     # new custodian (None on quarantine)

    def __post_init__(self):
        if self.kind not in ("provision", "rehome", "quarantine"):
            raise ValueError(f"unknown custody event kind {self.kind!r}")


def audit_custody(log: Sequence[CustodyEvent]) -> Dict[str, int]:
    """The paper's privacy claim over the custody log: private shards may be
    provisioned (to their owner) or quarantined, NEVER re-homed.

    Beyond the headline re-home count, two log pathologies are flagged:

    * ``private_shards_resurrected`` — a private shard provisioned *after*
      it was quarantined: tombstoned bytes coming back to life means some
      device re-materialized data whose owner is gone.
    * ``duplicate_provisions`` — the same shard provisioned twice to the
      same custodian with no intervening custody change: double-counted
      custody makes the rest of the log unauditable.
    """
    moved = 0
    resurrected = 0
    duplicates = 0
    quarantined: set = set()
    live: set = set()                 # (shard_id, custodian) currently held
    for e in log:
        if e.kind == "rehome":
            if e.private:
                moved += 1
            live.discard((e.shard_id, e.src))
            live.add((e.shard_id, e.dst))
        elif e.kind == "quarantine":
            quarantined.add(e.shard_id)
            live = {lv for lv in live if lv[0] != e.shard_id}
        elif e.kind == "provision":
            if e.private and e.shard_id in quarantined:
                resurrected += 1
            if (e.shard_id, e.dst) in live:
                duplicates += 1
            live.add((e.shard_id, e.dst))
    return {
        "private_shards_rehomed": moved,
        "private_shards_resurrected": resurrected,
        "duplicate_provisions": duplicates,
    }


def leakage_report(
    manifest: PlacementManifest, shards: Mapping[str, Shard]
) -> Dict[str, int]:
    """Bytes-equivalent of the paper's privacy claim: count private samples
    that would transit the interconnect (must be 0 by construction)."""
    leaked = 0
    for a in manifest.assignments:
        s = shards[a.shard_id]
        if s.private and a.worker != s.owner:
            leaked += a.n_samples
    return {"private_samples_moved": leaked}
