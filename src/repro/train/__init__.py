"""Training steps.  The old ``Trainer`` entry point is gone — construct a
:class:`repro.api.Session` instead."""
from repro.train.steps import (
    abstract_train_state, build_sharding_plan, loss_fn, make_serve_step,
    make_train_step,
)

__all__ = [
    "abstract_train_state",
    "build_sharding_plan",
    "loss_fn",
    "make_serve_step",
    "make_train_step",
]
