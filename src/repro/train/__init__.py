"""Training steps.  The old ``Trainer`` entry point is gone — construct a
:class:`repro.api.Session` instead (``repro.train.trainer`` holds the
raising stub with the migration map)."""
from repro.train.steps import loss_fn, make_serve_step, make_train_step

__all__ = ["loss_fn", "make_train_step", "make_serve_step"]
