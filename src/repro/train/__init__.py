from repro.train.steps import loss_fn, make_serve_step, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

__all__ = ["loss_fn", "make_train_step", "make_serve_step", "Trainer", "TrainerConfig"]
