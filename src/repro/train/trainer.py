"""DEPRECATED shim: ``Trainer`` now delegates to :class:`repro.api.Session`.

The staged Session API (``session.tune() -> .plan() -> .place() ->
.compile() -> .run()``) replaced the monolithic ``setup()``/``train()``
pipeline; new code should construct a Session directly:

    from repro.api import Session, SessionConfig, FleetSpec

This shim keeps the seed surface alive — ``setup``, ``train``, ``retune``,
``drop_workers`` and the ``tune_result``/``schedule``/``plan``/``manifest``/
``dataset``/``shards`` attributes — by forwarding everything to a Session.
``drop_workers`` and ``retune`` now route through the unified
``Session.apply(FleetEvent)`` path, which fixes the seed bug where a node
loss rebuilt the :class:`~repro.core.hetero.BatchSchedule` without the
pinned ``capacity`` and forced an avoidable recompile.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.api.events import DriftDetected, WorkerLost
from repro.api.session import Session, SessionConfig
from repro.core.hetero import BatchSchedule
from repro.core.load_balance import EpochPlan
from repro.core.privacy import PlacementManifest, Shard
from repro.core.topology import Fleet
from repro.core.tuner import TuneResult
from repro.data.pipeline import DataConfig, StannisDataset
from repro.models.api import Model
from repro.optim.optimizers import Optimizer

PyTree = Any


@dataclasses.dataclass
class TrainerConfig(SessionConfig):
    """Deprecated alias of :class:`repro.api.SessionConfig`."""


@dataclasses.dataclass
class Trainer:
    """Deprecated: use :class:`repro.api.Session`."""

    model: Model
    optimizer: Optimizer
    fleet: Fleet
    data_cfg: DataConfig
    cfg: TrainerConfig
    shards: Sequence[Shard]
    benchmark: Optional[Callable[[str, int], float]] = None

    session: Optional[Session] = None

    def __post_init__(self):
        warnings.warn(
            "repro.train.trainer.Trainer is deprecated; use repro.api.Session",
            DeprecationWarning,
            stacklevel=3,
        )

    def _session(self) -> Session:
        if self.session is None:
            self.session = Session(
                model=self.model,
                optimizer=self.optimizer,
                fleet=self.fleet,
                data=self.data_cfg,
                shards=list(self.shards),
                config=self.cfg,
                benchmark=self.benchmark,
            )
        return self.session

    # -- seed attribute surface (all derived from session artifacts) -------

    @property
    def tune_result(self) -> Optional[TuneResult]:
        s = self._session()
        return s.tune().result if s.cached("tune") else None

    @property
    def schedule(self) -> Optional[BatchSchedule]:
        s = self._session()
        return s.tune().schedule if s.cached("tune") else None

    @property
    def group_workers(self) -> Optional[List[str]]:
        s = self._session()
        return list(s.tune().group_workers) if s.cached("tune") else None

    @property
    def plan(self) -> Optional[EpochPlan]:
        s = self._session()
        return s.plan() if s.cached("plan") else None

    @property
    def manifest(self) -> Optional[PlacementManifest]:
        s = self._session()
        return s.place() if s.cached("place") else None

    @property
    def dataset(self) -> StannisDataset:
        return self._session().dataset

    # -- seed method surface -----------------------------------------------

    def setup(self) -> "Trainer":
        s = self._session()
        s.tune()
        s.plan()
        s.place()
        _ = s.dataset
        return self

    def train(
        self,
        params: Optional[PyTree] = None,
        *,
        steps: Optional[int] = None,
        on_metrics: Optional[Callable[[int, Dict], None]] = None,
    ) -> Tuple[PyTree, List[Dict[str, float]]]:
        s = self._session()
        remove = None
        if on_metrics is not None:
            remove = s.callbacks.on_step(on_metrics)
        try:
            report = s.run(params, steps=steps)
        finally:
            if remove is not None:
                s.callbacks.remove_on_step(remove)
        return report.params, list(report.history)

    def retune(self) -> None:
        """Online re-tune: new batch shares, same shapes => no recompilation."""
        self._session().apply(DriftDetected(source="manual"))

    def drop_workers(self, dead: Sequence[str]) -> None:
        """Node failure (paper's backfill/duplication remedy), routed through
        the unified ``Session.apply(WorkerLost)`` replanning path.

        Seed parity: unknown / already-dropped names are ignored (failure
        detectors double-report), where the Session API itself is strict."""
        s = self._session()
        known = [w for w in dead if w in s.tune().group_workers]
        if known:
            s.apply(WorkerLost(known))
        self.shards = list(s.shards)
