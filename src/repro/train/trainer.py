"""REMOVED: ``Trainer`` is a raising stub — use :class:`repro.api.Session`.

PR 1 replaced the monolithic ``Trainer`` with the staged Session API and
left a behavior-compatible delegation shim here; this PR finishes the
deprecation.  Instantiating ``Trainer`` now raises ``DeprecationWarning``
with the migration recipe instead of silently forwarding, so stale call
sites fail loudly at construction (not subtly at behavior drift).

Migration map (old -> new):

    Trainer(model, optimizer, fleet, data_cfg, cfg, shards)
        -> Session(model=..., optimizer=..., fleet=..., data=...,
                   shards=..., config=SessionConfig(...))
    .setup()                    -> session.tune(); session.plan();
                                   session.place()   (stages are lazy:
                                   session.run() alone also works)
    .train(params, steps=N)     -> session.run(params, steps=N)
    .retune()                   -> session.apply(DriftDetected())
    .drop_workers([w])          -> session.apply(WorkerLost([w]))
    .schedule / .plan / .manifest / .dataset
        -> session.tune().schedule / session.plan() / session.place()
           / session.dataset
"""
from __future__ import annotations

from repro.api.session import SessionConfig

_HINT = (
    "repro.train.trainer.Trainer was removed; use repro.api.Session:\n"
    "    from repro.api import Session, SessionConfig, FleetSpec\n"
    "    session = Session(model=model, optimizer=opt, fleet=fleet,\n"
    "                      data=data_cfg, shards=shards,\n"
    "                      config=SessionConfig(...))\n"
    "    report = session.run()\n"
    "See repro/train/trainer.py's docstring for the full migration map."
)


class TrainerConfig(SessionConfig):
    """Deprecated alias kept importable so old configs migrate in place."""


class Trainer:
    """Raising stub — see the module docstring for the migration map."""

    def __init__(self, *args, **kwargs):
        raise DeprecationWarning(_HINT)
