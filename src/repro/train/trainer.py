"""The Stannis trainer: tune -> balance -> place -> train, with fault tolerance.

Orchestrates the full paper pipeline:
  1. Algorithm 1 tunes per-class batch sizes (measured or analytic benchmark).
  2. Eq. 1 plans dataset shares so epochs align.
  3. The privacy planner pins private shards to owners.
  4. Training runs the masked-weighted SPMD step under ``jax.jit`` with
     sharding rules; per-class step times feed the :class:`DriftMonitor`,
     which triggers ONLINE re-tunes (beyond-paper) — shapes never change, so
     a re-tune costs zero recompilation.
  5. CheckpointManager gives restart-after-failure; elastic restore handles a
     shrunk fleet (lost pod => fewer dp-groups; private shards of lost workers
     follow the paper's backfill/duplication remedy).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.hetero import BatchSchedule, schedule_from_tune
from repro.core.load_balance import plan_epoch
from repro.core.privacy import PlacementManifest, Shard, place
from repro.core.topology import Fleet
from repro.core.tuner import DriftMonitor, TuneResult, tune
from repro.data.pipeline import DataConfig, make_stannis_dataset
from repro.models.api import Model
from repro.optim.optimizers import Optimizer
from repro.optim.schedules import goyal_schedule
from repro.train.steps import make_train_step

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    base_lr: float = 1e-3
    base_batch: int = 256
    warmup_steps: int = 20
    aux_weight: float = 0.01
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    retune_margin: float = 0.2       # DriftMonitor threshold = tuner 1/E
    retune_patience: int = 10
    log_every: int = 10
    seed: int = 0


@dataclasses.dataclass
class Trainer:
    model: Model
    optimizer: Optimizer
    fleet: Fleet
    data_cfg: DataConfig
    cfg: TrainerConfig
    shards: Sequence[Shard]
    benchmark: Optional[Callable[[str, int], float]] = None

    # populated by setup()
    tune_result: Optional[TuneResult] = None
    schedule: Optional[BatchSchedule] = None
    group_workers: Optional[List[str]] = None
    manifest: Optional[PlacementManifest] = None

    def setup(self) -> "Trainer":
        # 1. Algorithm 1
        self.tune_result = tune(self.fleet, self.benchmark)
        class_counts = {c.name: c.count for c in self.fleet.classes}
        self.schedule, self.group_workers = schedule_from_tune(
            self.tune_result.batches, class_counts
        )
        # 2. Eq. 1 over physical workers
        batches = {
            w: b for w, b in zip(self.group_workers, self.schedule.group_batches)
        }
        private_sizes = {w: 0 for w in self.group_workers}
        n_public = 0
        for s in self.shards:
            if s.private:
                private_sizes[s.owner] = private_sizes.get(s.owner, 0) + s.n_samples
            else:
                n_public += s.n_samples
        self.plan = plan_epoch(batches, private_sizes, n_public)
        # 3. privacy placement against the planned shares
        targets = {sh.worker: sh.total for sh in self.plan.shares}
        self.manifest = place(list(self.shards), targets)
        # 4. data pipeline
        self.dataset = make_stannis_dataset(
            self.data_cfg, self.schedule, self.group_workers, self.plan,
            self.manifest, self.shards,
        )
        return self

    # -- the jitted step -----------------------------------------------------
    def _build_step(self):
        sched = goyal_schedule(
            self.cfg.base_lr,
            self.schedule.valid_rows,
            base_batch=self.cfg.base_batch,
            warmup_steps=self.cfg.warmup_steps,
            total_steps=self.cfg.total_steps,
        )
        step = make_train_step(
            self.model, self.optimizer, sched, aux_weight=self.cfg.aux_weight
        )
        return jax.jit(step, donate_argnums=(0, 1))

    def train(
        self,
        params: Optional[PyTree] = None,
        *,
        steps: Optional[int] = None,
        on_metrics: Optional[Callable[[int, Dict], None]] = None,
    ) -> Tuple[PyTree, List[Dict[str, float]]]:
        if self.schedule is None:
            self.setup()
        steps = steps or self.cfg.total_steps
        key = jax.random.PRNGKey(self.cfg.seed)
        if params is None:
            params, _ = self.model.init_params(key=key)
        opt_state = self.optimizer.init(params)

        ckpt = (
            CheckpointManager(self.cfg.checkpoint_dir, keep=self.cfg.keep_checkpoints)
            if self.cfg.checkpoint_dir else None
        )
        start_step = 0
        if ckpt is not None and ckpt.latest_step() is not None:
            # restart-after-failure: resume newest valid checkpoint
            state, meta = ckpt.restore({"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = int(meta.get("step", ckpt.latest_step()))

        step_fn = self._build_step()
        monitor = DriftMonitor(
            margin=self.cfg.retune_margin, patience=self.cfg.retune_patience
        )
        history: List[Dict[str, float]] = []

        for i in range(start_step, steps):
            batch_np = self.dataset.next_batch()
            batch = {
                "tokens": jnp.asarray(batch_np["tokens"]),
                "labels": jnp.asarray(batch_np["labels"]),
                "loss_mask": jnp.asarray(batch_np["loss_mask"]),
            }
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step_time"] = time.perf_counter() - t0
            history.append(metrics)

            # straggler watch: feed per-class analytic times perturbed by the
            # observed wall time (single-host stand-in for per-worker probes)
            class_times = {
                c.name: self.fleet.by_name(c.name).step_time(
                    self.tune_result.batches[c.name]
                )
                for c in self.fleet.classes
            }
            if monitor.update(class_times):
                self.retune()

            if on_metrics:
                on_metrics(i, metrics)
            if ckpt is not None and (i + 1) % self.cfg.checkpoint_every == 0:
                ckpt.save(
                    i + 1, {"params": params, "opt": opt_state},
                    metadata={"step": i + 1,
                              "schedule": list(self.schedule.group_batches)},
                    async_=self.cfg.async_checkpoint,
                )
        if ckpt is not None:
            ckpt.wait()
        return params, history

    def retune(self) -> None:
        """Online re-tune: new batch shares, same shapes => no recompilation."""
        self.tune_result = tune(self.fleet, self.benchmark)
        class_counts = {c.name: c.count for c in self.fleet.classes}
        new_sched, workers = schedule_from_tune(
            self.tune_result.batches, class_counts
        )
        self.schedule = self.schedule.with_batches(new_sched.group_batches)
        self.dataset.schedule = self.schedule

    # -- failure handling ------------------------------------------------------
    def drop_workers(self, dead: Sequence[str]) -> None:
        """Node failure: remove dp-groups, re-plan data with the paper's remedy
        (dead workers' public share rebalances; their private shards are gone
        — by the privacy constraint nobody else may read them)."""
        alive = [w for w in self.group_workers if w not in set(dead)]
        keep_idx = [i for i, w in enumerate(self.group_workers) if w in set(alive)]
        self.group_workers = alive
        self.schedule = BatchSchedule(
            tuple(self.schedule.group_batches[i] for i in keep_idx),
            round_to=self.schedule.round_to,
        )
        live_shards = [
            s for s in self.shards if not (s.private and s.owner in set(dead))
        ]
        self.shards = live_shards
        batches = {w: b for w, b in zip(self.group_workers, self.schedule.group_batches)}
        private_sizes = {w: 0 for w in alive}
        n_public = 0
        for s in live_shards:
            if s.private:
                private_sizes[s.owner] = private_sizes.get(s.owner, 0) + s.n_samples
            else:
                n_public += s.n_samples
        self.plan = plan_epoch(batches, private_sizes, n_public)
        targets = {sh.worker: sh.total for sh in self.plan.shares}
        self.manifest = place(live_shards, targets)
        self.dataset = make_stannis_dataset(
            self.data_cfg, self.schedule, self.group_workers, self.plan,
            self.manifest, live_shards,
        )
