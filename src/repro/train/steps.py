"""train_step / serve_step factories: the functions that get pjit'd.

``make_train_step`` builds the masked-weighted-loss training step
(:mod:`repro.core.hetero` semantics): per-token CE, multiplied by the combined
row-validity x token mask, summed and normalized GLOBALLY, so heterogeneous
group batch sizes are numerically exact.  ``make_serve_step`` builds the
one-token KV-cache decode step for the inference shapes.

This module also owns the *abstract* train state (ShapeDtypeStruct trees for
params / opt_state / batch — no allocation) and :func:`build_sharding_plan`,
which resolves the logical-axis rule table against a live mesh into the
:class:`~repro.api.artifacts.ShardingPlan` every downstream consumer
(``Session.compile``, sharded init, meshfeed, checkpoint restore) reads.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.hetero import masked_mean_loss
from repro.distributed.sharding import (
    ShardingPlan, arg_shardings_for_tree, make_rules,
)
from repro.models.api import Model
from repro.optim.optimizers import Optimizer, OptState

PyTree = Any

# logical axes of the Stannis training batch: rows over the dp-ish axes,
# sequence replicated (SP long-context shards it via the seq_data rule)
BATCH_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "tokens": ("batch", "seq_data"),
    "labels": ("batch", "seq_data"),
    "loss_mask": ("batch", "seq_data"),
}


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-token CE, numerically stable. logits (B,S,V) f32/bf16; labels (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - gold


def loss_fn(
    model: Model,
    params: PyTree,
    batch: Dict[str, jax.Array],
    *,
    aux_weight: float = 0.01,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Masked global-mean LM loss (+ router aux for MoE)."""
    kwargs = {}
    for k in ("frames", "patch_embeds"):
        if k in batch:
            kwargs[k] = batch[k]
    logits, aux = model.forward(params, batch["tokens"], **kwargs)
    # VLM: logits cover [patches | text]; score text positions only
    labels = batch["labels"]
    mask = batch["loss_mask"]
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, -labels.shape[1]:]
    ce = cross_entropy(logits, labels)
    loss = masked_mean_loss(ce, mask)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux, "tokens": jnp.sum(mask)}


def make_train_step(
    model: Model,
    optimizer: Optimizer,
    lr_schedule: Callable[[jax.Array], jax.Array],
    *,
    aux_weight: float = 0.01,
    grad_transform: Optional[Callable[[PyTree], PyTree]] = None,
) -> Callable:
    """Returns ``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``.

    ``grad_transform`` hooks the beyond-paper compressed/ring allreduce in
    (identity under plain pjit where XLA inserts the psum itself).
    """

    def train_step(params, opt_state: OptState, batch):
        (total, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch, aux_weight=aux_weight), has_aux=True
        )(params)
        if grad_transform is not None:
            grads = grad_transform(grads)
        lr = lr_schedule(opt_state.step)
        opt_state, params = optimizer.update(grads, opt_state, params, lr)
        # NOTE: elementwise square + sum, NOT vdot — vdot reshapes each leaf
        # to 1-D, which GSPMD can only partition by all-gathering the whole
        # (f32-upcast) tensor; measured at +4.5 GB/layer on qwen3-moe.
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads))
        )
        metrics = {
            "loss": parts["loss"],
            "aux": parts["aux"],
            "total": total,
            "lr": lr,
            "grad_norm": gnorm,
            "tokens": parts["tokens"],
        }
        return params, opt_state, metrics

    return train_step


def make_partial_grad_step(
    model: Model,
    *,
    aux_weight: float = 0.01,
) -> Callable:
    """The per-host half of cluster (hostsync) training.

    Returns ``grad_step(params, batch) -> (grads, sums)`` computing this
    host's UNNORMALIZED contribution to the global objective over its local
    rows only:

        F_p(params) = Σ_p mask·ce  +  aux_weight · den_p · aux_p
        sums        = {num: Σ mask·ce, den: Σ mask, auxden: den_p · aux_p}

    The global masked-mean step is ``total = (Σ_p F_p) / max(Σ_p den_p, 1)``
    — a ratio of ACROSS-host sums — so summing each host's ``grads`` and
    ``sums`` and applying :func:`make_apply_step` reproduces the
    single-program :func:`make_train_step` exactly (dense models; an MoE
    router aux becomes its den-weighted mean, which coincides for P=1).
    This is how a backend that cannot run cross-process XLA programs
    (CPU jaxlib — see :func:`repro.compat.multiprocess_compute_supported`)
    still trains one exact global model: partial gradients meet at the
    coordinator, the paper's host-aggregation topology.
    """

    def objective(params, batch):
        kwargs = {
            k: batch[k] for k in ("frames", "patch_embeds") if k in batch
        }
        logits, aux = model.forward(params, batch["tokens"], **kwargs)
        labels = batch["labels"]
        mask = batch["loss_mask"]
        if logits.shape[1] != labels.shape[1]:
            logits = logits[:, -labels.shape[1]:]
        ce = cross_entropy(logits, labels)
        num = jnp.sum(ce * mask)
        den = jnp.sum(mask)
        auxden = den * aux
        return num + aux_weight * auxden, {
            "num": num, "den": den, "auxden": auxden,
        }

    def grad_step(params, batch):
        (_, sums), grads = jax.value_and_grad(
            objective, has_aux=True
        )(params, batch)
        return grads, sums

    return grad_step


def make_apply_step(
    optimizer: Optimizer,
    lr_schedule: Callable[[jax.Array], jax.Array],
    *,
    aux_weight: float = 0.01,
) -> Callable:
    """The update half of cluster (hostsync) training.

    ``apply_step(params, opt_state, grads, sums) -> (params, opt_state,
    metrics)`` consumes the ACROSS-host sums of :func:`make_partial_grad_step`
    outputs.  Every host applies the identical update to its identical
    params — replicas stay bit-synchronized without a broadcast, and the
    metrics match :func:`make_train_step`'s.
    """

    def apply_step(params, opt_state: OptState, grads, sums):
        den = jnp.maximum(sums["den"], 1.0)
        loss = sums["num"] / den
        aux = sums["auxden"] / den
        grads = jax.tree_util.tree_map(lambda g: g / den, grads)
        lr = lr_schedule(opt_state.step)
        opt_state, params = optimizer.update(grads, opt_state, params, lr)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads))
        )
        metrics = {
            "loss": loss,
            "aux": aux,
            "total": loss + aux_weight * aux,
            "lr": lr,
            "grad_norm": gnorm,
            "tokens": sums["den"],
        }
        return params, opt_state, metrics

    return apply_step


def plan_buckets(params_abs: PyTree, n_buckets: int) -> Tuple[Tuple[int, ...], ...]:
    """Split the param-leaf list into contiguous, byte-balanced groups.

    Buckets are the cluster transport's unit of pipelining: the hostsync
    grad step emits one flat f32 vector per group, so bucket *i*'s
    reduction overlaps bucket *i+1*'s encode.  Greedy contiguous packing —
    leaf order (and therefore the vector layout) is the deterministic
    ``tree_leaves`` order every worker shares.
    """
    leaves = jax.tree_util.tree_leaves(params_abs)
    n_leaves = len(leaves)
    n_buckets = max(1, min(int(n_buckets), n_leaves))
    sizes = [
        int(jnp.dtype(l.dtype).itemsize)
        * (int(math.prod(l.shape)) if l.shape else 1)
        for l in leaves
    ]
    groups = []
    start = 0
    left_bytes = float(sum(sizes))
    for b in range(n_buckets):
        buckets_left = n_buckets - b
        if buckets_left == 1:
            groups.append(tuple(range(start, n_leaves)))
            break
        target = left_bytes / buckets_left
        take, acc = 1, sizes[start]
        while (
            start + take < n_leaves
            and (n_leaves - start - take) > (buckets_left - 1)
            and abs(acc + sizes[start + take] - target) <= abs(acc - target)
        ):
            acc += sizes[start + take]
            take += 1
        groups.append(tuple(range(start, start + take)))
        start += take
        left_bytes -= acc
    return tuple(groups)


def make_bucketed_grad_step(
    model: Model,
    bucket_groups: Tuple[Tuple[int, ...], ...],
    *,
    aux_weight: float = 0.01,
) -> Callable:
    """:func:`make_partial_grad_step` with the grad pytree flattened into
    one f32 vector per bucket group — the cluster transport's wire format.
    Returns ``grad_step(params, batch) -> (bucket_vecs, sums)``.
    """
    base = make_partial_grad_step(model, aux_weight=aux_weight)

    def grad_step(params, batch):
        grads, sums = base(params, batch)
        leaves = jax.tree_util.tree_leaves(grads)
        vecs = tuple(
            leaves[grp[0]].astype(jnp.float32).reshape(-1)
            if len(grp) == 1 else
            jnp.concatenate(
                [leaves[i].astype(jnp.float32).reshape(-1) for i in grp]
            )
            for grp in bucket_groups
        )
        return vecs, sums

    return grad_step


def make_bucketed_apply_step(
    optimizer: Optimizer,
    lr_schedule: Callable[[jax.Array], jax.Array],
    params_abs: PyTree,
    bucket_groups: Tuple[Tuple[int, ...], ...],
    *,
    aux_weight: float = 0.01,
) -> Callable:
    """:func:`make_apply_step` taking the reduced bucket vectors instead of
    a grad pytree; the unflatten happens inside the jitted step.  Exact
    inverse of :func:`make_bucketed_grad_step`'s flatten (f32 round-trip of
    f32/bf16 grads is lossless), so bucketing never changes numerics.
    """
    base = make_apply_step(optimizer, lr_schedule, aux_weight=aux_weight)
    leaves_abs, treedef = jax.tree_util.tree_flatten(params_abs)
    shapes = [l.shape for l in leaves_abs]
    dtypes = [l.dtype for l in leaves_abs]
    counts = [int(math.prod(s)) if s else 1 for s in shapes]

    def apply_step(params, opt_state: OptState, bucket_vecs, sums):
        leaves = [None] * len(leaves_abs)
        for grp, vec in zip(bucket_groups, bucket_vecs):
            off = 0
            for i in grp:
                n = counts[i]
                leaves[i] = (
                    vec[off:off + n].reshape(shapes[i]).astype(dtypes[i])
                )
                off += n
        grads = jax.tree_util.tree_unflatten(treedef, leaves)
        return base(params, opt_state, grads, sums)

    return apply_step


def residual_bytes(
    model: Model, batch_abs: Dict[str, Any], *, aux_weight: float = 0.01
) -> int:
    """Bytes of saved-for-backward residuals of one loss VJP (no allocation).

    ``jax.vjp``'s pullback is a Partial pytree whose leaves ARE the residual
    arrays, so ``eval_shape`` of it prices the backward pass's live memory —
    the footprint ``train_precision="int8-fused"`` shrinks by saving K/V and
    scan activations as int8 + per-row scales instead of full-width floats.
    """
    params_abs, _ = model.init_params(abstract=True)

    def f(params, batch):
        _, pullback = jax.vjp(
            lambda p: loss_fn(model, p, batch, aux_weight=aux_weight)[0],
            params,
        )
        return pullback

    pb = jax.eval_shape(f, params_abs, batch_abs)
    return int(sum(
        jnp.dtype(l.dtype).itemsize * (int(math.prod(l.shape)) if l.shape else 1)
        for l in jax.tree_util.tree_leaves(pb)
    ))


def make_eval_step(model: Model, *, aux_weight: float = 0.01) -> Callable:
    def eval_step(params, batch):
        _, parts = loss_fn(model, params, batch, aux_weight=aux_weight)
        return parts

    return eval_step


def make_serve_step(model: Model) -> Callable:
    """One-token decode: (params, token, cache, pos) -> (next_token, logits, cache)."""

    def serve_step(params, token, cache, pos):
        logits, cache = model.decode_step(params, token, cache, pos)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token[:, None], logits, cache

    return serve_step


def make_prefill_step(model: Model, cache_len: int) -> Callable:
    def prefill_step(params, tokens, **kwargs):
        return model.prefill(params, tokens, cache_len, **kwargs)

    return prefill_step


# ---------------------------------------------------------------------------
# Abstract train state + the ShardingPlan builder
# ---------------------------------------------------------------------------


def abstract_opt_state(optimizer: Optimizer, params: PyTree) -> OptState:
    """Optimizer state as ShapeDtypeStructs — ``eval_shape`` of the real
    ``init``, so any optimizer (SGD's ``nu=None``, AdamW's two moments)
    yields the exact state structure without allocating a byte."""
    return jax.eval_shape(optimizer.init, params)


def abstract_train_state(
    model: Model, optimizer: Optimizer
) -> Tuple[PyTree, PyTree, OptState]:
    """(params, logical_axes, opt_state) as abstract trees (no allocation)."""
    params, axes = model.init_params(abstract=True)
    return params, axes, abstract_opt_state(optimizer, params)


def abstract_batch(global_rows: int, seq_len: int) -> Dict[str, Any]:
    """The Stannis batch as ShapeDtypeStructs (keys match ``BATCH_AXES``)."""
    SDS = jax.ShapeDtypeStruct
    return {
        "tokens": SDS((global_rows, seq_len), jnp.int32),
        "labels": SDS((global_rows, seq_len), jnp.int32),
        "loss_mask": SDS((global_rows, seq_len), jnp.float32),
    }


def build_sharding_plan(
    model: Model,
    optimizer: Optimizer,
    *,
    mesh: Mesh,
    global_rows: int,
    seq_len: int,
    extra_rules: Optional[Dict[str, Any]] = None,
) -> ShardingPlan:
    """Resolve the rule table against ``mesh`` into one ShardingPlan.

    Size-aware (via :func:`arg_shardings_for_tree`): a dim a mesh axis does
    not divide falls back to replicated on that dim, so the plan is valid as
    jit ARGUMENT shardings on any mesh shape.  Optimizer moments reuse the
    parameter shardings (same shapes, f32), the step counter and metrics are
    replicated, and batch rows shard over the dp axes.
    """
    rules = make_rules(
        fsdp=bool(getattr(model.cfg, "fsdp", False)), extra=extra_rules or None
    )
    rules.setdefault("seq_data", None)
    replicated = NamedSharding(mesh, P())

    params_abs, p_axes = model.init_params(abstract=True)
    p_sh = arg_shardings_for_tree(p_axes, params_abs, rules, mesh)
    opt_abs = abstract_opt_state(optimizer, params_abs)
    opt_sh = OptState(
        step=replicated,
        mu=p_sh,
        nu=None if opt_abs.nu is None else p_sh,
    )
    batch_abs = abstract_batch(global_rows, seq_len)
    b_sh = arg_shardings_for_tree(BATCH_AXES, batch_abs, rules, mesh)

    data_axis = int(mesh.shape.get("data", 1)) if "data" in mesh.axis_names else 1
    return ShardingPlan(
        mesh=mesh,
        rules=rules,
        params=p_sh,
        opt=opt_sh,
        batch=b_sh,
        replicated=replicated,
        global_rows=int(global_rows),
        data_axis=data_axis,
    )
