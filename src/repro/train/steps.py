"""train_step / serve_step factories: the functions that get pjit'd.

``make_train_step`` builds the masked-weighted-loss training step
(:mod:`repro.core.hetero` semantics): per-token CE, multiplied by the combined
row-validity x token mask, summed and normalized GLOBALLY, so heterogeneous
group batch sizes are numerically exact.  ``make_serve_step`` builds the
one-token KV-cache decode step for the inference shapes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.hetero import masked_mean_loss
from repro.models.api import Model
from repro.optim.optimizers import Optimizer, OptState

PyTree = Any


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-token CE, numerically stable. logits (B,S,V) f32/bf16; labels (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - gold


def loss_fn(
    model: Model,
    params: PyTree,
    batch: Dict[str, jax.Array],
    *,
    aux_weight: float = 0.01,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Masked global-mean LM loss (+ router aux for MoE)."""
    kwargs = {}
    for k in ("frames", "patch_embeds"):
        if k in batch:
            kwargs[k] = batch[k]
    logits, aux = model.forward(params, batch["tokens"], **kwargs)
    # VLM: logits cover [patches | text]; score text positions only
    labels = batch["labels"]
    mask = batch["loss_mask"]
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, -labels.shape[1]:]
    ce = cross_entropy(logits, labels)
    loss = masked_mean_loss(ce, mask)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux, "tokens": jnp.sum(mask)}


def make_train_step(
    model: Model,
    optimizer: Optimizer,
    lr_schedule: Callable[[jax.Array], jax.Array],
    *,
    aux_weight: float = 0.01,
    grad_transform: Optional[Callable[[PyTree], PyTree]] = None,
) -> Callable:
    """Returns ``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``.

    ``grad_transform`` hooks the beyond-paper compressed/ring allreduce in
    (identity under plain pjit where XLA inserts the psum itself).
    """

    def train_step(params, opt_state: OptState, batch):
        (total, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch, aux_weight=aux_weight), has_aux=True
        )(params)
        if grad_transform is not None:
            grads = grad_transform(grads)
        lr = lr_schedule(opt_state.step)
        opt_state, params = optimizer.update(grads, opt_state, params, lr)
        # NOTE: elementwise square + sum, NOT vdot — vdot reshapes each leaf
        # to 1-D, which GSPMD can only partition by all-gathering the whole
        # (f32-upcast) tensor; measured at +4.5 GB/layer on qwen3-moe.
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads))
        )
        metrics = {
            "loss": parts["loss"],
            "aux": parts["aux"],
            "total": total,
            "lr": lr,
            "grad_norm": gnorm,
            "tokens": parts["tokens"],
        }
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model, *, aux_weight: float = 0.01) -> Callable:
    def eval_step(params, batch):
        _, parts = loss_fn(model, params, batch, aux_weight=aux_weight)
        return parts

    return eval_step


def make_serve_step(model: Model) -> Callable:
    """One-token decode: (params, token, cache, pos) -> (next_token, logits, cache)."""

    def serve_step(params, token, cache, pos):
        logits, cache = model.decode_step(params, token, cache, pos)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token[:, None], logits, cache

    return serve_step


def make_prefill_step(model: Model, cache_len: int) -> Callable:
    def prefill_step(params, tokens, **kwargs):
        return model.prefill(params, tokens, cache_len, **kwargs)

    return prefill_step
