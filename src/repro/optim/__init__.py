"""Optimizers and LR schedules (no external deps — built in JAX per the scope).

SGD-momentum (the paper trains with SGD) and AdamW (LM-standard), plus the
Goyal et al. accuracy-preserving schedule the paper cites: linear LR scaling
with global batch size + gradual warmup.
"""
from repro.optim.optimizers import OptState, adamw, sgd_momentum, Optimizer
from repro.optim.schedules import goyal_schedule, linear_scaled_lr, warmup_cosine

__all__ = [
    "OptState", "Optimizer", "adamw", "sgd_momentum",
    "goyal_schedule", "linear_scaled_lr", "warmup_cosine",
]
