"""SGD-momentum and AdamW as pure (state, grads) -> (state, updates) functions.

Shape-generic over pytrees; optimizer state carries the same logical axes as
the parameters so ZeRO-1 sharding of optimizer state falls out of the same
rule table (see :mod:`repro.distributed.sharding`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array          # () int32
    mu: PyTree               # first moment / momentum
    nu: Optional[PyTree]     # second moment (None for SGD)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """init(params) -> state;  update(grads, state, params, lr) -> (new_state, new_params)."""

    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree, jax.Array], Tuple[OptState, PyTree]]
    # how many extra param-sized buffers the state holds (for memory analysis)
    state_factor: int = 1


def _zeros_like_f32(params: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd_momentum(momentum: float = 0.9, nesterov: bool = False,
                 weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32), mu=_zeros_like_f32(params), nu=None)

    def update(grads, state, params, lr):
        def upd(g, m, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            m_new = momentum * m + g
            d = g + momentum * m_new if nesterov else m_new
            return m_new, (p.astype(jnp.float32) - lr * d).astype(p.dtype)

        flat = jax.tree_util.tree_map(upd, grads, state.mu, params)
        mu = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_p = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return OptState(step=state.step + 1, mu=mu, nu=None), new_p

    return Optimizer(init=init, update=update, state_factor=1)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=_zeros_like_f32(params),
            nu=_zeros_like_f32(params),
        )

    def update(grads, state, params, lr):
        t = (state.step + 1).astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mhat = m_new / c1
            vhat = v_new / c2
            step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return m_new, v_new, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        flat = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
        is_t = lambda x: isinstance(x, tuple)
        mu = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=is_t)
        nu = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=is_t)
        new_p = jax.tree_util.tree_map(lambda t: t[2], flat, is_leaf=is_t)
        return OptState(step=state.step + 1, mu=mu, nu=nu), new_p

    return Optimizer(init=init, update=update, state_factor=2)
