"""LR schedules.  The paper (§IV, citing Goyal et al. 2017) preserves accuracy
under distribution via (a) linear LR scaling with the global batch and (b) a
warmup that ramps from a low LR — both implemented here as pure step->lr fns.
"""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> lr


def linear_scaled_lr(base_lr: float, global_batch: int, base_batch: int = 256) -> float:
    """Goyal linear scaling rule: lr = base_lr * global_batch / base_batch."""
    return base_lr * global_batch / base_batch


def goyal_schedule(
    base_lr: float,
    global_batch: int,
    *,
    base_batch: int = 256,
    warmup_steps: int = 500,
    total_steps: int = 100_000,
    final_frac: float = 0.1,
) -> Schedule:
    """Warmup from base_lr -> scaled lr over ``warmup_steps`` (gradual warmup),
    then linear decay to ``final_frac`` of the scaled LR."""
    peak = linear_scaled_lr(base_lr, global_batch, base_batch)

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr + (peak - base_lr) * jnp.minimum(step / max(1, warmup_steps), 1.0)
        frac = jnp.clip(
            (step - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0
        )
        decay = peak * (1.0 - (1.0 - final_frac) * frac)
        return jnp.where(step < warmup_steps, warm, decay)

    return lr


def warmup_cosine(
    peak_lr: float, warmup_steps: int = 500, total_steps: int = 100_000,
    final_lr: float = 0.0,
) -> Schedule:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(1, warmup_steps), 1.0)
        t = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = final_lr + 0.5 * (peak_lr - final_lr) * (1 + jnp.cos(math.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr
