"""`MeshFeedDevice`: per-dp-group feeding onto a real `jax.sharding.Mesh`.

The first two backends hand the Session one host-side global batch that jit
then scatters — fine on one device, but it re-stages the whole batch through
device 0 on a real mesh.  This backend models what a rack of CSDs actually
does: each device assembles ITS dp-group's rows locally, and the host never
holds more than views; the global array is stitched together from
per-device shards via :func:`jax.make_array_from_single_device_arrays`
(the multi-host feeding idiom), already laid out along the mesh's ``data``
axis.

Per-host feeding is the PRIMARY path: :meth:`MeshFeeder.feed_addressable`
takes only the rows THIS host owns (plus their offset into the global
batch), slices them by the sharding's own index map restricted to the
**addressable** devices, and ``device_put``s exactly those pieces — nothing
else.  The global array is then assembled from the single-device shards
under ``jax.transfer_guard_host_to_device("disallow")``, which turns the
"no cross-host batch bytes" invariant into a runtime guarantee: any byte
that would need to move beyond the addressable puts is a hard error, and
the per-feed :class:`FeedReceipt` records exactly which devices received
how many bytes.  The single-process :meth:`MeshFeeder.feed` is now just
``feed_addressable`` over the full row window (offset 0).

Device ↔ mesh mapping: the global Stannis batch is ``(n_groups *
max_local, seq)`` group-major.  The feed splits those rows into
``data_axis_size`` contiguous chunks — one per mesh device along ``data`` —
so dp-group g's rows land on the mesh slice that computes group g.  In a
multi-process cluster the mesh is the :func:`~repro.launch.mesh.
make_cluster_mesh` contract (process-major device order), so a process's
addressable chunks are exactly its dp-groups' rows.

Sampling custody is inherited from :class:`SyntheticDevice` — mesh feeding
changes where batches *land*, never who may *read* a shard.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.compat import process_index as _process_index
from repro.storage.synthetic import SyntheticDevice


class MeshFeedDevice(SyntheticDevice):
    """Synthetic sampling + mesh-placed batch delivery (see module doc)."""

    backend = "meshfeed"


def data_axis_size(global_rows: int, n_devices: int) -> int:
    """Largest divisor of ``global_rows`` that fits the device count."""
    if global_rows <= 0:
        return 1
    for d in range(min(n_devices, global_rows), 0, -1):
        if global_rows % d == 0:
            return d
    return 1


@dataclasses.dataclass(frozen=True)
class FeedReceipt:
    """Byte-exact accounting of ONE per-host feed (the invariant's proof).

    ``bytes_put`` is every host->device byte this feed moved; every
    destination in ``devices`` is addressable by construction (the index
    map is restricted to addressable devices), and the global-array
    assembly that followed ran under a host->device transfer guard — so
    ``bytes_put`` is the TOTAL h2d traffic of the feed, and none of it
    crossed a process boundary.
    """

    rows_local: int                  # host rows this process supplied
    rows_global: int                 # rows of the assembled global batch
    bytes_put: int                   # h2d bytes actually moved (all keys)
    n_puts: int                      # device_put calls issued
    devices: Tuple[int, ...]         # destination device ids (addressable)
    process_index: int               # which process fed

    @property
    def local_fraction(self) -> float:
        return self.rows_local / max(1, self.rows_global)


class MeshFeeder:
    """Builds (and re-builds, when the row count changes across elastic
    events) the feed mesh, and lands host batches onto it per-shard.

    When a session's :class:`~repro.api.artifacts.ShardingPlan` is adopted
    (:meth:`adopt_shardings`), batches land with the PLAN's ``NamedSharding``
    per key — the layout the compiled step declares as ``in_shardings`` —
    instead of a locally re-derived one, so the feed and the step can never
    disagree about placement.  Stale plans (from before an elastic mesh
    resize) are detected by mesh mismatch and ignored until the session
    adopts the re-derived plan.

    In a cluster, ``adopt_shardings`` may also carry per-key LOCAL
    shardings (the hostsync compute layout over this process's mesh):
    :meth:`feed_addressable` then assembles the local view from the SAME
    single-device buffers whenever the two index maps agree — the local
    compute arrays literally are the global arrays' addressable shards,
    zero extra transfers.
    """

    def __init__(self, data_axis: Optional[int] = None):
        self._forced = data_axis
        self._mesh = None
        self._rows = None
        self._shardings: Dict[str, object] = {}
        self._local_shardings: Dict[str, object] = {}
        self._plan_rows: Optional[int] = None
        self.last_receipt: Optional[FeedReceipt] = None
        self.last_local: Optional[Dict[str, object]] = None

    def mesh_for(self, global_rows: int):
        import jax

        from repro.launch.mesh import make_host_mesh

        if self._mesh is None or self._rows != global_rows:
            d = self._forced or data_axis_size(global_rows, len(jax.devices()))
            if global_rows % d != 0:
                raise ValueError(
                    f"data axis {d} does not divide global_rows {global_rows}"
                )
            self._mesh = make_host_mesh(data=d, model=1)
            self._rows = global_rows
        return self._mesh

    @property
    def n_feed_devices(self) -> int:
        return 0 if self._mesh is None else int(self._mesh.shape["data"])

    def adopt_shardings(
        self,
        shardings: Dict[str, object],
        local: Optional[Dict[str, object]] = None,
        *,
        global_rows: Optional[int] = None,
    ) -> None:
        """Adopt a ShardingPlan's per-key batch ``NamedSharding``s (and, in a
        cluster, the local compute shardings the hostsync step consumes).

        ``global_rows`` records the row count the plan was resolved for:
        a feed of a DIFFERENT row count (mid-replan, before the session
        re-adopts) ignores the stale plan and falls back to a locally
        derived mesh, exactly like the pre-cluster behavior.
        """
        self._shardings = dict(shardings)
        self._local_shardings = dict(local) if local else {}
        self._plan_rows = global_rows
        if global_rows is not None and self._shardings:
            # the plan's mesh IS the feed mesh for that row count (in a
            # cluster it spans processes — never derivable from mesh_for)
            self._mesh = next(iter(self._shardings.values())).mesh
            self._rows = int(global_rows)

    def _sharding_for(self, key: str, v_shape, mesh):
        from jax.sharding import NamedSharding, PartitionSpec as P

        sharding = self._shardings.get(key)
        if sharding is None or sharding.mesh != mesh:
            # no (or stale) plan: default row sharding over ``data``
            sharding = NamedSharding(
                mesh, P("data", *([None] * (len(v_shape) - 1)))
            )
        return sharding

    def feed(self, batch: Dict[str, np.ndarray]) -> Dict:
        """Single-host delivery: the full row window, offset 0."""
        return self.feed_addressable(batch)

    def feed_addressable(
        self,
        batch: Dict[str, np.ndarray],
        *,
        row_offset: int = 0,
        global_rows: Optional[int] = None,
    ) -> Dict:
        """Place THIS host's rows onto its addressable mesh slice, per-shard.

        ``batch`` holds only the local rows; ``row_offset``/``global_rows``
        situate them in the global batch (defaults: the batch IS the global
        batch).  Every ``device_put`` destination comes from the sharding's
        own ``addressable_devices_indices_map`` — a non-addressable device
        can never appear — and the global arrays are assembled from the
        single-device shards under a host->device transfer guard, so the
        :class:`FeedReceipt` in ``last_receipt`` accounts for every h2d
        byte the feed moved.  Raises if the addressable slice reaches
        beyond the rows this host holds (custody/mesh misalignment).
        """
        import jax

        local_rows = next(iter(batch.values())).shape[0]
        R = global_rows if global_rows is not None else local_rows
        adopted_ok = bool(self._shardings) and self._plan_rows == R
        mesh = (
            next(iter(self._shardings.values())).mesh
            if adopted_ok else self.mesh_for(R)
        )
        out: Dict[str, jax.Array] = {}
        local_out: Dict[str, jax.Array] = {}
        bytes_put = 0
        n_puts = 0
        dev_ids = set()
        want_local = bool(self._local_shardings)
        for k, v in batch.items():
            gshape = (R,) + v.shape[1:]
            sharding = self._sharding_for(k, gshape, mesh)
            idx_map = sharding.addressable_devices_indices_map(gshape)
            pieces = {}
            for dev, idx in sorted(idx_map.items(), key=lambda kv: kv[0].id):
                rs = idx[0] if idx else slice(None)
                start = rs.start or 0
                stop = rs.stop if rs.stop is not None else R
                if start < row_offset or stop > row_offset + local_rows:
                    raise ValueError(
                        f"addressable slice [{start}:{stop}) of {k!r} falls "
                        f"outside this host's rows "
                        f"[{row_offset}:{row_offset + local_rows}) — feed "
                        f"mesh and shard custody disagree"
                    )
                piece = v[start - row_offset:stop - row_offset, ...]
                pieces[dev] = jax.device_put(piece, dev)
                bytes_put += piece.nbytes
                n_puts += 1
                dev_ids.add(dev.id)
            # assembly is zero-copy: prove it by disallowing further h2d
            with jax.transfer_guard_host_to_device("disallow"):
                out[k] = jax.make_array_from_single_device_arrays(
                    gshape, sharding, list(pieces.values())
                )
                if want_local:
                    local_out[k] = self._assemble_local(
                        k, v.shape, pieces, row_offset
                    )
        self.last_receipt = FeedReceipt(
            rows_local=int(local_rows),
            rows_global=int(R),
            bytes_put=int(bytes_put),
            n_puts=int(n_puts),
            devices=tuple(sorted(dev_ids)),
            process_index=_process_index(),
        )
        self.last_local = local_out if want_local else None
        return out

    def _assemble_local(self, key, local_shape, pieces, row_offset):
        """The LOCAL (hostsync compute) view over the same device buffers.

        Valid only when the local sharding's index map tiles the local rows
        with exactly the pieces the global feed already placed (same
        devices, same row chunks) — guaranteed by construction when the
        local mesh's ``data`` axis is the per-process share of the global
        one and both meshes enumerate this process's devices in id order.
        A mismatch raises (custody/mesh misalignment), it never silently
        moves extra bytes.
        """
        import jax

        lsh = self._local_shardings.get(key)
        if lsh is None:
            return None
        lshape = tuple(local_shape)
        lmap = lsh.addressable_devices_indices_map(lshape)
        shards = []
        for dev, idx in sorted(lmap.items(), key=lambda kv: kv[0].id):
            rs = idx[0] if idx else slice(None)
            start = (rs.start or 0) + row_offset
            stop = (rs.stop if rs.stop is not None else lshape[0]) + row_offset
            piece = pieces.get(dev)
            if piece is None or piece.shape[0] != stop - start:
                raise ValueError(
                    f"local sharding of {key!r} wants rows [{start}:{stop}) "
                    f"on {dev} but the global feed placed "
                    f"{None if piece is None else piece.shape} there"
                )
            shards.append(piece)
        return jax.make_array_from_single_device_arrays(lshape, lsh, shards)
