"""`MeshFeedDevice`: per-dp-group feeding onto a real `jax.sharding.Mesh`.

The first two backends hand the Session one host-side global batch that jit
then scatters — fine on one device, but it re-stages the whole batch through
device 0 on a real mesh.  This backend models what a rack of CSDs actually
does: each device assembles ITS dp-group's rows locally, and the host never
holds more than views; the global array is stitched together from
per-device shards via :func:`jax.make_array_from_single_device_arrays`
(the multi-host feeding idiom), already laid out along the mesh's ``data``
axis.  This wires :func:`repro.launch.mesh.make_host_mesh` into the
training path: ``Session.run()`` consumes batches that are *born sharded*.

Device ↔ mesh mapping: the global Stannis batch is ``(n_groups *
max_local, seq)`` group-major.  The feed splits those rows into
``data_axis_size`` contiguous chunks — one per mesh device along ``data`` —
so dp-group g's rows land on the mesh slice that computes group g.  The
``data`` axis is the largest divisor of ``global_rows`` that fits the
available devices (a 1-device CPU degrades to data=1 and stays correct,
which is how the unit-test process runs; the multi-device path is exercised
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

Sampling custody is inherited from :class:`SyntheticDevice` — mesh feeding
changes where batches *land*, never who may *read* a shard.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.storage.synthetic import SyntheticDevice


class MeshFeedDevice(SyntheticDevice):
    """Synthetic sampling + mesh-placed batch delivery (see module doc)."""

    backend = "meshfeed"


def data_axis_size(global_rows: int, n_devices: int) -> int:
    """Largest divisor of ``global_rows`` that fits the device count."""
    if global_rows <= 0:
        return 1
    for d in range(min(n_devices, global_rows), 0, -1):
        if global_rows % d == 0:
            return d
    return 1


class MeshFeeder:
    """Builds (and re-builds, when the row count changes across elastic
    events) the host mesh, and feeds host batches onto it per-shard.

    When a session's :class:`~repro.api.artifacts.ShardingPlan` is adopted
    (:meth:`adopt_shardings`), batches land with the PLAN's ``NamedSharding``
    per key — the layout the compiled step declares as ``in_shardings`` —
    instead of a locally re-derived one, so the feed and the step can never
    disagree about placement.  Stale plans (from before an elastic mesh
    resize) are detected by mesh mismatch and ignored until the session
    adopts the re-derived plan.
    """

    def __init__(self, data_axis: Optional[int] = None):
        self._forced = data_axis
        self._mesh = None
        self._rows = None
        self._shardings: Dict[str, object] = {}

    def mesh_for(self, global_rows: int):
        import jax

        from repro.launch.mesh import make_host_mesh

        if self._mesh is None or self._rows != global_rows:
            d = self._forced or data_axis_size(global_rows, len(jax.devices()))
            if global_rows % d != 0:
                raise ValueError(
                    f"data axis {d} does not divide global_rows {global_rows}"
                )
            self._mesh = make_host_mesh(data=d, model=1)
            self._rows = global_rows
        return self._mesh

    @property
    def n_feed_devices(self) -> int:
        return 0 if self._mesh is None else int(self._mesh.shape["data"])

    def adopt_shardings(self, shardings: Dict[str, object]) -> None:
        """Adopt a ShardingPlan's per-key batch ``NamedSharding``s."""
        self._shardings = dict(shardings)

    def feed(self, batch: Dict[str, np.ndarray]) -> Dict:
        """Place row-major host arrays onto the mesh, per-shard.

        Each mesh device receives only its own chunk (``device_put`` of a
        view, sliced by the sharding's own index map), then the global array
        is assembled from the single-device shards — no full-batch staging
        through device 0.
        """
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        rows = next(iter(batch.values())).shape[0]
        mesh = self.mesh_for(rows)
        out: Dict[str, jax.Array] = {}
        for k, v in batch.items():
            sharding = self._shardings.get(k)
            if sharding is None or sharding.mesh != mesh:
                # no (or stale) plan: default row sharding over ``data``
                sharding = NamedSharding(
                    mesh, P("data", *([None] * (v.ndim - 1)))
                )
            idx_map = sharding.addressable_devices_indices_map(v.shape)
            shards = [
                jax.device_put(v[idx], dev) for dev, idx in idx_map.items()
            ]
            out[k] = jax.make_array_from_single_device_arrays(
                v.shape, sharding, shards
            )
        return out
