"""`repro.storage` — computational storage as a first-class subsystem.

STANNIS's central claim is that training happens *inside* the storage
devices: private data never crosses the device boundary, public data is
shared deliberately, and the host *places work onto* devices rather than
reading bytes out of them.  This package is that device model:

    CSD (paper)            repro.storage (here)
    -------------------    ------------------------------------------
    NAND flash + shards    StorageDevice custody table (Shard set)
    ISP engine             in-device read()/assemble() sampling
    NVMe boundary          PermissionError custody guard
    rack of CSDs           DeviceFleet (worker id -> device registry)
    device failure         quarantine_workers: public re-homes,
                           private tombstones (CustodyEvent log)
    host DMA / fabric      FleetBatcher.next_device_batch delivery

Three interchangeable backends (select via ``StorageSpec`` /
``FleetSpec.with_storage``):

  * ``synthetic`` — deterministic in-silico corpus, zero setup (default).
  * ``flash``     — memory-mapped file-backed shards, bit-identical to
    synthetic; models the paper's flash medium.
  * ``meshfeed``  — per-dp-group buffers placed directly onto a
    ``jax.sharding.Mesh`` (batches are born sharded).

``Session`` pulls training batches through a :class:`FleetBatcher`, and all
elastic custody changes route through the fleet API — see
:mod:`repro.storage.fleet`.
"""
from repro.storage.device import BaseStorageDevice, StorageDevice
from repro.storage.flash import FlashDevice
from repro.storage.fleet import (
    BACKENDS, DeviceFleet, DeviceRecord, FleetBatcher, FleetManifest,
    StorageSpec, make_fleet_batcher, manifest_sources,
)
from repro.storage.meshfeed import (
    FeedReceipt, MeshFeedDevice, MeshFeeder, data_axis_size,
)
from repro.storage.synthetic import DataConfig, SyntheticDevice, synth_sequence

__all__ = [
    "BACKENDS",
    "BaseStorageDevice",
    "DataConfig",
    "DeviceFleet",
    "DeviceRecord",
    "FeedReceipt",
    "FlashDevice",
    "FleetBatcher",
    "FleetManifest",
    "MeshFeedDevice",
    "MeshFeeder",
    "StorageDevice",
    "StorageSpec",
    "SyntheticDevice",
    "data_axis_size",
    "make_fleet_batcher",
    "manifest_sources",
    "synth_sequence",
]
