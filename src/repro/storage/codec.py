"""Spool codecs: how :class:`~repro.storage.flash.FlashDevice` lays token
bytes on flash.

The paper's discipline is to move fewer bytes off the medium; for an LM
corpus the shard payload is *token ids*, and ids must survive the round
trip **bit-exactly** — the flash==synthetic identity is a custody invariant
(a lossy int8+scale scheme a la ``kernels/quantize.py`` would round ids to
the nearest multiple of ``(vocab-1)/127`` ≈ 8 tokens at vocab 1024, silently
corrupting the corpus).  So "int8 on disk" here is the *lossless* narrow
integer codec: ids fitting one byte are spooled as ``u8`` (4x fewer bytes
than the legacy ``i32`` layout), two-byte vocabularies as ``u16`` (2x), and
the device widens back to ``int32`` during ``assemble`` — the in-device
"dequantize" of the mmap read path.  ``auto`` picks the narrowest width the
vocabulary fits.

Codecs only change the bytes AT REST on the device's own flash; the
assembled batches are identical, so custody rules, quarantine shredding,
and cross-backend bit-identity all hold per codec (property-tested).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

# codec name -> on-disk dtype; "auto" resolves to the narrowest that fits
CODEC_DTYPES: Dict[str, np.dtype] = {
    "i32": np.dtype(np.int32),
    "u16": np.dtype(np.uint16),
    "u8": np.dtype(np.uint8),
}

CODECS = ("auto",) + tuple(CODEC_DTYPES)


def resolve_codec(codec: str, vocab: int) -> str:
    """Validate ``codec`` against ``vocab``; resolve ``auto`` to a width.

    Raises ``ValueError`` for an unknown codec or one too narrow to hold
    every id in ``[0, vocab)`` losslessly — corrupting ids is never an option.
    """
    if codec == "auto":
        if vocab <= 1 << 8:
            return "u8"
        if vocab <= 1 << 16:
            return "u16"
        return "i32"
    if codec not in CODEC_DTYPES:
        raise ValueError(f"unknown spool codec {codec!r}; choose from {CODECS}")
    limit = 1 << (8 * CODEC_DTYPES[codec].itemsize)
    if codec != "i32" and vocab > limit:
        raise ValueError(
            f"spool codec {codec!r} holds ids < {limit}, but vocab={vocab}; "
            "narrow spooling must stay lossless (use 'auto')"
        )
    return codec


def encode_rows(rows: np.ndarray, codec: str) -> np.ndarray:
    """int32 sample rows -> on-disk representation (checked, lossless)."""
    dt = CODEC_DTYPES[codec]
    if dt == rows.dtype:
        return rows
    info = np.iinfo(dt)
    if rows.min() < info.min or rows.max() > info.max:
        raise ValueError(
            f"token ids [{rows.min()}, {rows.max()}] overflow spool codec "
            f"{codec!r} — refusing lossy spool"
        )
    return rows.astype(dt)


def decode_rows(rows: np.ndarray) -> np.ndarray:
    """On-disk representation -> int32 rows (the in-device widen)."""
    return np.asarray(rows, np.int32)


def bytes_per_sample(codec: str, seq_len: int) -> int:
    """On-flash payload bytes for one ``(seq_len+1,)`` sample row."""
    return (seq_len + 1) * CODEC_DTYPES[codec].itemsize
