"""`FlashDevice`: memory-mapped file-backed shards (CSD ↔ NAND flash).

Models the paper's actual medium: each shard is a file of pre-tokenized
samples, and a read is an mmap page fetch, not a recompute.  The layout
mirrors the paper's custody rules:

  * **private shards** live under the owning device's own spool directory
    (``<root>/dev-<worker>/``) — its "flash".  Another device never even
    computes the path: the custody guard in
    :class:`~repro.storage.device.BaseStorageDevice` rejects the read first.
  * **public shards** live in a shared pool directory (``<root>/public/``)
    written once and mapped read-only by every device — the paper's
    host-distributed public data.

Files are spooled lazily on first touch, from the same deterministic
generator the synthetic backend uses, so flash and synthetic devices return
**bit-identical** samples for the same ``(seed, shard, index)`` — the
property test in ``tests/test_storage.py`` pins this, and it is what lets a
fleet mix backends (e.g. flash CSDs + a synthetic host) without changing
training math.

Quarantine is physical here: :meth:`FlashDevice.quarantine` unlinks the
shard file (shreds the dead worker's flash) in addition to the tombstone.

Spool width is pluggable (see :mod:`repro.storage.codec`): the default
``i32`` layout writes 4 bytes/token; ``u8``/``u16``/``auto`` spool narrow
integer ids (up to 4x fewer bytes at rest and through the mmap page reads)
and the device widens back to int32 during ``_materialize`` — assembled
batches are bit-identical across codecs AND backends.
"""
from __future__ import annotations

import os
import tempfile
from typing import Dict, Optional

import numpy as np

from repro.core.privacy import Shard
from repro.storage.codec import decode_rows, encode_rows, resolve_codec
from repro.storage.device import BaseStorageDevice
from repro.storage.synthetic import synth_sequence


def _safe(name: str) -> str:
    return name.replace("/", "_").replace(os.sep, "_")


class FlashDevice(BaseStorageDevice):
    """File-backed backend: one ``(n_samples, seq_len+1)`` memmap per shard
    (dtype per the spool codec), spooled lazily, read via mmap pages."""

    backend = "flash"

    def __init__(self, worker: str, cfg, root: Optional[str] = None,
                 codec: str = "i32"):
        super().__init__(worker, cfg)
        self.root = root or tempfile.mkdtemp(prefix="repro-flash-")
        self.codec = resolve_codec(codec, cfg.vocab)
        self._maps: Dict[str, np.memmap] = {}
        self.spooled_bytes = 0          # payload bytes THIS device wrote

    # -- layout -----------------------------------------------------------

    def _shard_path(self, shard: Shard) -> str:
        if shard.private:
            home = os.path.join(self.root, f"dev-{_safe(shard.owner)}")
        else:
            home = os.path.join(self.root, "public")
        # codec in the name: devices with different codecs never alias files
        return os.path.join(home, f"{_safe(shard.shard_id)}.{self.codec}")

    def _spool(self, shard: Shard, path: str) -> None:
        """Write the shard's full sample matrix; atomic rename so a shared
        public file is never observed half-written."""
        os.makedirs(os.path.dirname(path), exist_ok=True)
        S = self.cfg.seq_len + 1
        from repro.storage.codec import CODEC_DTYPES

        dt = CODEC_DTYPES[self.codec]
        tmp = path + f".tmp-{os.getpid()}-{_safe(self.worker)}"
        arr = np.lib.format.open_memmap(
            tmp, mode="w+", dtype=dt, shape=(shard.n_samples, S)
        )
        for i in range(shard.n_samples):
            arr[i] = encode_rows(synth_sequence(self.cfg, shard.shard_id, i),
                                 self.codec)
        arr.flush()
        self.spooled_bytes += arr.nbytes
        del arr
        os.replace(tmp, path)

    def _map(self, shard: Shard) -> np.memmap:
        m = self._maps.get(shard.shard_id)
        if m is None:
            path = self._shard_path(shard)
            if not os.path.exists(path):
                self._spool(shard, path)
            m = np.load(path, mmap_mode="r")
            self._maps[shard.shard_id] = m
        return m

    # -- device hooks -----------------------------------------------------

    def _materialize(self, shard: Shard, index: int) -> np.ndarray:
        m = self._map(shard)
        # in-device widen: narrow spool bytes never leave the device raw
        return decode_rows(m[index % m.shape[0]])

    def evict(self, shard_id: str) -> None:
        self._maps.pop(shard_id, None)
        super().evict(shard_id)

    def quarantine(self, shard_id: str) -> None:
        shard = self._shards.get(shard_id)
        self._maps.pop(shard_id, None)
        if shard is not None and shard.private and shard.owner == self.worker:
            # shred the dead device's flash: the bytes cease to exist
            try:
                os.remove(self._shard_path(shard))
            except OSError:
                pass
        super().quarantine(shard_id)

    def close(self) -> None:
        self._maps.clear()
