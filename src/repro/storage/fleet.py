"""`DeviceFleet`: the registry mapping dp-group workers onto storage devices.

The paper's rack is a *fleet of CSDs*: the host places work onto devices,
each device computes over its own flash, and membership changes (a CSD dies,
a replacement arrives) are custody events, not data copies.  This module is
that control plane:

  * :class:`StorageSpec` — declarative backend selection (``synthetic`` /
    ``flash`` / ``meshfeed``), carried by ``FleetSpec`` so one line switches
    the entire data plane.
  * :class:`DeviceFleet` — worker-id → :class:`StorageDevice` registry with
    the custody API: ``provision_worker`` (WorkerJoined), ``quarantine_workers``
    (WorkerLost: public shards re-home to survivors, private shards are
    tombstoned fleet-wide), and an auditable
    :class:`~repro.core.privacy.CustodyEvent` log checked by
    :func:`~repro.core.privacy.audit_custody`.
  * :class:`FleetBatcher` — the batch iterator ``Session.run()`` pulls from:
    each dp-group's rows are assembled *in its device* and stitched into the
    Stannis masked global batch; ``next_device_batch`` lands it on the
    accelerator (host transfer for the first two backends, per-shard mesh
    feeding for ``meshfeed``).
  * :class:`FleetManifest` — what ``Session.place()`` returns: the core
    privacy :class:`~repro.core.privacy.PlacementManifest` plus per-device
    custody records, so placement is auditable down to the device.
"""
from __future__ import annotations

import dataclasses
import tempfile
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core.hetero import BatchSchedule
from repro.core.privacy import CustodyEvent, PlacementManifest, Shard
from repro.core.topology import ProcessMap
from repro.storage.device import BaseStorageDevice, StorageDevice
from repro.storage.flash import FlashDevice
from repro.storage.meshfeed import MeshFeedDevice, MeshFeeder
from repro.storage.synthetic import DataConfig, SyntheticDevice

BACKENDS: Dict[str, Type[BaseStorageDevice]] = {
    "synthetic": SyntheticDevice,
    "flash": FlashDevice,
    "meshfeed": MeshFeedDevice,
}


@dataclasses.dataclass(frozen=True)
class StorageSpec:
    """Declarative storage selection: which backend, and its knobs.

    ``root`` is the flash spool directory (a fresh tempdir when omitted);
    ``data_axis`` pins the meshfeed mesh's ``data`` axis (auto-sized to the
    largest divisor of the global row count otherwise); ``codec`` is the
    flash spool width (``i32`` legacy, ``u8``/``u16``/``auto`` narrow — see
    :mod:`repro.storage.codec`; ignored by the in-memory backends).
    """

    backend: str = "synthetic"
    root: Optional[str] = None
    data_axis: Optional[int] = None
    codec: str = "i32"

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown storage backend {self.backend!r}; "
                f"choose from {sorted(BACKENDS)}"
            )
        from repro.storage.codec import CODECS

        if self.codec not in CODECS:
            raise ValueError(
                f"unknown spool codec {self.codec!r}; choose from {CODECS}"
            )


@dataclasses.dataclass(frozen=True)
class DeviceRecord:
    """One device's custody summary inside a :class:`FleetManifest`.

    ``process`` is the worker PROCESS that owns the device in a cluster
    (0 single-process).  A record whose backend is ``"remote"`` describes a
    device provisioned by ANOTHER process: this process knows it exists
    (the manifest is the shared placement contract) but holds no custody
    for it — its shard bytes never enter this process.
    """

    worker: str
    backend: str
    custody: Tuple[str, ...]       # shard ids this device is custodian of
    n_samples: int                 # total samples under custody
    process: int = 0


@dataclasses.dataclass(frozen=True)
class FleetManifest(PlacementManifest):
    """Fleet-aware placement: core assignments + per-device custody.

    Process-aware in cluster mode: ``n_processes`` / ``local_process``
    situate the manifest, :meth:`local_devices` /
    :meth:`devices_of_process` split the records by owner process.
    """

    devices: Tuple[DeviceRecord, ...] = ()
    backend: str = "synthetic"
    quarantined: Tuple[str, ...] = ()
    n_processes: int = 1
    local_process: int = 0

    def device_for(self, worker: str) -> Optional[DeviceRecord]:
        for d in self.devices:
            if d.worker == worker:
                return d
        return None

    def devices_of_process(self, process: int) -> Tuple[DeviceRecord, ...]:
        return tuple(d for d in self.devices if d.process == process)

    def local_devices(self) -> Tuple[DeviceRecord, ...]:
        """Records this process actually provisioned (never ``remote``)."""
        return tuple(
            d for d in self.devices
            if d.process == self.local_process and d.backend != "remote"
        )


class DeviceFleet:
    """Worker-id-keyed registry of storage devices (see module docstring)."""

    def __init__(
        self,
        cfg: DataConfig,
        spec: Optional[StorageSpec] = None,
        *,
        process_map: Optional[ProcessMap] = None,
        process_id: int = 0,
    ):
        self.cfg = cfg
        self.spec = spec or StorageSpec()
        self._devices: Dict[str, BaseStorageDevice] = {}
        self._remote: Dict[str, int] = {}           # worker -> owner process
        self._shards: Dict[str, Shard] = {}
        self._custody: Dict[str, str] = {}          # shard_id -> custodian
        self.quarantined: set = set()
        self.custody_log: List[CustodyEvent] = []
        self.process_map = process_map
        self.process_id = int(process_id)
        self._flash_root = (
            (self.spec.root or tempfile.mkdtemp(prefix="repro-flash-"))
            if self.spec.backend == "flash" else None
        )
        self._feeder = (
            MeshFeeder(self.spec.data_axis)
            if self.spec.backend == "meshfeed" else None
        )

    # -- construction ------------------------------------------------------

    @classmethod
    def provision(
        cls,
        workers: Sequence[str],
        shards: Sequence[Shard],
        cfg: DataConfig,
        spec: Optional[StorageSpec] = None,
        *,
        process_map: Optional[ProcessMap] = None,
        process_id: int = 0,
    ) -> "DeviceFleet":
        fleet = cls(cfg, spec, process_map=process_map, process_id=process_id)
        for s in shards:
            fleet.register_shard(s)
        for w in workers:
            fleet.provision_worker(w)
        return fleet

    def is_local(self, worker: str) -> bool:
        """Does THIS process own ``worker``'s storage device?

        Always True single-process.  A worker unknown to the process map
        (joined after the map was built) defaults to local — the elastic
        controller that applies joins holds the full view.
        """
        if self.process_map is None:
            return True
        try:
            return self.process_map.process_of(worker) == self.process_id
        except ValueError:
            return True

    def register_shard(self, shard: Shard) -> None:
        self._shards[shard.shard_id] = shard
        for dev in self._devices.values():
            dev.adopt(shard)

    def _make_device(self, worker: str) -> BaseStorageDevice:
        klass = BACKENDS[self.spec.backend]
        if klass is FlashDevice:
            return FlashDevice(worker, self.cfg, root=self._flash_root,
                               codec=self.spec.codec)
        return klass(worker, self.cfg)

    def provision_worker(self, worker: str) -> Optional[StorageDevice]:
        """WorkerJoined: bring up a fresh device holding the live shard set.

        In a cluster, a worker owned by ANOTHER process gets a remote
        record only — its shard bytes never enter this process (the
        addressable-custody half of the no-cross-host invariant)."""
        if worker in self._devices:
            return self._devices[worker]
        if not self.is_local(worker):
            self._remote[worker] = self.process_map.process_of(worker)
            return None
        self._remote.pop(worker, None)
        dev = self._make_device(worker)
        dev.provision(list(self._shards.values()))
        for sid in self.quarantined:
            dev.quarantine(sid)       # tombstones propagate to late joiners
        self._devices[worker] = dev
        for s in self._shards.values():
            mine = s.private and s.owner == worker
            orphan_public = not s.private and (
                self._custody.get(s.shard_id) not in self._devices
            )
            if mine or orphan_public:
                self._custody[s.shard_id] = worker
                self.custody_log.append(CustodyEvent(
                    "provision", s.shard_id, s.private, dst=worker,
                ))
        return dev

    # -- custody changes (the ONE re-homing path) --------------------------

    def quarantine_workers(self, dead: Sequence[str]) -> Tuple[str, ...]:
        """WorkerLost: decommission devices; re-home public custody to
        survivors; tombstone the dead workers' private shards fleet-wide.

        Returns the quarantined (dropped) private shard ids.
        """
        dead_set = set(dead)
        dead_devices: Dict[str, BaseStorageDevice] = {}
        for w in dead_set:
            self._remote.pop(w, None)
            dev = self._devices.pop(w, None)
            if dev is not None:
                dead_devices[w] = dev
        survivors = [w for w in self._devices]
        dropped: List[str] = []
        for s in list(self._shards.values()):
            holder = self._custody.get(s.shard_id)
            if s.private and s.owner in dead_set:
                # privacy constraint: nobody else may ever read these bytes.
                # The owner's device quarantines FIRST — for flash that
                # shreds the file — then every survivor gets the tombstone.
                owner_dev = dead_devices.get(s.owner)
                if owner_dev is not None:
                    owner_dev.quarantine(s.shard_id)
                for dev in self._devices.values():
                    dev.quarantine(s.shard_id)
                del self._shards[s.shard_id]
                self._custody.pop(s.shard_id, None)
                self.quarantined.add(s.shard_id)
                dropped.append(s.shard_id)
                self.custody_log.append(CustodyEvent(
                    "quarantine", s.shard_id, True, src=s.owner,
                ))
            elif not s.private and holder in dead_set and survivors:
                # public custody moves: cheapest-loaded survivor takes over
                new_home = min(
                    survivors,
                    key=lambda w: sum(
                        1 for c in self._custody.values() if c == w
                    ),
                )
                self._custody[s.shard_id] = new_home
                self._devices[new_home].adopt(s)
                self.custody_log.append(CustodyEvent(
                    "rehome", s.shard_id, False, src=holder, dst=new_home,
                ))
        for dev in dead_devices.values():
            dev.close()
        return tuple(dropped)

    # -- access ------------------------------------------------------------

    @property
    def workers(self) -> Tuple[str, ...]:
        return tuple(self._devices)

    @property
    def backend(self) -> str:
        return self.spec.backend

    def device(self, worker: str) -> BaseStorageDevice:
        try:
            return self._devices[worker]
        except KeyError:
            raise KeyError(f"no storage device for worker {worker!r}") from None

    def __iter__(self) -> Iterator[BaseStorageDevice]:
        return iter(self._devices.values())

    def __len__(self) -> int:
        return len(self._devices)

    def custodian(self, shard_id: str) -> Optional[str]:
        return self._custody.get(shard_id)

    @property
    def shards(self) -> Tuple[Shard, ...]:
        return tuple(self._shards.values())

    # -- manifest / delivery ------------------------------------------------

    def manifest(self, core: PlacementManifest) -> FleetManifest:
        """Wrap the core privacy manifest with per-device custody records.

        Process-aware: locally provisioned devices carry their owner
        process and real custody; workers owned by other processes appear
        as ``remote`` records with empty custody — this process can audit
        the full placement without ever holding the bytes."""
        pmap, pid = self.process_map, self.process_id
        records = []
        for w, dev in self._devices.items():
            owned = sorted(
                sid for sid, c in self._custody.items() if c == w
            )
            records.append(DeviceRecord(
                worker=w, backend=dev.backend, custody=tuple(owned),
                n_samples=sum(self._shards[s].n_samples for s in owned),
                process=pid if pmap else 0,
            ))
        for w, proc in sorted(self._remote.items()):
            records.append(DeviceRecord(
                worker=w, backend="remote", custody=(), n_samples=0,
                process=proc,
            ))
        return FleetManifest(
            assignments=core.assignments,
            devices=tuple(records),
            backend=self.spec.backend,
            quarantined=tuple(sorted(self.quarantined)),
            n_processes=pmap.n_processes if pmap else 1,
            local_process=pid,
        )

    def adopt_plan(self, plan, local_plan=None) -> None:
        """Hand a session's :class:`~repro.api.artifacts.ShardingPlan` to the
        data plane: the meshfeed backend lands every batch key with the
        plan's ``NamedSharding`` (the exact layout the compiled step declares
        as ``in_shardings``).  ``local_plan`` is the hostsync compute plan of
        a cluster worker — when given, every feed also assembles the local
        view over the same device buffers.  Host-delivery backends ignore
        both — their arrays are resharded by jit against the plan's 1x1
        mesh."""
        if self._feeder is not None:
            self._feeder.adopt_shardings(
                plan.batch,
                local=None if local_plan is None else local_plan.batch,
                global_rows=plan.global_rows,
            )

    @property
    def last_receipt(self):
        """The :class:`~repro.storage.meshfeed.FeedReceipt` of the most
        recent feed (None before the first, or for host-delivery backends)."""
        return self._feeder.last_receipt if self._feeder is not None else None

    def to_device_batch(
        self,
        batch: Dict[str, np.ndarray],
        *,
        row_span: Optional[Tuple[int, int]] = None,
    ) -> Dict:
        """Land host arrays on the accelerator, backend-appropriately.

        ``row_span`` is this process's [start, stop) window of the global
        batch (cluster mode): only those rows are sliced out and fed through
        :meth:`MeshFeeder.feed_addressable` — the rest of ``batch`` is never
        transferred.  When a local (hostsync) plan was adopted the LOCAL
        view is returned — the compute arrays the partial-gradient step
        consumes, assembled over the same buffers as the global contract.
        """
        if self._feeder is not None:
            if row_span is not None:
                start, stop = row_span
                rows = next(iter(batch.values())).shape[0]
                local = {k: v[start:stop] for k, v in batch.items()}
                out = self._feeder.feed_addressable(
                    local, row_offset=start, global_rows=rows,
                )
            else:
                out = self._feeder.feed(batch)
            if self._feeder.last_local:
                return self._feeder.last_local
            return out
        import jax.numpy as jnp

        return {k: jnp.asarray(v) for k, v in batch.items()}

    @property
    def mesh(self):
        """The live meshfeed mesh (None for host-delivery backends)."""
        return self._feeder._mesh if self._feeder is not None else None

    def feed_mesh(self, global_rows: int):
        """The mesh that batches of ``global_rows`` will land on (building
        or re-building it now), or None for host-delivery backends.  Elastic
        events change the row count, which can change the mesh — callers
        re-home model state onto it before stepping."""
        if self._feeder is None:
            return None
        return self._feeder.mesh_for(global_rows)


# ---------------------------------------------------------------------------
# The Stannis batch iterator over a device fleet
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetBatcher:
    """Batch iterator over the Stannis masked layout, fed by the fleet.

    groups: list of (worker_id, batch_size, [(shard_id, n_samples), ...]).
    Yields dicts: tokens (R, S) int32, labels (R, S) int32,
    loss_mask (R, S) f32 with invalid rows zeroed, row_mask (R,) f32.
    Each dp-group's rows are assembled by ITS device (in-device batch
    assembly); the host only concatenates finished rows.
    """

    cfg: DataConfig
    schedule: BatchSchedule
    group_workers: List[str]
    group_sources: Dict[str, List[Tuple[str, int]]]   # worker -> shard draws
    fleet: DeviceFleet

    def __post_init__(self):
        self._cursor: Dict[str, int] = {w: 0 for w in self.group_workers}
        # flatten each worker's sample space: (shard_id, index) pairs
        self._space: Dict[str, List[Tuple[str, int]]] = {}
        for w in self.group_workers:
            pairs: List[Tuple[str, int]] = []
            for shard_id, n in self.group_sources.get(w, []):
                pairs.extend((shard_id, i) for i in range(n))
            self._space[w] = pairs

    def rewire(
        self,
        schedule: BatchSchedule,
        group_sources: Dict[str, List[Tuple[str, int]]],
    ) -> None:
        """Re-point the iterator at a re-planned schedule + placement while
        preserving per-worker epoch cursors (an online re-tune must not
        replay already-seen samples)."""
        cursors = dict(self._cursor)
        self.schedule = schedule
        self.group_sources = group_sources
        self.__post_init__()
        for w, c in cursors.items():
            if w in self._cursor and self._space[w]:
                self._cursor[w] = c % len(self._space[w])

    def cursors(self) -> Dict[str, int]:
        """Per-worker epoch positions (checkpoint metadata: a restore must
        resume the SAMPLING state too, or it replays already-seen data)."""
        return dict(self._cursor)

    def set_cursors(self, cursors: Dict[str, int]) -> None:
        """Fast-forward epoch positions (from checkpoint metadata)."""
        for w, c in cursors.items():
            if w in self._cursor and self._space[w]:
                self._cursor[w] = int(c) % len(self._space[w])

    def steps_per_epoch(self) -> int:
        counts = [
            len(self._space[w]) // max(1, b)
            for w, b in zip(self.group_workers, self.schedule.group_batches)
            if b > 0
        ]
        return min(counts) if counts else 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    def local_row_span(self) -> Optional[Tuple[int, int]]:
        """This process's [start, stop) rows of the global batch, or None
        single-process (the whole batch is local)."""
        pmap = self.fleet.process_map
        if pmap is None:
            return None
        return pmap.row_span(self.fleet.process_id, self.schedule.max_local)

    def next_batch(self) -> Dict[str, np.ndarray]:
        """One global-layout host batch; only LOCAL groups' rows are
        assembled (each by its own storage device).  Remote groups' rows
        stay zero — their bytes live in another process and never enter
        this one; every cursor still advances, so all processes agree on
        the epoch position of every group."""
        R = self.schedule.global_rows
        S = self.cfg.seq_len
        ml = self.schedule.max_local
        tokens = np.zeros((R, S + 1), np.int32)
        row_mask = self.schedule.row_mask()
        for g, (w, b) in enumerate(
            zip(self.group_workers, self.schedule.group_batches)
        ):
            space = self._space[w]
            cur = self._cursor[w]
            self._cursor[w] = (cur + b) % max(1, len(space))
            if not self.fleet.is_local(w):
                continue
            draws = [
                space[(cur + r) % max(1, len(space))] for r in range(b)
            ]
            if draws:
                tokens[g * ml:g * ml + b] = self.fleet.device(w).assemble(draws)
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
            "loss_mask": row_mask[:, None] * np.ones((1, S), np.float32),
            "row_mask": row_mask,
        }

    def next_device_batch(self) -> Dict:
        """One step's batch, already landed where the step function wants it
        (mesh-sharded for the meshfeed backend, plain device arrays else;
        per-host addressable slices only, in a cluster)."""
        b = self.next_batch()
        return self.fleet.to_device_batch(
            {k: b[k] for k in ("tokens", "labels", "loss_mask")},
            row_span=self.local_row_span(),
        )


def manifest_sources(
    manifest: PlacementManifest, group_workers: List[str]
) -> Dict[str, List[Tuple[str, int]]]:
    """Per-worker (shard_id, n_samples) draws from a placement manifest."""
    sources: Dict[str, List[Tuple[str, int]]] = {w: [] for w in group_workers}
    for a in manifest.assignments:
        if a.worker in sources:
            sources[a.worker].append((a.shard_id, a.n_samples))
    return sources


def make_fleet_batcher(
    cfg: DataConfig,
    schedule: BatchSchedule,
    group_workers: List[str],
    manifest: PlacementManifest,
    fleet: DeviceFleet,
) -> FleetBatcher:
    """Wire the Eq.1 plan + privacy manifest into a fleet-fed iterator."""
    return FleetBatcher(
        cfg=cfg, schedule=schedule, group_workers=group_workers,
        group_sources=manifest_sources(manifest, group_workers), fleet=fleet,
    )
