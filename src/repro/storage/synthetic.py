"""`SyntheticDevice`: the deterministic in-silico corpus (CSD ↔ PRNG).

Plays the role of the paper's TinyImageNet-on-flash without any bytes on
disk: sample ``i`` of shard ``s`` is a pure function of ``(seed, s, i)``, so
any device reproduces ITS shards bit-exactly with zero cross-device I/O —
the in-storage property, minus the flash.  Sequences are Zipf-distributed
token ids with a linear-congruential position mix so the LM loss actually
decreases during the end-to-end example runs.

This module owns the canonical :class:`DataConfig` and
:func:`synth_sequence`.  :class:`~repro.storage.flash.FlashDevice` spools
exactly these samples onto memory-mapped files, which is what makes the two
backends bit-identical (property-tested in ``tests/test_storage.py``).
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.core.privacy import Shard
from repro.storage.device import BaseStorageDevice


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2      # token unigram skew


def _mix(*vals: int) -> np.random.Generator:
    return np.random.default_rng(np.array(vals, np.uint64))


def synth_sequence(cfg: DataConfig, shard_id: str, index: int) -> np.ndarray:
    """Deterministic (seed, shard, index) -> (seq_len+1,) int32 token ids.

    Zipf unigram + LCG positional drift gives learnable low-entropy structure.
    """
    # crc32 (not hash()): stable across processes — workers must agree bit-exactly
    h = zlib.crc32(shard_id.encode()) & 0x7FFFFFFF
    rng = _mix(cfg.seed, h, index)
    z = rng.zipf(cfg.zipf_a, size=cfg.seq_len + 1).astype(np.int64)
    base = z % max(2, cfg.vocab // 4)
    drift = (np.arange(cfg.seq_len + 1, dtype=np.int64) * (h % 97 + 1)) % 13
    return ((base + drift) % cfg.vocab).astype(np.int32)


class SyntheticDevice(BaseStorageDevice):
    """Deterministic generator backend — the default, zero-setup device."""

    backend = "synthetic"

    def _materialize(self, shard: Shard, index: int) -> np.ndarray:
        return synth_sequence(self.cfg, shard.shard_id, index)
