"""`StorageDevice`: the computational-storage abstraction (paper C1/C3).

STANNIS trains *inside* the storage devices: each Newport CSD holds shards
of the corpus on its flash, its ISP engine is the only compute that may
touch them, and private shards never cross the NVMe boundary.  A
``StorageDevice`` is this repo's software model of one such device:

  * **custody** — the device holds a table of shards it may serve: its own
    private shards plus the public pool.  A read of a private shard it does
    not own raises ``PermissionError`` (the host-side analogue of "the bytes
    physically cannot leave the flash").
  * **in-device sampling** — ``read(shard_id, index)`` materializes one
    sample *on the device*; ``assemble(draws)`` builds a whole per-dp-group
    batch without any sample crossing a device boundary.
  * **quarantine** — when a device's worker dies, its private shards are
    tombstoned fleet-wide (:meth:`quarantine`): even stale readers get a
    ``PermissionError``, never bytes.

Backends subclass :class:`BaseStorageDevice` and implement a single hook,
``_materialize(shard, index)``.  The custody guard runs *before* the hook,
so no backend can leak a private sample by construction.  See
:mod:`repro.storage.synthetic`, :mod:`repro.storage.flash`, and
:mod:`repro.storage.meshfeed` for the three shipped backends, and
:mod:`repro.storage.fleet` for the registry that maps CSDs onto dp-group
workers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from repro.core.privacy import Shard


class StorageDevice(Protocol):
    """What the fleet and batcher require of a storage backend."""

    worker: str
    backend: str

    def provision(self, shards: Sequence[Shard]) -> None: ...
    def read(self, shard_id: str, index: int) -> np.ndarray: ...
    def assemble(self, draws: Sequence[Tuple[str, int]]) -> np.ndarray: ...
    def holdings(self) -> Tuple[Shard, ...]: ...
    def adopt(self, shard: Shard) -> None: ...
    def evict(self, shard_id: str) -> None: ...
    def quarantine(self, shard_id: str) -> None: ...


class BaseStorageDevice:
    """Custody bookkeeping shared by every backend.

    Subclasses set ``backend`` and implement ``_materialize(shard, index) ->
    (seq_len+1,) int32`` — called only after the custody guard passed.
    """

    backend = "abstract"

    def __init__(self, worker: str, cfg):
        self.worker = worker
        self.cfg = cfg                      # DataConfig: sample geometry
        self._shards: Dict[str, Shard] = {}
        self._quarantined: set = set()

    # -- custody ----------------------------------------------------------

    def provision(self, shards: Sequence[Shard]) -> None:
        """Install the device's shard table (its privates + the public pool)."""
        for s in shards:
            self.adopt(s)

    def adopt(self, shard: Shard) -> None:
        self._shards[shard.shard_id] = shard
        self._quarantined.discard(shard.shard_id)

    def evict(self, shard_id: str) -> None:
        self._shards.pop(shard_id, None)

    def quarantine(self, shard_id: str) -> None:
        """Tombstone: the shard's owner died; reads must fail loudly forever
        (a silent KeyError would let a caller mistake 'gone' for 'unknown')."""
        self._shards.pop(shard_id, None)
        self._quarantined.add(shard_id)

    def holdings(self) -> Tuple[Shard, ...]:
        return tuple(self._shards.values())

    def _guard(self, shard_id: str) -> Shard:
        if shard_id in self._quarantined:
            raise PermissionError(
                f"shard {shard_id!r} is quarantined (its owner left the "
                f"fleet); private data dies with its device"
            )
        try:
            s = self._shards[shard_id]
        except KeyError:
            raise KeyError(
                f"device {self.worker!r} holds no shard {shard_id!r}"
            ) from None
        if s.private and s.owner != self.worker:
            raise PermissionError(
                f"device {self.worker!r} cannot read private shard "
                f"{shard_id!r} (owner {s.owner!r})"
            )
        return s

    # -- in-device sampling ----------------------------------------------

    def read(self, shard_id: str, index: int) -> np.ndarray:
        """One custody-checked sample: (seq_len+1,) int32 token ids."""
        return self._materialize(self._guard(shard_id), index)

    def assemble(self, draws: Sequence[Tuple[str, int]]) -> np.ndarray:
        """In-device batch assembly: (len(draws), seq_len+1) int32.

        The whole dp-group batch is built on the device; only the finished
        rows leave it (the paper's ISP engine streaming activations, not
        raw flash pages).
        """
        S = self.cfg.seq_len + 1
        out = np.zeros((len(draws), S), np.int32)
        for r, (shard_id, idx) in enumerate(draws):
            out[r] = self.read(shard_id, idx)
        return out

    def _materialize(self, shard: Shard, index: int) -> np.ndarray:
        raise NotImplementedError

    def close(self) -> None:
        """Release backend resources (files, maps); default no-op."""

    def __repr__(self) -> str:
        return (f"<{type(self).__name__} worker={self.worker!r} "
                f"shards={len(self._shards)}>")
