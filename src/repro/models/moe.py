"""Mixture-of-Experts decoder LM (dbrx-132b: 16e top-4; qwen3-moe-30b-a3b: 128e top-8).

Expert parallelism: expert weights carry the ``experts`` logical axis which the
sharding rules map onto the ``model`` mesh axis.  Token dispatch uses the
sort-by-expert + capacity layout (MaxText/GShard style, but with gather/scatter
instead of one-hot einsum so memory is O(E·C·d) not O(T·E·C)); under pjit the
scatter from token-sharded activations into expert-sharded buffers lowers to an
all-to-all.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import with_logical_constraint as wlc
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.param import ParamBuilder, build, scaled_init, stacked

PyTree = Any


# ---------------------------------------------------------------------------
# MoE layer
# ---------------------------------------------------------------------------


def init_moe_mlp(b, name: str, d_model: int, d_ff: int, n_experts: int):
    s = b.scope(name)
    s.param("router", (d_model, n_experts), ("embed", "experts"), init=scaled_init(0))
    s.param("wi_gate", (n_experts, d_model, d_ff),
            ("experts", "embed", "expert_mlp"), init=scaled_init(-2))
    s.param("wi_up", (n_experts, d_model, d_ff),
            ("experts", "embed", "expert_mlp"), init=scaled_init(-2))
    s.param("wo", (n_experts, d_ff, d_model),
            ("experts", "expert_mlp", "embed"), init=scaled_init(-2))


def expert_capacity(n_tokens: int, n_experts: int, k: int, capacity_factor: float) -> int:
    c = int(n_tokens * k * capacity_factor / n_experts)
    return max(8, ((c + 127) // 128) * 128)  # MXU-aligned


# Dispatch implementation: "auto" picks the shard_map group-local path when a
# mesh with a >1 "model" axis is active (the production EP path), else the
# fused Pallas dispatch+expert-GEMM kernel when cfg.fused_moe; "fused" /
# "dense" force the single-program fused-kernel / gather-scatter paths (the
# perf A/B baselines).  Env REPRO_MOE_IMPL overrides.
import os as _os

MOE_IMPL = _os.environ.get("REPRO_MOE_IMPL", "auto")


def moe_mlp(p: Dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss); dispatches on MOE_IMPL."""
    impl = MOE_IMPL
    if impl == "auto":
        from repro.compat import get_abstract_mesh

        mesh = get_abstract_mesh()
        if (
            mesh is not None
            and "model" in mesh.axis_names and mesh.shape["model"] > 1
            and cfg.n_experts % mesh.shape["model"] == 0
        ):
            return _moe_mlp_local(p, x, cfg, mesh)
        impl = "fused" if cfg.fused_moe else "dense"
    if impl == "fused":
        return _moe_mlp_fused(p, x, cfg)
    return _moe_mlp_dense(p, x, cfg)


def _moe_mlp_fused(p: Dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Single-program path through the fused Pallas kernel.

    Same routing/capacity math as :func:`_moe_mlp_dense` (parity-tested, incl.
    capacity overflow), but the dispatch gather, capacity masking, expert
    SwiGLU, and gate scaling run in one kernel — the (T·k, d) token-copy
    tensor and the g/u/h intermediates never round-trip HBM.  Backward
    recomputes through the ref oracle (see kernels/ops.py).
    """
    from repro.kernels import ops as kops

    B, S, d = x.shape
    T = B * S
    C = expert_capacity(T, cfg.n_experts, cfg.experts_per_token, cfg.capacity_factor)
    out, aux = kops.fused_moe_mlp(
        x.reshape(T, d), p["router"], p["wi_gate"], p["wi_up"], p["wo"],
        k=cfg.experts_per_token, capacity=C,
        interpret=L.FLAGS.pallas_interpret,
    )
    out = wlc(out.reshape(B, S, d), "batch", "seq", "act_embed")
    return out, aux


def _moe_mlp_local(
    p: Dict, x: jax.Array, cfg: ModelConfig, mesh
) -> Tuple[jax.Array, jax.Array]:
    """Group-local EP dispatch (GShard grouped capacity), zero all-to-all.

    Layout: token groups = dp shards (("pod","data") slices of the batch);
    experts sharded over "model".  Device (g, j) routes ITS tokens to ITS
    experts only, with per-group capacity C/n_groups — dispatch gather and
    combine scatter are LOCAL.  Each expert's shards across j see disjoint
    token groups, so expert compute is pure data parallelism; the only
    communication is the combine psum of (T_loc, d) over "model" — the same
    collective a dense TP MLP needs anyway.

    vs the GSPMD-auto dense path: the compiler partitions the global
    gather/scatter by REPLICATING the (T·k, d) token-copy tensor per device
    (~69 GB f32 for qwen3-30b at 4k·256) and all-reducing it; this path
    removes those entirely.
    """
    from repro.distributed.sharding import get_rules

    E, k = cfg.n_experts, cfg.experts_per_token
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_groups = 1
    for a in dp_axes:
        n_groups *= mesh.shape[a]
    n_model = mesh.shape["model"]
    E_loc = E // n_model
    B, S, d = x.shape
    T = B * S
    C_group = expert_capacity(T // max(1, n_groups), E, k, cfg.capacity_factor)

    # DP-attention layout: batch rows also sharded over "model".  The group's
    # tokens are reconstituted with an EXPLICIT tiled all-gather (and the
    # combined output returned with a psum_scatter) — letting GSPMD reshard
    # instead triggers involuntary full rematerialization (replicate+slice).
    batch_rule = get_rules().get("batch")
    rule_axes = (batch_rule,) if isinstance(batch_rule, str) else tuple(batch_rule or ())
    over_model = "model" in rule_axes and (B % (n_groups * n_model) == 0)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def local_fn(router, wg, wu, wo, xl):
        # xl: (B_loc, S, d); wg/wu/wo: (E_loc, ...); router replicated
        if over_model:
            xl = jax.lax.all_gather(xl, "model", axis=0, tiled=True)
        Bl = xl.shape[0]
        Tl = Bl * S
        xf = xl.reshape(Tl, d)
        logits = (xf @ router.astype(jnp.float32)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)                 # (Tl, E)
        gate_vals, expert_ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        # Switch aux from GLOBAL stats: psum the (E,) vectors over dp.
        # bincount, not one_hot: the (Tl, k, E) one-hot costs 268 MB of HBM
        # traffic per layer at qwen3 dims; the bincount is (Tl*k) ints.
        tok_frac = (
            jnp.bincount(expert_ids.reshape(-1), length=E).astype(jnp.float32)
            / expert_ids.shape[0]
        )
        prob_frac = jnp.mean(probs, axis=0)
        if dp_axes:
            tok_frac = jax.lax.pmean(tok_frac, dp_axes)
            prob_frac = jax.lax.pmean(prob_frac, dp_axes)
        aux = E * jnp.sum(tok_frac * prob_frac)

        # local experts on this model shard
        e0 = jax.lax.axis_index("model") * E_loc
        flat_expert = expert_ids.reshape(-1)                    # (Tl*k,)
        flat_token = jnp.repeat(jnp.arange(Tl), k)
        flat_gate = gate_vals.reshape(-1)
        local_e = flat_expert - e0                              # in [0, E_loc)?
        is_local = (local_e >= 0) & (local_e < E_loc)

        order = jnp.argsort(jnp.where(is_local, local_e, E_loc), stable=True)
        se = local_e[order]
        st = flat_token[order]
        sg = flat_gate[order]
        sl = is_local[order]

        counts = jnp.bincount(jnp.where(is_local, local_e, E_loc),
                              length=E_loc + 1)[:E_loc]
        offsets = jnp.concatenate(
            [jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]]
        )
        pos_in_e = jnp.arange(Tl * k) - offsets[jnp.clip(se, 0, E_loc - 1)]
        keep = sl & (pos_in_e < C_group)
        slot = jnp.where(keep, se * C_group + pos_in_e, E_loc * C_group)

        # compact dispatch: scatter token INDICES (ints) into slots, then
        # gather exactly (E_loc*C, d) rows — materializing xf[st] first would
        # move the full (Tl*k, d) copy tensor (~12x larger than the buffer)
        slot_tok = jnp.zeros((E_loc * C_group + 1,), jnp.int32).at[slot].set(
            st.astype(jnp.int32))
        slot_ok = jnp.zeros((E_loc * C_group + 1,), jnp.bool_).at[slot].set(keep)
        buf = xf[slot_tok[: E_loc * C_group]]
        buf = buf * slot_ok[: E_loc * C_group, None].astype(buf.dtype)
        buf = buf.reshape(E_loc, C_group, d)

        g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(buf.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(buf.dtype))
        h = jax.nn.silu(g) * u
        y = jnp.einsum("ecf,efd->ecd", h, wo.astype(buf.dtype))

        yf = y.reshape(E_loc * C_group, d)
        safe_slot = jnp.minimum(slot, E_loc * C_group - 1)
        out_copies = yf[safe_slot] * (sg * keep)[:, None].astype(yf.dtype)
        out = jnp.zeros((Tl, d), yf.dtype).at[st].add(out_copies)
        out = out.reshape(Bl, S, d)
        # combine partial expert outputs across the model axis; in the
        # DP-attention layout fuse the combine with the re-scatter (RS costs
        # half an AR and lands directly in the 256-way layout)
        if over_model:
            out = jax.lax.psum_scatter(out, "model", scatter_dimension=0,
                                       tiled=True)
        else:
            out = jax.lax.psum(out, "model")
        return out, aux.astype(jnp.float32)

    if over_model:
        batch_spec = (*dp_axes, "model")
    else:
        batch_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    out, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(), P("model"), P("model"), P("model"),
            P(batch_spec, None, None),
        ),
        out_specs=(P(batch_spec, None, None), P()),
        check_rep=False,
    )(p["router"], p["wi_gate"], p["wi_up"], p["wo"], x)
    out = wlc(out, "batch", "seq", "act_embed")
    return out, aux


def _moe_mlp_dense(p: Dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Single-program gather/scatter dispatch (GSPMD-auto partitioning).

    Top-k routing with normalized gates; load-balancing aux loss (Switch-style):
    ``E * Σ_e f_e · p_e`` where f_e = token fraction, p_e = mean router prob.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    T = B * S
    C = expert_capacity(T, E, k, cfg.capacity_factor)
    xf = x.reshape(T, d)

    router_logits = (xf @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)              # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # aux load-balance loss
    tok_frac = jnp.mean(
        jax.nn.one_hot(expert_ids, E, dtype=jnp.float32).sum(axis=1), axis=0
    )                                                           # (E,)
    prob_frac = jnp.mean(probs, axis=0)                         # (E,)
    aux = E * jnp.sum(tok_frac * prob_frac)

    # ---- dispatch: sort token-copies by expert, take first C per expert ----
    flat_expert = expert_ids.reshape(-1)                        # (T*k,)
    flat_token = jnp.repeat(jnp.arange(T), k)                   # (T*k,)
    flat_gate = gate_vals.reshape(-1)                           # (T*k,)

    order = jnp.argsort(flat_expert, stable=True)               # group by expert
    se = flat_expert[order]
    st = flat_token[order]
    sg = flat_gate[order]

    counts = jnp.bincount(flat_expert, length=E)                # (E,)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(T * k) - offsets[se]
    keep = pos_in_expert < C
    slot = jnp.where(keep, se * C + pos_in_expert, E * C)       # overflow -> dump row

    # scatter tokens into expert buffers (E*C+1, d); final row is the dump
    gathered = xf[st] * keep[:, None].astype(xf.dtype)
    buf = jnp.zeros((E * C + 1, d), xf.dtype).at[slot].set(gathered)
    buf = buf[: E * C].reshape(E, C, d)
    buf = wlc(buf, "act_experts", None, None)

    # ---- expert compute (per-expert SwiGLU) ----
    g = jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"].astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["wi_up"].astype(buf.dtype))
    h = jax.nn.silu(g) * u
    h = wlc(h, "act_experts", None, None)
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(buf.dtype))

    # ---- combine: gather back token copies, weight by gates, sum over k ----
    yf = y.reshape(E * C, d)
    safe_slot = jnp.minimum(slot, E * C - 1)
    out_copies = yf[safe_slot] * (sg * keep)[:, None].astype(yf.dtype)
    out = jnp.zeros((T, d), yf.dtype).at[st].add(out_copies)
    out = wlc(out.reshape(B, S, d), "batch", "seq", "act_embed")
    return out, aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Model = dense skeleton with MoE FFN
# ---------------------------------------------------------------------------


def _init_block(s, cfg: ModelConfig):
    hd = cfg.resolved_head_dim()
    L.init_rmsnorm(s, "ln1", cfg.d_model)
    L.init_attention(
        s, "attn", cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd, qkv_bias=cfg.qkv_bias
    )
    L.init_rmsnorm(s, "ln2", cfg.d_model)
    init_moe_mlp(s, "moe", cfg.d_model, cfg.d_ff, cfg.n_experts)


def init_params(cfg: ModelConfig, key=None, abstract=False, dtype=None):
    dtype = dtype or cfg.dtype

    def f(b: ParamBuilder):
        L.init_embedding(b, "embedding", cfg.vocab, cfg.d_model)
        _init_block(stacked(b, cfg.n_layers).scope("blocks"), cfg)
        L.init_rmsnorm(b, "ln_f", cfg.d_model)
        if not cfg.tie_embeddings:
            L.init_embedding(b, "lm_head", cfg.vocab, cfg.d_model)

    return build(f, key=key, abstract=abstract, dtype=dtype)


def _block_train(lp, x, cfg: ModelConfig, positions):
    h = L.rms_norm(lp["ln1"], x)
    h = L.attention_train(
        lp["attn"], h, positions=positions, causal=True, window=cfg.window,
        rope_theta=cfg.rope_theta, precision=cfg.train_precision,
    )
    x = x + h
    h = L.rms_norm(lp["ln2"], x)
    y, aux = moe_mlp(lp["moe"], h, cfg)
    return x + y, aux


def forward(params, cfg: ModelConfig, tokens, **_) -> Tuple[jax.Array, jax.Array]:
    """-> (logits, total_aux_loss)."""
    x = L.embed(params["embedding"], tokens, cfg.dtype)
    positions = jnp.arange(x.shape[1])

    def body(lp, h):
        return _block_train(lp, h, cfg, positions)

    fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        def step(carry, lp):
            h, aux = carry
            h, a = fn(lp, h)
            return (h, aux + a), None

        (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            x, a = fn(lp, x)
            aux = aux + a

    from repro.models.dense import _final

    return _final(params, x, cfg), aux


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    from repro.models import dense

    return dense.init_cache(cfg, batch, cache_len, dtype)


def cache_logical_axes(cfg: ModelConfig):
    from repro.models import dense

    return dense.cache_logical_axes(cfg)


def prefill(params, cfg: ModelConfig, tokens, cache_len: int, **_):
    x = L.embed(params["embedding"], tokens, cfg.dtype)
    positions = jnp.arange(x.shape[1])

    def body(lp, h):
        hn = L.rms_norm(lp["ln1"], h)
        attn_out, kv = L.attention_prefill(
            lp["attn"], hn, positions=positions, cache_len=cache_len,
            causal=True, window=cfg.window, rope_theta=cfg.rope_theta,
            kv_cache_dtype=cfg.kv_cache_dtype,
        )
        h = h + attn_out
        hn = L.rms_norm(lp["ln2"], h)
        y, _aux = moe_mlp(lp["moe"], hn, cfg)
        return h + y, kv

    fn = jax.checkpoint(body) if cfg.remat else body

    if cfg.scan_layers:
        x, cache = jax.lax.scan(lambda c, lp: fn(lp, c), x, params["blocks"])
    else:
        kvs = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            x, kv = fn(lp, x)
            kvs.append(kv)
        cache = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *kvs)
    from repro.models.dense import _final

    return _final(params, x[:, -1:], cfg), cache


def decode_step(params, cfg: ModelConfig, token, cache, pos):
    x = L.embed(params["embedding"], token, cfg.dtype)

    def body(h, xs):
        lp, kv = xs
        hn = L.rms_norm(lp["ln1"], h)
        attn_out, kv = L.attention_decode(
            lp["attn"], hn, kv, pos=pos, window=cfg.window, rope_theta=cfg.rope_theta
        )
        h = h + attn_out
        hn = L.rms_norm(lp["ln2"], h)
        y, _aux = moe_mlp(lp["moe"], hn, cfg)
        return h + y, kv

    from repro.models.dense import _final, _maybe_unrolled_scan

    x, new_cache = _maybe_unrolled_scan(cfg, body, x, (params["blocks"], cache))
    return _final(params, x, cfg), new_cache
