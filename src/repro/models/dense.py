"""Dense llama-style decoder LM (GQA + RoPE + SwiGLU), config-driven.

Covers deepseek-coder-33b, minitron-8b, deepseek-7b, qwen1.5-4b, and serves as the
backbone for qwen2-vl (see :mod:`repro.models.vlm`).

Entry points:
  * ``init_params(cfg, key/abstract)``       -> (params, logical_axes)
  * ``forward(params, cfg, tokens)``         -> logits               (train)
  * ``prefill(params, cfg, tokens, cache_len)`` -> (logits, cache)   (inference)
  * ``decode_step(params, cfg, token, cache, pos)`` -> (logits, cache)
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import with_logical_constraint as wlc
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.param import ParamBuilder, build, stacked

PyTree = Any


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _init_block(s, cfg: ModelConfig):
    hd = cfg.resolved_head_dim()
    L.init_rmsnorm(s, "ln1", cfg.d_model)
    L.init_attention(
        s, "attn", cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd, qkv_bias=cfg.qkv_bias
    )
    L.init_rmsnorm(s, "ln2", cfg.d_model)
    L.init_mlp(s, "mlp", cfg.mlp, cfg.d_model, cfg.d_ff)


def init_params(
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
    abstract: bool = False,
    dtype: Any = None,
) -> Tuple[PyTree, PyTree]:
    dtype = dtype or cfg.dtype

    def f(b: ParamBuilder):
        L.init_embedding(b, "embedding", cfg.vocab, cfg.d_model)
        _init_block(stacked(b, cfg.n_layers).scope("blocks"), cfg)
        L.init_rmsnorm(b, "ln_f", cfg.d_model)
        if not cfg.tie_embeddings:
            L.init_embedding(b, "lm_head", cfg.vocab, cfg.d_model)

    return build(f, key=key, abstract=abstract, dtype=dtype)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _block_train(lp: Dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
                 mrope_positions=None) -> jax.Array:
    h = L.rms_norm(lp["ln1"], x)
    h = L.attention_train(
        lp["attn"], h, positions=positions, causal=True, window=cfg.window,
        rope_theta=cfg.rope_theta,
        mrope_sections=cfg.mrope_sections or None,
        mrope_positions=mrope_positions,
        precision=cfg.train_precision,
    )
    x = x + h
    h = L.rms_norm(lp["ln2"], x)
    return x + L.mlp_apply(lp["mlp"], h, cfg.mlp)


def _scan_blocks(params: PyTree, x: jax.Array, cfg: ModelConfig, body) -> jax.Array:
    blocks = params["blocks"]
    fn = jax.checkpoint(body) if cfg.remat else body  # full remat per layer
    if cfg.scan_layers:
        def step(carry, lp):
            return fn(lp, carry), None

        x, _ = jax.lax.scan(step, x, blocks)
    else:
        # unrolled: used by smoke tests and the dry-run's cost calibration
        # (XLA cost_analysis counts a scan body ONCE; unrolled HLO counts all)
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], blocks)
            x = fn(lp, x)
    return x


def _final(params: PyTree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = L.rms_norm(params["ln_f"], x)
    head = params["embedding"] if cfg.tie_embeddings else params["lm_head"]
    y = L.logits(head, x)
    if cfg.logit_softcap:
        y = jnp.tanh(y / cfg.logit_softcap) * cfg.logit_softcap
    return y


# ---------------------------------------------------------------------------
# Train / prefill / decode
# ---------------------------------------------------------------------------


def forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    inputs_embeds: Optional[jax.Array] = None,
    mrope_positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Training forward. tokens: (B, S) int32 -> logits (B, S, V)."""
    if inputs_embeds is not None:
        x = inputs_embeds.astype(cfg.dtype)
    else:
        x = L.embed(params["embedding"], tokens, cfg.dtype)
    S = x.shape[1]
    positions = jnp.arange(S)
    body = partial(
        lambda lp, h: _block_train(lp, h, cfg, positions, mrope_positions)
    )
    x = _scan_blocks(params, x, cfg, lambda lp, h: body(lp, h))
    return _final(params, x, cfg)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None) -> PyTree:
    dtype = dtype or cfg.dtype
    hd = cfg.resolved_head_dim()
    shape = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, hd)
    if cfg.kv_cache_dtype == "int8":
        # per-row symmetric int8 + f32 scale column: ~4x fewer KV-pool bytes
        sshape = shape[:-1] + (1,)
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(sshape, jnp.float32),
            "v": jnp.zeros(shape, jnp.int8),
            "v_scale": jnp.zeros(sshape, jnp.float32),
        }
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def cache_logical_axes(cfg: ModelConfig) -> PyTree:
    ax = ("layers", "batch", "kv_seq", "act_kv_heads", None)
    if cfg.kv_cache_dtype == "int8":
        return {"k": ax, "k_scale": ax, "v": ax, "v_scale": ax}
    return {"k": ax, "v": ax}


def prefill(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jax.Array,
    cache_len: int,
    *,
    inputs_embeds: Optional[jax.Array] = None,
    mrope_positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, PyTree]:
    """Run the prompt, return last-position logits + KV cache."""
    if inputs_embeds is not None:
        x = inputs_embeds.astype(cfg.dtype)
    else:
        x = L.embed(params["embedding"], tokens, cfg.dtype)
    S = x.shape[1]
    positions = jnp.arange(S)

    def body(lp, h):
        hn = L.rms_norm(lp["ln1"], h)
        attn_out, kv = L.attention_prefill(
            lp["attn"], hn, positions=positions, cache_len=cache_len,
            causal=True, window=cfg.window, rope_theta=cfg.rope_theta,
            mrope_sections=(cfg.mrope_sections or None)
            if mrope_positions is not None else None,
            mrope_positions=mrope_positions,
            kv_cache_dtype=cfg.kv_cache_dtype,
        )
        h = h + attn_out
        hn = L.rms_norm(lp["ln2"], h)
        return h + L.mlp_apply(lp["mlp"], hn, cfg.mlp), kv

    if cfg.scan_layers:
        fn = jax.checkpoint(body) if cfg.remat else body

        def step(carry, lp):
            h, kv = fn(lp, carry)
            return h, kv

        x, cache = jax.lax.scan(step, x, params["blocks"])
    else:
        kvs = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            x, kv = body(lp, x)
            kvs.append(kv)
        cache = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *kvs)
    return _final(params, x[:, -1:], cfg), cache


def _maybe_unrolled_scan(cfg, body, x, blocks_and_state):
    """scan when cfg.scan_layers else an unrolled Python loop (same semantics)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, x, blocks_and_state)
    n = jax.tree_util.tree_leaves(blocks_and_state)[0].shape[0]
    outs = []
    for i in range(n):
        xs = jax.tree_util.tree_map(lambda a: a[i], blocks_and_state)
        x, out = body(x, xs)
        outs.append(out)
    stacked = jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls), *outs
    ) if outs and outs[0] is not None else None
    return x, stacked


def decode_step(
    params: PyTree,
    cfg: ModelConfig,
    token: jax.Array,   # (B, 1) int32
    cache: PyTree,      # {"k","v"}: (L, B, Skv, Hkv, D)
    pos: jax.Array,     # (B,) absolute position of the new token
    rope_offset: Optional[jax.Array] = None,  # (B,): rope at pos+offset (VLM)
) -> Tuple[jax.Array, PyTree]:
    x = L.embed(params["embedding"], token, cfg.dtype)
    rope_pos = pos if rope_offset is None else pos + rope_offset

    def body(h, xs):
        lp, kv = xs
        hn = L.rms_norm(lp["ln1"], h)
        attn_out, kv = L.attention_decode(
            lp["attn"], hn, kv, pos=rope_pos, window=cfg.window,
            rope_theta=cfg.rope_theta, slot=pos,
        )
        h = h + attn_out
        hn = L.rms_norm(lp["ln2"], h)
        return h + L.mlp_apply(lp["mlp"], hn, cfg.mlp), kv

    x, new_cache = _maybe_unrolled_scan(cfg, body, x, (params["blocks"], cache))
    return _final(params, x, cfg), new_cache
