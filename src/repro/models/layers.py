"""Shared transformer layers: norms, RoPE / M-RoPE, GQA attention (full / causal /
local-window / cross, train + KV-cache decode), MLPs, embeddings.

All functions are pure; parameters come in as nested dicts created by
:mod:`repro.models.param`.  Activation sharding is annotated with logical axis
names (see :mod:`repro.distributed.sharding`).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import with_logical_constraint as wlc
from repro.models.param import (
    ParamBuilder,
    normal_init,
    ones_init,
    scaled_init,
    zeros_init,
)

# ---------------------------------------------------------------------------
# Global compute switches
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ComputeFlags:
    use_pallas: bool = False          # dispatch attention/scan hot spots to kernels
    pallas_interpret: bool = True     # CPU container: interpret mode
    attn_dtype: Any = jnp.float32     # accumulation dtype for attention softmax
    # switch to the chunked (flash-style, O(S·chunk)-memory) XLA attention path
    # when Sq*Skv exceeds this; the exact sdpa stays the small-shape oracle.
    chunk_threshold: int = 4 * 1024 * 1024
    attn_chunk: int = 512             # KV chunk length for the chunked path
    causal_block_skip: bool = False   # skip fully-masked KV chunks (block-causal)


FLAGS = ComputeFlags()


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(b: ParamBuilder, name: str, dim: int):
    s = b.scope(name)
    s.param("scale", (dim,), ("norm",), init=ones_init())


def rms_norm(p: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(b: ParamBuilder, name: str, dim: int):
    s = b.scope(name)
    s.param("scale", (dim,), ("norm",), init=ones_init())
    s.param("bias", (dim,), ("norm",), init=zeros_init())


def layer_norm(p: Dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE (standard and multimodal M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                      # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    sections: Tuple[int, ...],
    theta: float = 1000000.0,
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, D); positions: (3, B, S) — (temporal, height, width) position ids.
    ``sections`` gives the number of *frequency pairs* per modality,
    sum(sections) == D/2.  Each frequency pair i uses the position stream of the
    section it falls into.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                       # (D/2,)
    # section id per frequency pair
    sec_ids = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=d // 2
    )                                                  # (D/2,)
    # pos_per_freq: (B, S, D/2) — pick the position stream per pair
    pos = jnp.moveaxis(positions, 0, -1)               # (B, S, 3)
    pos_per_freq = jnp.take_along_axis(
        pos.astype(jnp.float32),
        jnp.broadcast_to(sec_ids, pos.shape[:-1] + (d // 2,)).astype(jnp.int32),
        axis=-1,
    )                                                  # (B, S, D/2)
    angles = pos_per_freq[..., None, :] * freqs        # (B, S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA) — parameters
# ---------------------------------------------------------------------------


def init_attention(
    b: ParamBuilder,
    name: str,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    qkv_bias: bool = False,
):
    s = b.scope(name)
    s.param("wq", (d_model, n_heads, head_dim), ("embed", "heads", "head_dim"),
            init=scaled_init(0))
    s.param("wk", (d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim"),
            init=scaled_init(0))
    s.param("wv", (d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim"),
            init=scaled_init(0))
    s.param("wo", (n_heads, head_dim, d_model), ("heads", "head_dim", "embed"),
            init=scaled_init(0))
    if qkv_bias:
        s.param("bq", (n_heads, head_dim), ("heads", "head_dim"), init=zeros_init())
        s.param("bk", (n_kv_heads, head_dim), ("kv_heads", "head_dim"), init=zeros_init())
        s.param("bv", (n_kv_heads, head_dim), ("kv_heads", "head_dim"), init=zeros_init())


def qkv_project(
    p: Dict, x: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = wlc(q, "batch", "seq", "act_heads", None)
    k = wlc(k, "batch", "seq", "act_kv_heads", None)
    v = wlc(v, "batch", "seq", "act_kv_heads", None)
    return q, k, v


def out_project(p: Dict, o: jax.Array) -> jax.Array:
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return wlc(y, "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# Attention — core math (reference XLA path; Pallas path lives in repro.kernels)
# ---------------------------------------------------------------------------


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, H, D) by repeating groups."""
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    reps = n_heads // n_kv
    return jnp.repeat(k, reps, axis=2)


def sdpa(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    kv_valid_len: Optional[jax.Array] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Reference scaled-dot-product attention with GQA.

    q: (B, Sq, H, D); k, v: (B, Skv, Hkv, D).
    ``q_offset``: absolute position of q[0] within the kv sequence (decode).
    ``window``: local attention window (keys within [pos-window+1, pos]).
    ``kv_valid_len``: (B,) number of valid kv positions (decode with cache).
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(FLAGS.attn_dtype), k.astype(FLAGS.attn_dtype)
    ) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap

    q_pos = jnp.arange(Sq) + q_offset           # (Sq,)
    k_pos = jnp.arange(Skv)                     # (Skv,)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    if kv_valid_len is not None:
        vmask = k_pos[None, :] < kv_valid_len[:, None]  # (B, Skv)
        logits = jnp.where(vmask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out


def chunked_sdpa(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    softcap: Optional[float] = None,
    chunk: Optional[int] = None,
) -> jax.Array:
    """Flash-style online-softmax attention over KV chunks (pure XLA).

    Memory is O(B·H·Sq·chunk) instead of O(B·H·Sq·Skv) — this is the deployable
    large-context path on which the dry-run/roofline numbers are based; the Pallas
    kernel in :mod:`repro.kernels.flash_attention` is the TPU-native hot path.
    Numerically matches :func:`sdpa` (property-tested).
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    chunk = chunk or FLAGS.attn_chunk
    chunk = min(chunk, Skv)
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)

    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (Skv + pad) // chunk

    scale = 1.0 / math.sqrt(D)
    qf = q.astype(FLAGS.attn_dtype) * scale
    q_pos = jnp.arange(Sq) + q_offset                     # (Sq,)

    # xs: (n_chunks, B, chunk, H, D)
    ks = jnp.moveaxis(k.reshape(B, n_chunks, chunk, H, D), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, n_chunks, chunk, H, D), 1, 0)
    chunk_ids = jnp.arange(n_chunks)

    m0 = jnp.full((B, H, Sq), -jnp.inf, FLAGS.attn_dtype)
    l0 = jnp.zeros((B, H, Sq), FLAGS.attn_dtype)
    acc0 = jnp.zeros((B, Sq, H, D), FLAGS.attn_dtype)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, ci = xs                                   # (B,c,H,D) x2, ()
        k_pos = ci * chunk + jnp.arange(chunk)            # (c,)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kc.astype(FLAGS.attn_dtype))
        if softcap is not None:
            logits = jnp.tanh(logits / softcap) * softcap
        mask = k_pos[None, :] < Skv                       # drop right-padding
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (k_pos[None, :] > (q_pos[:, None] - window))
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        # Rows with every position masked keep m=-inf -> p would be exp(0)=1.
        p = jnp.where(mask[None, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        corr = jnp.where(jnp.isfinite(corr), corr, 0.0)   # first-chunk -inf - -inf
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p, vc.astype(FLAGS.attn_dtype))
        acc = acc * jnp.moveaxis(corr, 1, 2)[..., None] + pv
        return (m, l, acc), None

    # carry m is updated via m_new; rebind for scan correctness
    def scan_body(carry, xs):
        m, l, acc = carry
        (m2, l2, acc2), _ = _chunk_step(m, l, acc, xs)
        return (m2, l2, acc2), None

    def _chunk_step(m, l, acc, xs):
        kc, vc, ci = xs
        k_pos = ci * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kc.astype(FLAGS.attn_dtype))
        if softcap is not None:
            logits = jnp.tanh(logits / softcap) * softcap
        mask = k_pos[None, :] < Skv
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (k_pos[None, :] > (q_pos[:, None] - window))
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        corr = jnp.where(jnp.isfinite(corr), corr, 0.0)
        l2 = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p, vc.astype(FLAGS.attn_dtype))
        acc2 = acc * jnp.moveaxis(corr, 1, 2)[..., None] + pv
        return (m_new, l2, acc2), None

    (m, l, acc), _ = jax.lax.scan(scan_body, (m0, l0, acc0), (ks, vs, chunk_ids))
    l = jnp.maximum(l, 1e-30)
    out = acc / jnp.moveaxis(l, 1, 2)[..., None]
    return out.astype(v.dtype)


def _dispatch_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
    window: Optional[int], softcap: Optional[float] = None,
) -> jax.Array:
    """Pick pallas / chunked / exact attention by flags and problem size."""
    if FLAGS.use_pallas:
        from repro.kernels import ops as kops

        return kops.flash_attention(
            q, k, v, causal=causal, window=window,
            interpret=FLAGS.pallas_interpret,
        )
    if q.shape[1] * k.shape[1] > FLAGS.chunk_threshold:
        return chunked_sdpa(q, k, v, causal=causal, window=window, softcap=softcap)
    return sdpa(q, k, v, causal=causal, window=window, softcap=softcap)


def attention_train(
    p: Dict,
    x: jax.Array,
    *,
    positions: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    rope_theta: float = 10000.0,
    use_rope: bool = True,
    mrope_sections: Optional[Tuple[int, ...]] = None,
    mrope_positions: Optional[jax.Array] = None,
    precision: str = "f32",
) -> jax.Array:
    """Full-sequence attention (training / prefill without cache return).

    ``precision`` is ``ModelConfig.train_precision``: ``"bf16"`` casts the
    attention operands before the kernel; ``"int8-fused"`` routes to the
    quantized-K/V kernel whose backward saves int8 residuals.  The precision
    semantics hold on AND off Pallas (the q8 op has an exact jnp fallback),
    so a trajectory trained on CPU matches the TPU quantization decisions.
    """
    q, k, v = qkv_project(p, x)
    if mrope_sections is not None:
        q = apply_mrope(q, mrope_positions, mrope_sections, rope_theta)
        k = apply_mrope(k, mrope_positions, mrope_sections, rope_theta)
    elif use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if precision == "bf16":
        q, k, v = (t.astype(jnp.bfloat16) for t in (q, k, v))
    if precision == "int8-fused":
        from repro.kernels import ops as kops

        o = kops.flash_attention_q8(
            q, k, v, causal=causal, window=window,
            interpret=FLAGS.pallas_interpret, use_kernel=FLAGS.use_pallas,
        )
    elif FLAGS.use_pallas:
        from repro.kernels import ops as kops

        o = kops.flash_attention(
            q, k, v, causal=causal, window=window,
            interpret=FLAGS.pallas_interpret,
        )
    else:
        o = sdpa(q, k, v, causal=causal, window=window)
    return out_project(p, o.astype(x.dtype))


def attention_prefill(
    p: Dict,
    x: jax.Array,
    *,
    positions: jax.Array,
    cache_len: int,
    causal: bool = True,
    window: Optional[int] = None,
    rope_theta: float = 10000.0,
    use_rope: bool = True,
    rotating: bool = False,
    mrope_sections: Optional[Tuple[int, ...]] = None,
    mrope_positions: Optional[jax.Array] = None,
    kv_cache_dtype: str = "native",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill: run full attention AND return a KV cache padded to ``cache_len``.

    ``rotating=True`` (local-attention archs): the cache holds only the LAST
    ``min(S, cache_len)`` positions, aligned to slot 0 — the layout the
    rotating-window decode path expects.  Keys keep their absolute RoPE
    phases (RoPE is relative, so rolled slots stay exact).

    ``kv_cache_dtype="int8"``: the returned cache stores per-row symmetric
    int8 K/V + f32 scales (``k_scale``/``v_scale`` leaves); the decode path
    dequantizes inside the kernel.  Attention over the prompt itself still
    runs full-precision — only the cache is quantized.
    """
    q, k, v = qkv_project(p, x)
    if mrope_sections is not None:
        q = apply_mrope(q, mrope_positions, mrope_sections, rope_theta)
        k = apply_mrope(k, mrope_positions, mrope_sections, rope_theta)
    elif use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    o = _dispatch_attention(q, k, v, causal=causal, window=window)
    B, S, Hkv, D = k.shape
    if rotating and S > cache_len:
        k = k[:, S - cache_len:]
        v = v[:, S - cache_len:]
        S = cache_len
    pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
    kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
    if kv_cache_dtype == "int8":
        # padded rows quantize against absmax 0 -> scale floor, q == 0
        from repro.kernels import ref as KR

        kq, ks = KR.quantize_int8_ref(kc)
        vq, vs = KR.quantize_int8_ref(vc)
        cache = {
            "k": wlc(kq, "batch", "kv_seq", "act_kv_heads", None),
            "k_scale": wlc(ks, "batch", "kv_seq", "act_kv_heads", None),
            "v": wlc(vq, "batch", "kv_seq", "act_kv_heads", None),
            "v_scale": wlc(vs, "batch", "kv_seq", "act_kv_heads", None),
        }
    else:
        cache = {
            "k": wlc(kc, "batch", "kv_seq", "act_kv_heads", None),
            "v": wlc(vc, "batch", "kv_seq", "act_kv_heads", None),
        }
    return out_project(p, o), cache


def attention_decode(
    p: Dict,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    *,
    pos: jax.Array,  # (B,) current absolute position of the new token
    window: Optional[int] = None,
    rope_theta: float = 10000.0,
    use_rope: bool = True,
    slot: Optional[jax.Array] = None,        # (B,) cache row to write (default pos)
    valid_len: Optional[jax.Array] = None,   # (B,) valid cache rows (default pos+1)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode against a KV cache. x: (B, 1, d).

    RoPE always uses the ABSOLUTE ``pos`` (never the cache slot): RoPE is
    relative, so as long as every cached key kept its absolute phase, rolled
    rotating-window slots still attend at the true distances.

    An int8 cache (``"k_scale"`` leaf present) is detected from the pytree:
    the new row is quantized per-(batch, head) before the cache write and the
    sweep dequantizes in-kernel (Pallas) or up-front (exact CPU path).
    """
    q, k, v = qkv_project(p, x)                       # (B,1,H,D) / (B,1,Hkv,D)
    if use_rope:
        q = apply_rope(q, pos[:, None], rope_theta)
        k = apply_rope(k, pos[:, None], rope_theta)
    idx = (pos if slot is None else slot).astype(jnp.int32)   # (B,) write row
    valid = (idx + 1) if valid_len is None else valid_len.astype(jnp.int32)
    upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0, 0)))
    kv_axes = ("batch", "kv_seq", "act_kv_heads", None)

    if "k_scale" in cache:
        from repro.kernels import ref as KR

        kq, ks_new = KR.quantize_int8_ref(k[:, 0:1])
        vq, vs_new = KR.quantize_int8_ref(v[:, 0:1])
        ck = wlc(upd(cache["k"], kq, idx), *kv_axes)
        cks = wlc(upd(cache["k_scale"], ks_new, idx), *kv_axes)
        cv = wlc(upd(cache["v"], vq, idx), *kv_axes)
        cvs = wlc(upd(cache["v_scale"], vs_new, idx), *kv_axes)
        if FLAGS.use_pallas:
            from repro.kernels import ops as kops

            o = kops.decode_attention_int8(
                q, ck, cks, cv, cvs, valid,
                window=window, interpret=FLAGS.pallas_interpret,
            )
        else:
            o = _decode_sdpa_exact(
                q,
                KR.dequantize_int8_ref(ck, cks),
                KR.dequantize_int8_ref(cv, cvs),
                valid - 1, window,
            )
        return out_project(p, o), {
            "k": ck, "k_scale": cks, "v": cv, "v_scale": cvs
        }

    ck = wlc(upd(cache["k"], k[:, 0:1], idx), *kv_axes)
    cv = wlc(upd(cache["v"], v[:, 0:1], idx), *kv_axes)
    if FLAGS.use_pallas:
        from repro.kernels import ops as kops

        o = kops.decode_attention(
            q, ck, cv, valid, window=window, interpret=FLAGS.pallas_interpret
        )
    else:
        o = _decode_sdpa_exact(q, ck, cv, valid - 1, window)
    return out_project(p, o), {"k": ck, "v": cv}


def _decode_sdpa_exact(
    q: jax.Array, ck: jax.Array, cv: jax.Array, idx: jax.Array,
    window: Optional[int],
) -> jax.Array:
    """Exact reference decode attention with per-batch positions."""
    B, _, H, D = q.shape
    Skv = ck.shape[1]
    k = _repeat_kv(ck, H)
    v = _repeat_kv(cv, H)
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(FLAGS.attn_dtype), k.astype(FLAGS.attn_dtype)
    ) * scale                                        # (B,H,1,Skv)
    k_pos = jnp.arange(Skv)[None, :]                 # (1,Skv)
    mask = k_pos <= idx[:, None]                     # causal-valid
    if window is not None:
        mask &= k_pos > (idx[:, None] - window)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def cross_attention(
    p: Dict,
    x: jax.Array,
    ctx_k: jax.Array,
    ctx_v: jax.Array,
) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V (no RoPE)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    q = wlc(q, "batch", "seq", "act_heads", None)
    o = sdpa(q, ctx_k, ctx_v, causal=False)
    return out_project(p, o)


def cross_kv(p: Dict, ctx: jax.Array) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", ctx, p["wk"].astype(ctx.dtype))
    v = jnp.einsum("bsd,dhk->bshk", ctx, p["wv"].astype(ctx.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(ctx.dtype)
        v = v + p["bv"].astype(ctx.dtype)
    return k, v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_swiglu(b: ParamBuilder, name: str, d_model: int, d_ff: int):
    s = b.scope(name)
    s.param("wi_gate", (d_model, d_ff), ("embed", "mlp"), init=scaled_init(0))
    s.param("wi_up", (d_model, d_ff), ("embed", "mlp"), init=scaled_init(0))
    s.param("wo", (d_ff, d_model), ("mlp", "embed"), init=scaled_init(0))


def swiglu(p: Dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = wlc(h, "batch", "seq", "act_mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
    return wlc(y, "batch", "seq", "act_embed")


def init_gelu_mlp(b: ParamBuilder, name: str, d_model: int, d_ff: int, bias: bool = True):
    s = b.scope(name)
    s.param("wi", (d_model, d_ff), ("embed", "mlp"), init=scaled_init(0))
    s.param("wo", (d_ff, d_model), ("mlp", "embed"), init=scaled_init(0))
    if bias:
        s.param("bi", (d_ff,), ("mlp",), init=zeros_init())
        s.param("bo", (d_model,), ("embed",), init=zeros_init())


def gelu_mlp(p: Dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
    if "bi" in p:
        h = h + p["bi"].astype(x.dtype)
    h = jax.nn.gelu(wlc(h, "batch", "seq", "act_mlp"))
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
    if "bo" in p:
        y = y + p["bo"].astype(x.dtype)
    return wlc(y, "batch", "seq", "act_embed")


def init_mlp(b: ParamBuilder, name: str, kind: str, d_model: int, d_ff: int):
    """kind: swiglu | geglu | gelu | relu2."""
    if kind in ("swiglu", "geglu"):
        init_swiglu(b, name, d_model, d_ff)
    else:
        init_gelu_mlp(b, name, d_model, d_ff, bias=(kind == "gelu"))


def mlp_apply(p: Dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        return swiglu(p, x)
    if kind == "geglu":
        return geglu(p, x)
    if kind == "relu2":
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
        h = jnp.square(jax.nn.relu(wlc(h, "batch", "seq", "act_mlp")))
        y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
        return wlc(y, "batch", "seq", "act_embed")
    return gelu_mlp(p, x)


def init_geglu(b: ParamBuilder, name: str, d_model: int, d_ff: int):
    init_swiglu(b, name, d_model, d_ff)


def geglu(p: Dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(x.dtype))
    h = jax.nn.gelu(g) * u
    h = wlc(h, "batch", "seq", "act_mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
    return wlc(y, "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def init_embedding(b: ParamBuilder, name: str, vocab: int, d_model: int):
    s = b.scope(name)
    s.param("table", (vocab, d_model), ("vocab", "embed"), init=normal_init(1.0))


def embed(p: Dict, tokens: jax.Array, dtype: Any = jnp.float32) -> jax.Array:
    x = p["table"].astype(dtype)[tokens]
    return wlc(x, "batch", "seq", "act_embed")


def logits(p: Dict, x: jax.Array) -> jax.Array:
    y = jnp.einsum("bsd,vd->bsv", x, p["table"].astype(x.dtype))
    return wlc(y, "batch", "seq", "act_vocab")


def init_linear(
    b: ParamBuilder, name: str, d_in: int, d_out: int,
    axes: Tuple[Optional[str], Optional[str]] = ("embed", "mlp"),
    bias: bool = False,
):
    s = b.scope(name)
    s.param("w", (d_in, d_out), axes, init=scaled_init(0))
    if bias:
        s.param("b", (d_out,), (axes[1],), init=zeros_init())


def linear(p: Dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y
