"""RecurrentGemma / Griffin-style hybrid: RG-LRU recurrent blocks + local attention,
repeating (R, R, A) pattern.  Sub-quadratic => runs the long_500k shape.

RG-LRU recurrence (per channel, c = 8):
    r_t = sigmoid(x_t W_a + b_a)                      (recurrence gate)
    i_t = sigmoid(x_t W_x + b_x)                      (input gate)
    log a_t = -c * softplus(Lambda) * r_t
    h_t = exp(log a_t) * h_{t-1} + sqrt(1 - exp(2 log a_t)) * (i_t * x_t)

The temporal-mixing recurrent block is:  linear-in (2 branches) -> [causal conv1d(4)
-> RG-LRU] * gelu-gate -> linear-out.  Each layer is temporal-mix + GeGLU MLP, both
pre-norm residual.  Training uses an associative scan (or the Pallas blocked-scan
kernel); decode carries (conv window, lru state) per layer.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import with_logical_constraint as wlc
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.param import (
    ParamBuilder, build, constant_init, normal_init, scaled_init, stacked,
    uniform_init, zeros_init,
)

PyTree = Any
C_RGLRU = 8.0


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------


def init_rglru(b, name: str, width: int):
    s = b.scope(name)
    s.param("wa", (width,), ("lru",), init=zeros_init())       # diagonal gates
    s.param("ba", (width,), ("lru",), init=zeros_init())
    s.param("wx", (width,), ("lru",), init=zeros_init())
    s.param("bx", (width,), ("lru",), init=zeros_init())
    # Lambda init so that a = sigmoid(Lambda) in [0.9, 0.999] (paper init)
    s.param("lam", (width,), ("lru",), init=uniform_init(2.2, 6.9))


def _rglru_gates(p: Dict, x: jax.Array):
    """x: (B, S, W) -> (log_a, gated_x) both (B, S, W), float32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * p["wa"].astype(jnp.float32) + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf * p["wx"].astype(jnp.float32) + p["bx"].astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return log_a, gated


def rglru_scan(p: Dict, x: jax.Array, h0: Optional[jax.Array] = None,
               precision: str = "f32") -> jax.Array:
    """Associative-scan reference. x: (B, S, W) -> y: (B, S, W)."""
    log_a, gated = _rglru_gates(p, x)
    a = jnp.exp(log_a)
    if h0 is not None:
        gated = gated.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    from repro.models.layers import FLAGS

    if precision == "int8-fused":
        from repro.kernels import ops as kops

        # gated input streams as int8 + per-row scales; the decay a stays f32
        # (seq padding inside the kernel must be exactly 1.0 to pass the carry)
        y = kops.rglru_scan_q8(
            a, gated, interpret=FLAGS.pallas_interpret,
            use_kernel=FLAGS.use_pallas,
        )
    elif FLAGS.use_pallas:
        if precision == "bf16":
            gated = gated.astype(jnp.bfloat16).astype(jnp.float32)
        from repro.kernels import ops as kops

        y = kops.rglru_scan(a, gated, interpret=FLAGS.pallas_interpret)
    else:
        if precision == "bf16":
            gated = gated.astype(jnp.bfloat16).astype(jnp.float32)
        _, y = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return y.astype(x.dtype)


def rglru_step(p: Dict, x: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One decode step. x: (B, 1, W), h: (B, W) -> (y, new_h)."""
    log_a, gated = _rglru_gates(p, x)
    a = jnp.exp(log_a[:, 0])
    new_h = a * h.astype(jnp.float32) + gated[:, 0]
    return new_h[:, None].astype(x.dtype), new_h


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (width 4)
# ---------------------------------------------------------------------------


def init_conv1d(b, name: str, width: int, ksize: int):
    s = b.scope(name)
    s.param("w", (ksize, width), ("conv", "lru"), init=normal_init(0.02))
    s.param("b", (width,), ("lru",), init=zeros_init())


def causal_conv1d(p: Dict, x: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, W)."""
    k = p["w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1]] * p["w"][i].astype(x.dtype) for i in range(k)
    )
    return out + p["b"].astype(x.dtype)


def conv1d_step(p: Dict, x: jax.Array, window: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Decode step. x: (B, 1, W); window: (B, k-1, W) past inputs."""
    k = p["w"].shape[0]
    full = jnp.concatenate([window, x], axis=1)          # (B, k, W)
    out = jnp.einsum("bkw,kw->bw", full.astype(jnp.float32),
                     p["w"].astype(jnp.float32))[:, None]
    out = out.astype(x.dtype) + p["b"].astype(x.dtype)
    return out, full[:, 1:]


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def init_recurrent_block(s, cfg: ModelConfig):
    w = cfg.lru_width or cfg.d_model
    L.init_linear(s, "in_rec", cfg.d_model, w, axes=("embed", "lru"))
    L.init_linear(s, "in_gate", cfg.d_model, w, axes=("embed", "lru"))
    init_conv1d(s, "conv", w, cfg.conv_width)
    init_rglru(s, "lru", w)
    L.init_linear(s, "out", w, cfg.d_model, axes=("lru", "embed"))


def recurrent_block(
    lp: Dict, x: jax.Array, cfg: ModelConfig, return_state: bool = False
):
    rec_in = L.linear(lp["in_rec"], x)
    gate = jax.nn.gelu(L.linear(lp["in_gate"], x))
    rec = causal_conv1d(lp["conv"], rec_in)
    rec = rglru_scan(lp["lru"], rec, precision=cfg.train_precision)
    y = rec * gate
    y = wlc(y, "batch", "seq", "act_mlp")
    out = L.linear(lp["out"], y)
    if not return_state:
        return out
    # decode-ready state: conv window = last (k-1) conv INPUTS (zero-padded on
    # the left when the prompt is shorter); lru h = last scan output.
    k = lp["conv"]["w"].shape[0]
    S = rec_in.shape[1]
    win = rec_in[:, max(0, S - (k - 1)):]
    if S < k - 1:
        win = jnp.pad(win, ((0, 0), (k - 1 - S, 0), (0, 0)))
    state = {"conv": win, "lru": rec[:, -1].astype(jnp.float32)}
    return out, state


def recurrent_block_step(
    lp: Dict, x: jax.Array, state: Dict
) -> Tuple[jax.Array, Dict]:
    rec = L.linear(lp["in_rec"], x)
    gate = jax.nn.gelu(L.linear(lp["in_gate"], x))
    rec, conv_win = conv1d_step(lp["conv"], rec, state["conv"])
    rec, h = rglru_step(lp["lru"], rec, state["lru"])
    y = rec * gate
    return L.linear(lp["out"], y), {"conv": conv_win, "lru": h}


def _init_layer(s, cfg: ModelConfig, kind: str):
    L.init_rmsnorm(s, "ln1", cfg.d_model)
    if kind == "A":
        hd = cfg.resolved_head_dim()
        L.init_attention(s, "attn", cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd)
    else:
        init_recurrent_block(s, cfg)
    L.init_rmsnorm(s, "ln2", cfg.d_model)
    L.init_geglu(s, "mlp", cfg.d_model, cfg.d_ff)


def layer_kinds(cfg: ModelConfig):
    pat = cfg.block_pattern or ("R", "R", "A")
    return [pat[i % len(pat)] for i in range(cfg.n_layers)]


def init_params(cfg: ModelConfig, key=None, abstract=False, dtype=None):
    """Layers are grouped per *kind* into separate stacked scan groups.

    ``groups`` in the param tree: {"R": stacked recurrent layers, "A": stacked
    attention layers}; execution interleaves them by the pattern.
    """
    dtype = dtype or cfg.dtype
    kinds = layer_kinds(cfg)
    n_r = sum(1 for k in kinds if k == "R")
    n_a = len(kinds) - n_r

    def f(b: ParamBuilder):
        L.init_embedding(b, "embedding", cfg.vocab, cfg.d_model)
        g = b.scope("groups")
        if n_r:
            _init_layer(stacked(g, n_r).scope("R"), cfg, "R")
        if n_a:
            _init_layer(stacked(g, n_a).scope("A"), cfg, "A")
        L.init_rmsnorm(b, "ln_f", cfg.d_model)
        if not cfg.tie_embeddings:
            L.init_embedding(b, "lm_head", cfg.vocab, cfg.d_model)

    return build(f, key=key, abstract=abstract, dtype=dtype)


def _layer_train(lp: Dict, x: jax.Array, cfg: ModelConfig, kind: str,
                 positions: jax.Array) -> jax.Array:
    h = L.rms_norm(lp["ln1"], x)
    if kind == "A":
        h = L.attention_train(
            lp["attn"], h, positions=positions, causal=True,
            window=cfg.window, rope_theta=cfg.rope_theta,
            precision=cfg.train_precision,
        )
    else:
        h = recurrent_block(lp, h, cfg)
    x = x + h
    h = L.rms_norm(lp["ln2"], x)
    return x + L.geglu(lp["mlp"], h)


def forward(params, cfg: ModelConfig, tokens, **_) -> jax.Array:
    x = L.embed(params["embedding"], tokens, cfg.dtype)
    positions = jnp.arange(x.shape[1])
    kinds = layer_kinds(cfg)

    # Interleave two scan groups by the pattern: run each group's layers in
    # pattern order.  Scans stay uniform per group; the interleave is a Python
    # loop over *pattern cycles* with dynamic slices into the stacked groups.
    # For HLO compactness we scan each contiguous same-kind run.
    idx = {"R": 0, "A": 0}
    i = 0
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        kind, n_run = kinds[i], j - i
        group = params["groups"][kind]
        run = jax.tree_util.tree_map(
            lambda a: jax.lax.slice_in_dim(a, idx[kind], idx[kind] + n_run), group
        )

        def body(h, lp, _kind=kind):
            out = _layer_train(lp, h, cfg, _kind, positions)
            return out, None

        fn = jax.checkpoint(lambda lp, h, _k=kind: _layer_train(lp, h, cfg, _k, positions)) \
            if cfg.remat else (lambda lp, h, _k=kind: _layer_train(lp, h, cfg, _k, positions))
        if cfg.scan_layers:
            x, _ = jax.lax.scan(lambda c, lp: (fn(lp, c), None), x, run)
        else:
            for li in range(n_run):
                lp = jax.tree_util.tree_map(lambda a: a[li], run)
                x = fn(lp, x)
        idx[kind] += n_run
        i = j

    from repro.models.dense import _final

    return _final(params, x, cfg)


def prefill(params, cfg: ModelConfig, tokens, cache_len: int, **_):
    """Run the prompt; return (last-position logits, decode-ready cache).

    A-layer caches are rotating windows of ``min(window, cache_len)`` rows
    holding the last in-window KVs (absolute RoPE phases); R-layer states are
    (conv window, final lru h).
    """
    x = L.embed(params["embedding"], tokens, cfg.dtype)
    S = tokens.shape[1]
    positions = jnp.arange(S)
    kinds = layer_kinds(cfg)
    attn_len = min(cache_len, cfg.window or cache_len)

    A_kv, R_conv, R_lru = [], [], []
    idx = {"R": 0, "A": 0}
    for kind in kinds:
        lp = jax.tree_util.tree_map(lambda a: a[idx[kind]], params["groups"][kind])
        h = L.rms_norm(lp["ln1"], x)
        if kind == "A":
            h, kv = L.attention_prefill(
                lp["attn"], h, positions=positions, cache_len=attn_len,
                causal=True, window=cfg.window, rope_theta=cfg.rope_theta,
                rotating=True, kv_cache_dtype=cfg.kv_cache_dtype,
            )
            A_kv.append(kv)
        else:
            h, st = recurrent_block(lp, h, cfg, return_state=True)
            R_conv.append(st["conv"])
            R_lru.append(st["lru"])
        x = x + h
        h = L.rms_norm(lp["ln2"], x)
        x = x + L.geglu(lp["mlp"], h)
        idx[kind] += 1

    empty_a = (
        {"k": jnp.zeros((0,)), "k_scale": jnp.zeros((0,)),
         "v": jnp.zeros((0,)), "v_scale": jnp.zeros((0,))}
        if cfg.kv_cache_dtype == "int8"
        else {"k": jnp.zeros((0,)), "v": jnp.zeros((0,))}
    )
    cache = {
        "A": jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *A_kv)
        if A_kv else empty_a,
        "R": {"conv": jnp.stack(R_conv), "lru": jnp.stack(R_lru)} if R_conv else {
            "conv": jnp.zeros((0,)), "lru": jnp.zeros((0,))},
    }
    from repro.models.dense import _final

    return _final(params, x[:, -1:], cfg), cache


# ---------------------------------------------------------------------------
# Decode: state = attention KV caches (A layers) + (conv, lru) states (R layers)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    kinds = layer_kinds(cfg)
    n_r = sum(1 for k in kinds if k == "R")
    n_a = len(kinds) - n_r
    hd = cfg.resolved_head_dim()
    w = cfg.lru_width or cfg.d_model
    attn_len = min(cache_len, cfg.window or cache_len)
    kv_shape = (n_a, batch, attn_len, cfg.n_kv_heads, hd)
    if cfg.kv_cache_dtype == "int8":
        a_cache = {
            "k": jnp.zeros(kv_shape, jnp.int8),
            "k_scale": jnp.zeros(kv_shape[:-1] + (1,), jnp.float32),
            "v": jnp.zeros(kv_shape, jnp.int8),
            "v_scale": jnp.zeros(kv_shape[:-1] + (1,), jnp.float32),
        }
    else:
        a_cache = {
            "k": jnp.zeros(kv_shape, dtype),
            "v": jnp.zeros(kv_shape, dtype),
        }
    return {
        "A": a_cache,
        "R": {
            "conv": jnp.zeros((n_r, batch, cfg.conv_width - 1, w), dtype),
            "lru": jnp.zeros((n_r, batch, w), jnp.float32),
        },
    }


def cache_logical_axes(cfg: ModelConfig):
    kv = ("layers", "batch", "kv_seq", "act_kv_heads", None)
    a_axes = (
        {"k": kv, "k_scale": kv, "v": kv, "v_scale": kv}
        if cfg.kv_cache_dtype == "int8" else {"k": kv, "v": kv}
    )
    return {
        "A": a_axes,
        "R": {
            "conv": ("layers", "batch", None, "lru"),
            "lru": ("layers", "batch", "lru"),
        },
    }


def decode_step(params, cfg: ModelConfig, token, cache, pos):
    """Local-attention KV cache is a rotating window of size cfg.window.

    Keys keep their ABSOLUTE RoPE phase; the roll evicts the oldest key, so
    every cached key is in-window by construction (no window mask needed) and
    attention distances stay exact.
    """
    x = L.embed(params["embedding"], token, cfg.dtype)
    kinds = layer_kinds(cfg)
    window = cfg.window or cache.get("A", {}).get("k", jnp.zeros((1, 1, 1))).shape[2]

    new_A, new_conv, new_lru = [], [], []
    idx = {"R": 0, "A": 0}
    for i, kind in enumerate(kinds):
        lp = jax.tree_util.tree_map(
            lambda a: a[idx[kind]], params["groups"][kind]
        )
        h = L.rms_norm(lp["ln1"], x)
        if kind == "A":
            # every KV leaf (2-leaf native or 4-leaf int8 + scales) rides
            # the same rotating-window roll: scale columns are (B, S, H, 1)
            kv = {n: c[idx["A"]] for n, c in cache["A"].items()}
            cache_rows = kv["k"].shape[1]
            win = min(window, cache_rows)
            # rotating-window slot; if full, roll left then write the last row
            slot = jnp.minimum(pos, win - 1)
            def roll_if_full(c):
                rolled = jnp.roll(c, -1, axis=1)
                return jnp.where((pos >= win)[:, None, None, None], rolled, c)

            kv = {k: roll_if_full(v) for k, v in kv.items()}
            attn_out, kv = L.attention_decode(
                lp["attn"], h, kv,
                pos=pos, rope_theta=cfg.rope_theta,
                slot=slot, valid_len=jnp.minimum(pos + 1, win),
            )
            new_A.append(kv)
            h = attn_out
        else:
            st = {
                "conv": cache["R"]["conv"][idx["R"]],
                "lru": cache["R"]["lru"][idx["R"]],
            }
            h, st = recurrent_block_step(lp, h, st)
            new_conv.append(st["conv"])
            new_lru.append(st["lru"])
        x = x + h
        h = L.rms_norm(lp["ln2"], x)
        x = x + L.geglu(lp["mlp"], h)
        idx[kind] += 1

    new_cache = {
        "A": jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *new_A)
        if new_A else cache["A"],
        "R": {"conv": jnp.stack(new_conv), "lru": jnp.stack(new_lru)}
        if new_conv else cache["R"],
    }
    from repro.models.dense import _final

    return _final(params, x, cfg), new_cache
