"""RWKV-6 "Finch": attention-free LM with data-dependent decay (arXiv:2404.05892).

Per-layer time-mixing with matrix-valued state S in R^{H x D x D} (H heads, D=64):

    w_t = exp(-exp(w0 + tanh(x_t A_w) B_w))            (data-dependent decay)
    out_t = r_t . (S_{t-1} + (u k_t^T) v_t)            (bonus term u for current tok)
    S_t   = diag(w_t) S_{t-1} + k_t^T v_t

Token-shift mixing (lerp of x_t and x_{t-1}) for r/k/v/g/w; output head-wise
GroupNorm and SiLU(g) gating.  Channel-mixing is the squared-ReLU MLP.  O(1)-state
decode => runs long_500k.  Training uses a chunked scan (Pallas kernel) or a
lax.scan reference.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import with_logical_constraint as wlc
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.param import (
    ParamBuilder, build, normal_init, ones_init, scaled_init, stacked, zeros_init,
)

PyTree = Any


# ---------------------------------------------------------------------------
# WKV6 recurrence
# ---------------------------------------------------------------------------


def wkv6_ref(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
    s0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Reference scan.  r/k/v/w: (B, S, H, D); u: (H, D).

    Returns out: (B, S, H, D) and final state (B, H, D, D).
    State recurrence: S_t = diag(w_t) S_{t-1} + k_t outer v_t;
    out_t = r_t @ (S_{t-1} + diag(u) k_t outer v_t).
    """
    B, S, H, D = r.shape
    s = jnp.zeros((B, H, D, D), jnp.float32) if s0 is None else s0.astype(jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs  # (B, H, D)
        kv = kt[..., :, None] * vt[..., None, :]          # (B, H, D, D)
        out = jnp.einsum("bhd,bhde->bhe", rt, s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    xs = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w)
    )  # (S, B, H, D)
    s, outs = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), s


def wkv6_step(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
    s: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. r/k/v/w: (B, H, D); s: (B, H, D, D)."""
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    sf = s.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    out = jnp.einsum("bhd,bhde->bhe", rf, sf + u[..., :, None] * kv)
    s_new = wf[..., :, None] * sf + kv
    return out.astype(r.dtype), s_new


# ---------------------------------------------------------------------------
# Time mixing
# ---------------------------------------------------------------------------


def init_time_mix(b, cfg: ModelConfig):
    d = cfg.d_model
    la = cfg.decay_lora
    s = b.scope("tmix")
    for nm in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w"):
        s.param(nm, (d,), ("lru",), init=normal_init(0.02))
    s.param("wr", (d, d), ("embed", "lru"), init=scaled_init(0))
    s.param("wk", (d, d), ("embed", "lru"), init=scaled_init(0))
    s.param("wv", (d, d), ("embed", "lru"), init=scaled_init(0))
    s.param("wg", (d, d), ("embed", "lru"), init=scaled_init(0))
    s.param("wo", (d, d), ("lru", "embed"), init=scaled_init(0))
    # data-dependent decay LoRA
    s.param("w0", (d,), ("lru",), init=normal_init(0.5))
    s.param("wa", (d, la), ("embed", None), init=scaled_init(0))
    s.param("wb", (la, d), (None, "lru"), init=zeros_init())
    # per-head bonus
    s.param("u", (d,), ("lru",), init=normal_init(0.5))
    # head-wise group norm
    s.param("gn_scale", (d,), ("lru",), init=ones_init())
    s.param("gn_bias", (d,), ("lru",), init=zeros_init())


def _token_shift(x: jax.Array, x_prev: Optional[jax.Array] = None) -> jax.Array:
    """Returns x_{t-1}; for the first token uses x_prev (decode) or zeros."""
    if x_prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _heads(x: jax.Array, hd: int) -> jax.Array:
    B, S, d = x.shape
    return x.reshape(B, S, d // hd, hd)


def _group_norm(p: Dict, x: jax.Array, hd: int, eps: float = 64e-5) -> jax.Array:
    """Head-wise group norm over (..., H, D) flattened back to channels."""
    B, S, H, D = x.shape
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(B, S, H * D)
    return (y * p["gn_scale"].astype(jnp.float32)
            + p["gn_bias"].astype(jnp.float32)).astype(x.dtype)


def time_mix(
    p: Dict, x: jax.Array, cfg: ModelConfig,
    state: Optional[Dict] = None,
    return_state: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, d).  state (decode): {"shift": (B, d), "wkv": (B, H, D, D)}.

    ``return_state=True`` on the full-sequence path returns the decode-ready
    state after the last position (prefill)."""
    hd = cfg.rwkv_head_dim
    H = cfg.d_model // hd
    xp = _token_shift(x, state["shift"] if state else None)

    def mix(mu):
        return x + (xp - x) * jax.nn.sigmoid(mu.astype(x.dtype))

    r = mix(p["mu_r"]) @ p["wr"].astype(x.dtype)
    k = mix(p["mu_k"]) @ p["wk"].astype(x.dtype)
    v = mix(p["mu_v"]) @ p["wv"].astype(x.dtype)
    g = mix(p["mu_g"]) @ p["wg"].astype(x.dtype)
    xw = mix(p["mu_w"])
    decay_in = jnp.tanh(xw @ p["wa"].astype(x.dtype)) @ p["wb"].astype(x.dtype)
    w = jnp.exp(
        -jnp.exp(
            jnp.clip(p["w0"].astype(jnp.float32) + decay_in.astype(jnp.float32),
                     -10.0, 5.0)
        )
    )                                                   # (B, S, d) in (0,1)

    r4, k4, v4, w4 = (_heads(t, hd) for t in (r, k, v, w.astype(x.dtype)))
    u = p["u"].astype(jnp.float32).reshape(H, hd)

    from repro.models.layers import FLAGS

    if state is None:
        precision = cfg.train_precision
        if precision == "bf16":
            r4, k4, v4 = (t.astype(jnp.bfloat16) for t in (r4, k4, v4))
        if precision == "int8-fused":
            from repro.kernels import ops as kops

            # r/k/v stream through the kernel as int8 + per-row scales; the
            # decay w stays f32 (its log-cumsum is the overflow-safety math)
            out, _s = kops.rwkv6_scan_q8(
                r4, k4, v4, w4, u,
                interpret=FLAGS.pallas_interpret, use_kernel=FLAGS.use_pallas,
            )
        elif FLAGS.use_pallas:
            from repro.kernels import ops as kops

            out, _s = kops.rwkv6_scan(
                r4, k4, v4, w4, u, interpret=FLAGS.pallas_interpret
            )
        else:
            out, _s = wkv6_ref(r4, k4, v4, w4, u)
        new_state = {"shift": x[:, -1], "wkv": _s} if return_state else None
    else:
        out, s_new = wkv6_step(
            r4[:, 0], k4[:, 0], v4[:, 0], w4[:, 0], u, state["wkv"]
        )
        out = out[:, None]
        new_state = {"shift": x[:, -1], "wkv": s_new}

    out = _group_norm(p, out, hd)
    out = out * jax.nn.silu(g)
    out = wlc(out, "batch", "seq", "act_mlp")
    return out @ p["wo"].astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Channel mixing
# ---------------------------------------------------------------------------


def init_channel_mix(b, cfg: ModelConfig):
    s = b.scope("cmix")
    s.param("mu_r", (cfg.d_model,), ("lru",), init=normal_init(0.02))
    s.param("mu_k", (cfg.d_model,), ("lru",), init=normal_init(0.02))
    s.param("wr", (cfg.d_model, cfg.d_model), ("embed", "lru"), init=scaled_init(0))
    s.param("wk", (cfg.d_model, cfg.d_ff), ("embed", "mlp"), init=scaled_init(0))
    s.param("wv", (cfg.d_ff, cfg.d_model), ("mlp", "embed"), init=scaled_init(0))


def channel_mix(
    p: Dict, x: jax.Array, state: Optional[Dict] = None
) -> Tuple[jax.Array, Optional[Dict]]:
    xp = _token_shift(x, state["shift"] if state else None)

    def mix(mu):
        return x + (xp - x) * jax.nn.sigmoid(mu.astype(x.dtype))

    r = jax.nn.sigmoid(mix(p["mu_r"]) @ p["wr"].astype(x.dtype))
    k = mix(p["mu_k"]) @ p["wk"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(k))
    k = wlc(k, "batch", "seq", "act_mlp")
    out = r * (k @ p["wv"].astype(x.dtype))
    new_state = {"shift": x[:, -1]} if state is not None else None
    return out, new_state


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def _init_block(s, cfg: ModelConfig):
    L.init_layernorm(s, "ln1", cfg.d_model)
    init_time_mix(s, cfg)
    L.init_layernorm(s, "ln2", cfg.d_model)
    init_channel_mix(s, cfg)


def init_params(cfg: ModelConfig, key=None, abstract=False, dtype=None):
    dtype = dtype or cfg.dtype

    def f(b: ParamBuilder):
        L.init_embedding(b, "embedding", cfg.vocab, cfg.d_model)
        L.init_layernorm(b, "ln0", cfg.d_model)
        _init_block(stacked(b, cfg.n_layers).scope("blocks"), cfg)
        L.init_layernorm(b, "ln_f", cfg.d_model)
        if not cfg.tie_embeddings:
            L.init_embedding(b, "lm_head", cfg.vocab, cfg.d_model)

    return build(f, key=key, abstract=abstract, dtype=dtype)


def _block_train(lp, x, cfg: ModelConfig):
    h, _ = time_mix(lp["tmix"], L.layer_norm(lp["ln1"], x), cfg)
    x = x + h
    h, _ = channel_mix(lp["cmix"], L.layer_norm(lp["ln2"], x))
    return x + h


def forward(params, cfg: ModelConfig, tokens, **_) -> jax.Array:
    x = L.embed(params["embedding"], tokens, cfg.dtype)
    x = L.layer_norm(params["ln0"], x)

    def body(lp, h):
        return _block_train(lp, h, cfg)

    fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, lp: (fn(lp, c), None), x, params["blocks"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            x = fn(lp, x)
    x = L.layer_norm(params["ln_f"], x)
    head = params["embedding"] if cfg.tie_embeddings else params["lm_head"]
    return L.logits(head, x)


def prefill(params, cfg: ModelConfig, tokens, cache_len: int = 0, **_):
    """Run the prompt; return (last-position logits, O(1) recurrent state)."""
    x = L.embed(params["embedding"], tokens, cfg.dtype)
    x = L.layer_norm(params["ln0"], x)

    tshift, cshift, wkv = [], [], []
    for i in range(cfg.n_layers):
        lp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
        xn = L.layer_norm(lp["ln1"], x)
        t_out, st = time_mix(lp["tmix"], xn, cfg, return_state=True)
        tshift.append(st["shift"])
        wkv.append(st["wkv"])
        x = x + t_out
        xn = L.layer_norm(lp["ln2"], x)
        cshift.append(xn[:, -1])
        c_out, _ = channel_mix(lp["cmix"], xn)
        x = x + c_out
    x = L.layer_norm(params["ln_f"], x)
    head = params["embedding"] if cfg.tie_embeddings else params["lm_head"]
    cache = {
        "tshift": jnp.stack(tshift),
        "cshift": jnp.stack(cshift),
        "wkv": jnp.stack(wkv),
    }
    return L.logits(head, x[:, -1:]), cache


def init_cache(cfg: ModelConfig, batch: int, cache_len: int = 0, dtype=None):
    """RWKV state is O(1) in sequence length (cache_len unused)."""
    dtype = dtype or cfg.dtype
    hd = cfg.rwkv_head_dim
    H = cfg.d_model // hd
    Ln = cfg.n_layers
    return {
        "tshift": jnp.zeros((Ln, batch, cfg.d_model), dtype),
        "cshift": jnp.zeros((Ln, batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((Ln, batch, H, hd, hd), jnp.float32),
    }


def cache_logical_axes(cfg: ModelConfig):
    return {
        "tshift": ("layers", "batch", "lru"),
        "cshift": ("layers", "batch", "lru"),
        "wkv": ("layers", "batch", "lru", None, None),
    }


def decode_step(params, cfg: ModelConfig, token, cache, pos):
    x = L.embed(params["embedding"], token, cfg.dtype)
    x = L.layer_norm(params["ln0"], x)

    def body(h, xs):
        lp, st = xs
        t_out, t_state = time_mix(
            lp["tmix"], L.layer_norm(lp["ln1"], h), cfg,
            state={"shift": st["tshift"], "wkv": st["wkv"]},
        )
        h = h + t_out
        c_out, c_state = channel_mix(
            lp["cmix"], L.layer_norm(lp["ln2"], h), state={"shift": st["cshift"]}
        )
        h = h + c_out
        return h, {
            "tshift": t_state["shift"],
            "cshift": c_state["shift"],
            "wkv": t_state["wkv"],
        }

    from repro.models.dense import _maybe_unrolled_scan

    x, new_cache = _maybe_unrolled_scan(cfg, body, x, (params["blocks"], cache))
    x = L.layer_norm(params["ln_f"], x)
    head = params["embedding"] if cfg.tie_embeddings else params["lm_head"]
    return L.logits(head, x), new_cache
