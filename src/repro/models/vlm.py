"""Qwen2-VL backbone: dense llama-style decoder with M-RoPE (arXiv:2409.12191).

The vision patch frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (B, n_patches, d_model) which are concatenated before
the text-token embeddings.  M-RoPE splits head_dim/2 frequency pairs into
(temporal, height, width) sections — config sections (16, 24, 24) for head_dim 128.

M-RoPE position ids: text tokens advance all three streams together; vision patches
advance height/width over the (stub) patch grid at a fixed temporal position.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import dense, layers as L
from repro.models.config import ModelConfig

PyTree = Any


def init_params(cfg: ModelConfig, key=None, abstract=False, dtype=None):
    return dense.init_params(cfg, key=key, abstract=abstract, dtype=dtype)


def mrope_positions(cfg: ModelConfig, batch: int, seq: int,
                    grid: Optional[int] = None,
                    n_vis: Optional[int] = None) -> jax.Array:
    """Build (3, B, S) position ids: vision prefix (t fixed; h/w over grid) then text."""
    n_vis = min(cfg.n_vision_patches, seq) if n_vis is None else n_vis
    grid = grid or max(1, int(n_vis ** 0.5))
    i = jnp.arange(seq)
    is_vis = i < n_vis
    h_pos = jnp.where(is_vis, i // grid, 0)
    w_pos = jnp.where(is_vis, i % grid, 0)
    # text positions continue from the max vision position
    start = jnp.maximum(grid, 1)
    t_pos = jnp.where(is_vis, 0, i - n_vis + start)
    h = jnp.where(is_vis, h_pos, t_pos)
    w = jnp.where(is_vis, w_pos, t_pos)
    pos3 = jnp.stack([jnp.where(is_vis, 0, t_pos), h, w])     # (3, S)
    return jnp.broadcast_to(pos3[:, None], (3, batch, seq))


def forward(params, cfg: ModelConfig, tokens: jax.Array, *,
            patch_embeds: Optional[jax.Array] = None, **_) -> jax.Array:
    """tokens: (B, S_text); patch_embeds: (B, n_patches, d) stub frontend output.

    Total sequence = [patches | text]; logits returned for all positions.
    """
    if patch_embeds is not None:
        text = L.embed(params["embedding"], tokens, cfg.dtype)
        x = jnp.concatenate([patch_embeds.astype(cfg.dtype), text], axis=1)
        n_vis = patch_embeds.shape[1]
    else:
        x = L.embed(params["embedding"], tokens, cfg.dtype)
        n_vis = None
    B, S = x.shape[0], x.shape[1]
    mpos = mrope_positions(cfg, B, S, n_vis=n_vis)
    return dense.forward(
        params, cfg, tokens, inputs_embeds=x, mrope_positions=mpos
    )


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    cache = dense.init_cache(cfg, batch, cache_len, dtype)
    # rope position of text token at sequence index i is i + mrope_offset
    cache["mrope_offset"] = jnp.zeros((batch,), jnp.int32)
    return cache


def cache_logical_axes(cfg: ModelConfig):
    axes = dense.cache_logical_axes(cfg)
    axes["mrope_offset"] = ("batch",)
    return axes


def decode_step(params, cfg: ModelConfig, token, cache, pos):
    # Past the multimodal prefix, all three M-RoPE streams advance together,
    # so text decode is EXACT standard RoPE at the M-RoPE text position
    # pos + offset (offset = grid_start - n_vis, carried in the cache).
    offset = cache["mrope_offset"]
    # pass every KV leaf through (2-leaf native or 4-leaf int8 + scales)
    kv = {k: v for k, v in cache.items() if k != "mrope_offset"}
    logits, kv = dense.decode_step(params, cfg, token, kv, pos,
                                   rope_offset=offset)
    kv["mrope_offset"] = offset
    return logits, kv


def prefill(params, cfg: ModelConfig, tokens, cache_len: int, *,
            patch_embeds: Optional[jax.Array] = None, **_):
    """Multimodal prefill: [patches | text] with M-RoPE phases in the cache."""
    if patch_embeds is not None:
        text = L.embed(params["embedding"], tokens, cfg.dtype)
        x = jnp.concatenate([patch_embeds.astype(cfg.dtype), text], axis=1)
        n_vis = patch_embeds.shape[1]
    else:
        x = L.embed(params["embedding"], tokens, cfg.dtype)
        n_vis = min(cfg.n_vision_patches, x.shape[1])
    B, S = x.shape[0], x.shape[1]
    mpos = mrope_positions(cfg, B, S, n_vis=n_vis)
    logits, cache = dense.prefill(
        params, cfg, tokens, cache_len, inputs_embeds=x, mrope_positions=mpos
    )
    grid = max(1, int(n_vis ** 0.5))
    start = max(grid, 1)
    cache["mrope_offset"] = jnp.full((B,), start - n_vis, jnp.int32)
    return logits, cache
