"""Model registry: one uniform facade over every architecture family.

``get_model(cfg)`` returns a :class:`Model` whose methods dispatch to the family
module.  All entry points are pure functions of (params, inputs) so they can be
jit/pjit'd by the callers in :mod:`repro.train` and :mod:`repro.launch`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.models.config import ModelConfig

PyTree = Any

_FAMILIES: Dict[str, Any] = {}


def _family(name: str):
    if name not in _FAMILIES:
        import importlib

        mod = {
            "dense": "repro.models.dense",
            "moe": "repro.models.moe",
            "rglru": "repro.models.rglru",
            "rwkv6": "repro.models.rwkv6",
            "encdec": "repro.models.encdec",
            "vlm": "repro.models.vlm",
        }[name]
        _FAMILIES[name] = importlib.import_module(mod)
    return _FAMILIES[name]


@dataclasses.dataclass(frozen=True)
class Model:
    """Uniform interface. ``forward`` returns (logits, aux_loss)."""

    cfg: ModelConfig
    mod: Any

    # -- params ---------------------------------------------------------------
    def init_params(self, key=None, abstract: bool = False, dtype=None):
        return self.mod.init_params(self.cfg, key=key, abstract=abstract, dtype=dtype)

    # -- compute --------------------------------------------------------------
    def forward(self, params, tokens, **inputs) -> Tuple[jax.Array, jax.Array]:
        out = self.mod.forward(params, self.cfg, tokens, **inputs)
        if isinstance(out, tuple):
            return out
        import jax.numpy as jnp

        return out, jnp.zeros((), jnp.float32)

    def decode_step(self, params, token, cache, pos):
        return self.mod.decode_step(params, self.cfg, token, cache, pos)

    def prefill(self, params, tokens, cache_len: int, **inputs):
        return self.mod.prefill(params, self.cfg, tokens, cache_len, **inputs)

    # -- caches ---------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int, dtype=None):
        return self.mod.init_cache(self.cfg, batch, cache_len, dtype=dtype)

    def cache_logical_axes(self):
        return self.mod.cache_logical_axes(self.cfg)

    def abstract_cache(self, batch: int, cache_len: int, dtype=None):
        """ShapeDtypeStruct cache (dry-run, no allocation)."""
        fn = lambda: self.init_cache(batch, cache_len, dtype=dtype)
        return jax.eval_shape(fn)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-DEC)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs run the long_500k shape."""
        return self.cfg.family in ("rglru", "rwkv6")


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg, mod=_family(cfg.family))
