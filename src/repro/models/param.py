"""Parameter builder: one code path yields concrete params, abstract shapes, and
logical-axis annotations.

Every model in ``repro.models`` creates its parameters through a :class:`ParamBuilder`.
The builder runs in one of two modes:

* ``concrete`` — leaves are real ``jnp`` arrays (used by smoke tests / real training).
* ``abstract`` — leaves are ``jax.ShapeDtypeStruct`` (used by the multi-pod dry-run;
  no device memory is ever allocated).

In both modes the builder records a parallel pytree of *logical axis names* per leaf
(e.g. ``("layers", "embed", "mlp")``).  ``repro.distributed.sharding`` maps logical
axes onto mesh axes with a per-arch rule table, producing the ``PartitionSpec`` trees
consumed by ``jax.jit(in_shardings=...)``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# ---------------------------------------------------------------------------
# Initializers (shape, dtype, key) -> array
# ---------------------------------------------------------------------------


def normal_init(stddev: float = 0.02) -> Callable:
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)

    return init


def scaled_init(fan_in_axis: int = -2) -> Callable:
    """LeCun-style 1/sqrt(fan_in) initializer (fan-in read from shape)."""

    def init(key, shape, dtype):
        fan_in = shape[fan_in_axis] if len(shape) >= 2 else shape[-1]
        std = 1.0 / math.sqrt(max(1, fan_in))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

    return init


def zeros_init() -> Callable:
    def init(key, shape, dtype):
        return jnp.zeros(shape, dtype)

    return init


def ones_init() -> Callable:
    def init(key, shape, dtype):
        return jnp.ones(shape, dtype)

    return init


def constant_init(value: float) -> Callable:
    def init(key, shape, dtype):
        return jnp.full(shape, value, dtype)

    return init


def uniform_init(lo: float, hi: float) -> Callable:
    def init(key, shape, dtype):
        return jax.random.uniform(
            key, shape, jnp.float32, minval=lo, maxval=hi
        ).astype(dtype)

    return init


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ParamBuilder:
    """Collects parameters into a nested-dict pytree with logical axis metadata."""

    key: Optional[jax.Array]
    abstract: bool
    dtype: Any
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    axes: Dict[str, Any] = dataclasses.field(default_factory=dict)
    _counter: int = 0

    # -- scoping ------------------------------------------------------------
    def scope(self, name: str) -> "ParamBuilder":
        child = ParamBuilder(key=self.key, abstract=self.abstract, dtype=self.dtype)
        self.params[name] = child.params
        self.axes[name] = child.axes
        # Children share the parent key; uniqueness comes from fold_in counters.
        child._parent = self  # type: ignore[attr-defined]
        return child

    def _next_key(self) -> Optional[jax.Array]:
        root = self
        while getattr(root, "_parent", None) is not None:
            root = root._parent  # type: ignore[attr-defined]
        root._counter += 1
        if root.key is None:
            return None
        return jax.random.fold_in(root.key, root._counter)

    # -- parameter creation ---------------------------------------------------
    def param(
        self,
        name: str,
        shape: Sequence[int],
        axes: Tuple[Optional[str], ...],
        init: Optional[Callable] = None,
        dtype: Any = None,
    ):
        assert len(shape) == len(axes), (name, shape, axes)
        dtype = dtype or self.dtype
        if self.abstract:
            leaf = jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)
        else:
            init = init or normal_init()
            leaf = init(self._next_key(), tuple(int(s) for s in shape), dtype)
        self.params[name] = leaf
        self.axes[name] = tuple(axes)
        return leaf


class StackedBuilder:
    """View over a ParamBuilder that prepends a stacked-layer dim to every param.

    Used for scan-over-layers models: all per-layer params get shape ``(L, ...)``
    and logical axes ``("layers", ...)``.
    """

    def __init__(self, inner, n: int):
        self._inner = inner
        self._n = n

    def scope(self, name: str) -> "StackedBuilder":
        return StackedBuilder(self._inner.scope(name), self._n)

    def param(self, name, shape, axes, init=None, dtype=None):
        return self._inner.param(
            name, (self._n, *shape), ("layers", *axes), init=init, dtype=dtype
        )


def stacked(b, n: int) -> StackedBuilder:
    return StackedBuilder(b, n)


def build(
    fn: Callable[[ParamBuilder], None],
    *,
    key: Optional[jax.Array] = None,
    abstract: bool = False,
    dtype: Any = jnp.float32,
) -> Tuple[PyTree, PyTree]:
    """Run ``fn(builder)`` and return ``(params, logical_axes)`` pytrees."""
    b = ParamBuilder(key=key, abstract=abstract, dtype=dtype)
    fn(b)
    return b.params, b.axes


def count_params(params: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(params)
    return int(sum(int(np.prod(l.shape)) for l in leaves))


def param_bytes(params: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(params)
    return int(sum(int(np.prod(l.shape)) * l.dtype.itemsize for l in leaves))
