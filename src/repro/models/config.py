"""Shared model configuration dataclass for every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "model"
    family: str = "dense"            # dense | moe | rglru | rwkv6 | encdec | vlm
    modality: str = "text"           # text | audio | vision

    # transformer dims
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: Optional[int] = None   # default: d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    mlp: str = "swiglu"              # swiglu | gelu | geglu
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # fused Pallas dispatch+expert-GEMM kernel for the single-program path
    # (the group-local EP path takes precedence under a >1 "model" mesh)
    fused_moe: bool = True

    # hybrid / recurrent (RecurrentGemma)
    block_pattern: Tuple[str, ...] = ()   # cycle of "R" (recurrent) / "A" (attention)
    window: Optional[int] = None          # local attention window
    lru_width: Optional[int] = None
    conv_width: int = 4

    # rwkv
    rwkv_head_dim: int = 64
    decay_lora: int = 64

    # enc-dec (Whisper)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    n_frames: int = 1500              # encoder positions (stubbed conv frontend)

    # vlm (Qwen2-VL)
    mrope_sections: Tuple[int, ...] = ()
    n_vision_patches: int = 0         # stubbed patch-embedding prefix length

    # numerics / structure
    dtype: Any = jnp.float32
    # "native" keeps the decode KV cache in `dtype`; "int8" stores per-row
    # symmetric int8 + f32 scales and dequantizes inside the decode kernel
    kv_cache_dtype: str = "native"
    # training hot-loop precision:
    #   "f32"        — kernels stream activations at the model dtype
    #   "bf16"       — attention/scan operands cast to bf16 before the kernel
    #   "int8-fused" — K/V and scan activations quantized per-row to int8,
    #                  dequantized inside the Pallas sweep (f32 accumulation),
    #                  and saved-for-backward residuals kept as int8 + scales
    train_precision: str = "f32"
    remat: bool = True
    scan_layers: bool = True
    fsdp: bool = False                # ZeRO-3-style extra sharding over "data"
    logit_softcap: Optional[float] = None

    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- analytic parameter counts (for roofline MODEL_FLOPS = 6·N·D) --------
    def param_count(self, active_only: bool = False) -> int:
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim()
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d

        if self.family == "moe":
            e = self.experts_per_token if active_only else self.n_experts
            mlp_p = 3 * d * ff * e + d * self.n_experts  # experts + router
            per_layer = attn + mlp_p
            n = self.n_layers * per_layer
        elif self.family == "rglru":
            lw = self.lru_width or d
            rec = 2 * d * lw + lw * d + self.conv_width * lw + 3 * lw  # in/out + conv + gates
            mlp_p = 3 * d * ff
            n_att = sum(1 for i in range(self.n_layers)
                        if self.block_pattern[i % len(self.block_pattern)] == "A")
            n_rec = self.n_layers - n_att
            n = n_att * (attn + mlp_p) + n_rec * (rec + mlp_p)
        elif self.family == "rwkv6":
            heads = d // self.rwkv_head_dim
            tm = 6 * d * d + 2 * self.decay_lora * d + heads * self.rwkv_head_dim
            cm = 2 * d * ff
            n = self.n_layers * (tm + cm)
        elif self.family == "encdec":
            enc = self.n_enc_layers * (attn + 2 * d * ff)
            dec = self.n_dec_layers * (2 * attn + 2 * d * ff)
            n = enc + dec
        else:  # dense / vlm
            mlp_p = 3 * d * ff if self.mlp in ("swiglu", "geglu") else 2 * d * ff
            n = self.n_layers * (attn + mlp_p)
        n += V * d  # embedding
        if not self.tie_embeddings and self.family != "encdec":
            n += V * d  # untied lm head
        return int(n)
