"""Whisper-style encoder-decoder transformer (whisper-medium backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, n_frames, d_model).  Encoder uses sinusoidal
positions and bidirectional attention; decoder uses learned positions, causal
self-attention + cross-attention.  LayerNorm + GELU MLP with biases (Whisper
convention), pre-norm.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.param import ParamBuilder, build, normal_init, stacked

PyTree = Any


def sinusoids(length: int, channels: int) -> jax.Array:
    log_timescale = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2, dtype=jnp.float32))
    t = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


def _init_enc_layer(s, cfg: ModelConfig):
    hd = cfg.resolved_head_dim()
    L.init_layernorm(s, "ln1", cfg.d_model)
    L.init_attention(s, "attn", cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd,
                     qkv_bias=True)
    L.init_layernorm(s, "ln2", cfg.d_model)
    L.init_gelu_mlp(s, "mlp", cfg.d_model, cfg.d_ff, bias=True)


def _init_dec_layer(s, cfg: ModelConfig):
    hd = cfg.resolved_head_dim()
    L.init_layernorm(s, "ln1", cfg.d_model)
    L.init_attention(s, "self_attn", cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd,
                     qkv_bias=True)
    L.init_layernorm(s, "ln_x", cfg.d_model)
    L.init_attention(s, "cross_attn", cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd,
                     qkv_bias=True)
    L.init_layernorm(s, "ln2", cfg.d_model)
    L.init_gelu_mlp(s, "mlp", cfg.d_model, cfg.d_ff, bias=True)


def init_params(cfg: ModelConfig, key=None, abstract=False, dtype=None,
                max_dec_len: int = 448):
    dtype = dtype or cfg.dtype

    def f(b: ParamBuilder):
        L.init_embedding(b, "embedding", cfg.vocab, cfg.d_model)
        b.param("dec_pos", (max_dec_len, cfg.d_model), ("pos", "embed"),
                init=normal_init(0.01))
        _init_enc_layer(stacked(b, cfg.n_enc_layers).scope("enc_blocks"), cfg)
        L.init_layernorm(b, "ln_enc", cfg.d_model)
        _init_dec_layer(stacked(b, cfg.n_dec_layers).scope("dec_blocks"), cfg)
        L.init_layernorm(b, "ln_dec", cfg.d_model)

    return build(f, key=key, abstract=abstract, dtype=dtype)


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, n_frames, d_model) stubbed conv-frontend output."""
    x = frames.astype(cfg.dtype) + sinusoids(
        frames.shape[1], cfg.d_model
    ).astype(cfg.dtype)
    positions = jnp.arange(x.shape[1])

    def body(lp, h):
        a = L.attention_train(
            lp["attn"], L.layer_norm(lp["ln1"], h),
            positions=positions, causal=False, use_rope=False,
            precision=cfg.train_precision,
        )
        h = h + a
        return h + L.gelu_mlp(lp["mlp"], L.layer_norm(lp["ln2"], h))

    fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, lp: (fn(lp, c), None), x, params["enc_blocks"])
    else:
        for i in range(cfg.n_enc_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["enc_blocks"])
            x = fn(lp, x)
    return L.layer_norm(params["ln_enc"], x)


# ---------------------------------------------------------------------------
# Decoder (train)
# ---------------------------------------------------------------------------


def _dec_positions(params, S: int, offset=0):
    table = params["dec_pos"]
    maxlen = table.shape[0]
    idx = jnp.minimum(jnp.arange(S) + offset, maxlen - 1)
    return table[idx]


def decode_train(params, cfg: ModelConfig, tokens: jax.Array,
                 enc_out: jax.Array) -> jax.Array:
    x = L.embed(params["embedding"], tokens, cfg.dtype)
    x = x + _dec_positions(params, tokens.shape[1]).astype(cfg.dtype)
    positions = jnp.arange(tokens.shape[1])

    def body(lp, h):
        a = L.attention_train(
            lp["self_attn"], L.layer_norm(lp["ln1"], h),
            positions=positions, causal=True, use_rope=False,
            precision=cfg.train_precision,
        )
        h = h + a
        ck, cv = L.cross_kv(lp["cross_attn"], enc_out)
        h = h + L.cross_attention(lp["cross_attn"], L.layer_norm(lp["ln_x"], h), ck, cv)
        return h + L.gelu_mlp(lp["mlp"], L.layer_norm(lp["ln2"], h))

    fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, lp: (fn(lp, c), None), x, params["dec_blocks"])
    else:
        for i in range(cfg.n_dec_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["dec_blocks"])
            x = fn(lp, x)
    x = L.layer_norm(params["ln_dec"], x)
    return L.logits(params["embedding"], x)


def forward(params, cfg: ModelConfig, tokens, *, frames=None, **_) -> jax.Array:
    """Full enc-dec training forward."""
    enc_out = encode(params, cfg, frames)
    return decode_train(params, cfg, tokens, enc_out)


# ---------------------------------------------------------------------------
# Decoder (serve): self-KV cache + precomputed cross-KV
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    hd = cfg.resolved_head_dim()
    Ln = cfg.n_dec_layers
    kv_shape = (Ln, batch, cache_len, cfg.n_kv_heads, hd)
    x_shape = (Ln, batch, cfg.n_frames, cfg.n_kv_heads, hd)
    if cfg.kv_cache_dtype == "int8":
        # self-attn KV and the (large, static) cross-attn KV both store
        # per-row symmetric int8 + f32 scale columns
        return {
            "k": jnp.zeros(kv_shape, jnp.int8),
            "k_scale": jnp.zeros(kv_shape[:-1] + (1,), jnp.float32),
            "v": jnp.zeros(kv_shape, jnp.int8),
            "v_scale": jnp.zeros(kv_shape[:-1] + (1,), jnp.float32),
            "xk": jnp.zeros(x_shape, jnp.int8),
            "xk_scale": jnp.zeros(x_shape[:-1] + (1,), jnp.float32),
            "xv": jnp.zeros(x_shape, jnp.int8),
            "xv_scale": jnp.zeros(x_shape[:-1] + (1,), jnp.float32),
        }
    return {
        "k": jnp.zeros(kv_shape, dtype),
        "v": jnp.zeros(kv_shape, dtype),
        "xk": jnp.zeros(x_shape, dtype),
        "xv": jnp.zeros(x_shape, dtype),
    }


def cache_logical_axes(cfg: ModelConfig):
    kv = ("layers", "batch", "kv_seq", "act_kv_heads", None)
    x = ("layers", "batch", None, "act_kv_heads", None)
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": kv, "k_scale": kv, "v": kv, "v_scale": kv,
            "xk": x, "xk_scale": x, "xv": x, "xv_scale": x,
        }
    return {"k": kv, "v": kv, "xk": x, "xv": x}


def build_cross_cache(params, cfg: ModelConfig, enc_out: jax.Array):
    def per_layer(lp):
        return L.cross_kv(lp["cross_attn"], enc_out)

    xk, xv = jax.lax.map(per_layer, params["dec_blocks"])
    return xk, xv


def prefill(params, cfg: ModelConfig, tokens, cache_len: int, *,
            frames=None, **_):
    """Encode audio frames, run the decoder prompt, return decode-ready cache:
    self-attn KV (padded to cache_len) + per-layer cross-attn KV."""
    enc_out = encode(params, cfg, frames)
    x = L.embed(params["embedding"], tokens, cfg.dtype)
    x = x + _dec_positions(params, tokens.shape[1]).astype(cfg.dtype)
    positions = jnp.arange(tokens.shape[1])

    kvs, crosses = [], []
    n = cfg.n_dec_layers
    for i in range(n):
        lp = jax.tree_util.tree_map(lambda a: a[i], params["dec_blocks"])
        a, kv = L.attention_prefill(
            lp["self_attn"], L.layer_norm(lp["ln1"], x),
            positions=positions, cache_len=cache_len, causal=True,
            use_rope=False, kv_cache_dtype=cfg.kv_cache_dtype,
        )
        x = x + a
        ck, cv = L.cross_kv(lp["cross_attn"], enc_out)
        x = x + L.cross_attention(
            lp["cross_attn"], L.layer_norm(lp["ln_x"], x), ck, cv
        )
        x = x + L.gelu_mlp(lp["mlp"], L.layer_norm(lp["ln2"], x))
        kvs.append(kv)
        if cfg.kv_cache_dtype == "int8":
            from repro.kernels import ref as KR

            xkq, xks = KR.quantize_int8_ref(ck)
            xvq, xvs = KR.quantize_int8_ref(cv)
            crosses.append({
                "xk": xkq, "xk_scale": xks, "xv": xvq, "xv_scale": xvs,
            })
        else:
            crosses.append({"xk": ck, "xv": cv})
    x = L.layer_norm(params["ln_dec"], x)
    cache = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *[
        {**kv, **cross} for kv, cross in zip(kvs, crosses)
    ])
    return L.logits(params["embedding"], x[:, -1:]), cache


def decode_step(params, cfg: ModelConfig, token, cache, pos):
    x = L.embed(params["embedding"], token, cfg.dtype)
    pos_emb = params["dec_pos"][
        jnp.minimum(pos, params["dec_pos"].shape[0] - 1)
    ]  # (B, d)
    x = x + pos_emb[:, None].astype(cfg.dtype)

    def body(h, xs):
        lp, kv = xs
        self_kv = {n: kv[n] for n in kv if not n.startswith("x")}
        a, new_kv = L.attention_decode(
            lp["self_attn"], L.layer_norm(lp["ln1"], h),
            self_kv, pos=pos, use_rope=False,
        )
        h = h + a
        if "xk_scale" in kv:
            from repro.kernels import ref as KR

            ck = KR.dequantize_int8_ref(kv["xk"], kv["xk_scale"], cfg.dtype)
            cv = KR.dequantize_int8_ref(kv["xv"], kv["xv_scale"], cfg.dtype)
        else:
            ck, cv = kv["xk"], kv["xv"]
        h = h + L.cross_attention(
            lp["cross_attn"], L.layer_norm(lp["ln_x"], h), ck, cv
        )
        h = h + L.gelu_mlp(lp["mlp"], L.layer_norm(lp["ln2"], h))
        cross = {n: kv[n] for n in kv if n.startswith("x")}
        return h, {**new_kv, **cross}

    from repro.models.dense import _maybe_unrolled_scan

    x, new_cache = _maybe_unrolled_scan(cfg, body, x, (params["dec_blocks"], cache))
    x = L.layer_norm(params["ln_dec"], x)
    return L.logits(params["embedding"], x), new_cache
