"""Deterministic synthetic data pipeline with private/public partitions.

Plays the role of the paper's TinyImageNet-on-flash: a corpus of token
sequences split into *public* shards (shareable with every worker) and
*private* shards (pinned to a home worker; never materialized elsewhere —
enforced through the :class:`~repro.core.privacy.PlacementManifest`).

Synthetic-but-deterministic: sample ``i`` of shard ``s`` is a pure function of
``(seed, s, i)``, so any worker reproduces ITS shards bit-exactly without any
cross-worker I/O — the in-storage property, minus the flash.  Sequences are
Zipf-distributed token ids with a linear-congruential position mix so the LM
loss actually decreases during the end-to-end example runs.

The batch iterator materializes the Stannis layout directly:
  (global_rows, seq) group-major rows + (global_rows,) validity mask,
with group g's valid rows drawn from g's assigned shards only.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hetero import BatchSchedule
from repro.core.load_balance import EpochPlan
from repro.core.privacy import PlacementManifest, Shard


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2      # token unigram skew


class PrivateShardStore:
    """Per-worker view of the corpus.  The ONLY object that can read a private
    shard is the store constructed with the matching worker id (mirrors the
    paper: only the CSD's ISP engine can see its flash)."""

    def __init__(self, worker: str, shards: Sequence[Shard], cfg: DataConfig):
        self.worker = worker
        self.cfg = cfg
        self._shards = {s.shard_id: s for s in shards}

    def sample(self, shard_id: str, index: int) -> np.ndarray:
        s = self._shards[shard_id]
        if s.private and s.owner != self.worker:
            raise PermissionError(
                f"worker {self.worker!r} cannot read private shard {shard_id!r} "
                f"(owner {s.owner!r})"
            )
        return synth_sequence(self.cfg, shard_id, index)


def _mix(*vals: int) -> np.random.Generator:
    return np.random.default_rng(np.array(vals, np.uint64))


def synth_sequence(cfg: DataConfig, shard_id: str, index: int) -> np.ndarray:
    """Deterministic (seed, shard, index) -> (seq_len+1,) int32 token ids.

    Zipf unigram + LCG positional drift gives learnable low-entropy structure.
    """
    # crc32 (not hash()): stable across processes — workers must agree bit-exactly
    h = zlib.crc32(shard_id.encode()) & 0x7FFFFFFF
    rng = _mix(cfg.seed, h, index)
    z = rng.zipf(cfg.zipf_a, size=cfg.seq_len + 1).astype(np.int64)
    base = z % max(2, cfg.vocab // 4)
    drift = (np.arange(cfg.seq_len + 1, dtype=np.int64) * (h % 97 + 1)) % 13
    return ((base + drift) % cfg.vocab).astype(np.int32)


@dataclasses.dataclass
class StannisDataset:
    """Batch iterator over the Stannis layout for one synchronous step.

    groups: list of (worker_id, batch_size, [(shard_id, n_samples), ...]).
    Yields dicts: tokens (R, S) int32, labels (R, S) int32,
    loss_mask (R, S) f32 with invalid rows zeroed, row_mask (R,) f32.
    """

    cfg: DataConfig
    schedule: BatchSchedule
    group_workers: List[str]
    group_sources: Dict[str, List[Tuple[str, int]]]   # worker -> shard draws
    stores: Dict[str, PrivateShardStore]

    def __post_init__(self):
        self._cursor: Dict[str, int] = {w: 0 for w in self.group_workers}
        # flatten each worker's sample space: (shard_id, index) pairs
        self._space: Dict[str, List[Tuple[str, int]]] = {}
        for w in self.group_workers:
            pairs: List[Tuple[str, int]] = []
            for shard_id, n in self.group_sources.get(w, []):
                pairs.extend((shard_id, i) for i in range(n))
            self._space[w] = pairs

    def rewire(
        self,
        schedule: BatchSchedule,
        group_sources: Dict[str, List[Tuple[str, int]]],
    ) -> None:
        """Re-point the iterator at a re-planned schedule + placement while
        preserving per-worker epoch cursors (an online re-tune must not
        replay already-seen samples)."""
        cursors = dict(self._cursor)
        self.schedule = schedule
        self.group_sources = group_sources
        self.__post_init__()
        for w, c in cursors.items():
            if w in self._cursor and self._space[w]:
                self._cursor[w] = c % len(self._space[w])

    def steps_per_epoch(self) -> int:
        counts = [
            len(self._space[w]) // max(1, b)
            for w, b in zip(self.group_workers, self.schedule.group_batches)
            if b > 0
        ]
        return min(counts) if counts else 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> Dict[str, np.ndarray]:
        R = self.schedule.global_rows
        S = self.cfg.seq_len
        ml = self.schedule.max_local
        tokens = np.zeros((R, S + 1), np.int32)
        row_mask = self.schedule.row_mask()
        for g, (w, b) in enumerate(
            zip(self.group_workers, self.schedule.group_batches)
        ):
            space = self._space[w]
            cur = self._cursor[w]
            store = self.stores[w]
            for r in range(b):
                shard_id, idx = space[(cur + r) % max(1, len(space))]
                tokens[g * ml + r] = store.sample(shard_id, idx)
            self._cursor[w] = (cur + b) % max(1, len(space))
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
            "loss_mask": row_mask[:, None] * np.ones((1, S), np.float32),
            "row_mask": row_mask,
        }


def manifest_sources(
    manifest: PlacementManifest, group_workers: List[str]
) -> Dict[str, List[Tuple[str, int]]]:
    """Per-worker (shard_id, n_samples) draws from a placement manifest."""
    sources: Dict[str, List[Tuple[str, int]]] = {w: [] for w in group_workers}
    for a in manifest.assignments:
        if a.worker in sources:
            sources[a.worker].append((a.shard_id, a.n_samples))
    return sources


def make_stannis_dataset(
    cfg: DataConfig,
    schedule: BatchSchedule,
    group_workers: List[str],
    plan: EpochPlan,
    manifest: PlacementManifest,
    shards: Sequence[Shard],
) -> StannisDataset:
    """Wire the Eq.1 plan + privacy manifest into a batch iterator.

    Each worker's sample sources come from its manifest assignments; duplicated
    private samples (the paper's remedy) appear as a second pass over the same
    shard (indices wrap in ``next_batch``).
    """
    sources = manifest_sources(manifest, group_workers)
    stores = {
        w: PrivateShardStore(w, shards, cfg) for w in group_workers
    }
    return StannisDataset(
        cfg=cfg, schedule=schedule, group_workers=group_workers,
        group_sources=sources, stores=stores,
    )
