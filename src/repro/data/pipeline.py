"""DEPRECATED-in-place: thin compat shim over :mod:`repro.storage`.

The data layer moved into the ``repro.storage`` device-fleet subsystem
(:class:`~repro.storage.StorageDevice` custody + :class:`~repro.storage.DeviceFleet`
registry + three backends).  Every name this module used to define keeps
working and now delegates to the synthetic storage backend:

  * :class:`DataConfig`, :func:`synth_sequence` — canonical definitions now
    live in :mod:`repro.storage.synthetic`; re-exported unchanged.
  * :class:`PrivateShardStore` — a per-worker view backed by one
    :class:`~repro.storage.SyntheticDevice` (same custody semantics: reading
    a private shard from a non-owner raises ``PermissionError``).
  * :class:`StannisDataset` — alias of :class:`~repro.storage.FleetBatcher`.
  * :func:`make_stannis_dataset` — builds a synthetic
    :class:`~repro.storage.DeviceFleet` under the hood.

New code should import from :mod:`repro.storage` directly.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.hetero import BatchSchedule
from repro.core.load_balance import EpochPlan
from repro.core.privacy import PlacementManifest, Shard
from repro.storage.fleet import (
    DeviceFleet, FleetBatcher, make_fleet_batcher, manifest_sources,
)
from repro.storage.synthetic import DataConfig, SyntheticDevice, synth_sequence

__all__ = [
    "DataConfig",
    "PrivateShardStore",
    "StannisDataset",
    "make_stannis_dataset",
    "manifest_sources",
    "synth_sequence",
]

# The batcher IS the old dataset (field-compatible: cfg / schedule /
# group_workers / group_sources / _cursor / rewire / next_batch).
StannisDataset = FleetBatcher


class PrivateShardStore:
    """Per-worker view of the corpus, now one synthetic storage device.

    Kept for the seed API: the ONLY object that can read a private shard is
    the store constructed with the matching worker id (mirrors the paper:
    only the CSD's ISP engine can see its flash).
    """

    def __init__(self, worker: str, shards: Sequence[Shard], cfg: DataConfig):
        self.worker = worker
        self.cfg = cfg
        self._device = SyntheticDevice(worker, cfg)
        self._device.provision(list(shards))

    def sample(self, shard_id: str, index: int) -> np.ndarray:
        return self._device.read(shard_id, index)


def make_stannis_dataset(
    cfg: DataConfig,
    schedule: BatchSchedule,
    group_workers: List[str],
    plan: EpochPlan,
    manifest: PlacementManifest,
    shards: Sequence[Shard],
) -> StannisDataset:
    """Wire the Eq.1 plan + privacy manifest into a batch iterator.

    Seed-compatible constructor: provisions a synthetic device fleet for
    ``group_workers`` and returns the fleet-fed batcher.  Duplicated private
    samples (the paper's remedy) appear as a second pass over the same shard
    (indices wrap in ``next_batch``).
    """
    fleet = DeviceFleet.provision(group_workers, shards, cfg)
    return make_fleet_batcher(cfg, schedule, group_workers, manifest, fleet)
