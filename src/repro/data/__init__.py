from repro.data.pipeline import (
    DataConfig, PrivateShardStore, StannisDataset, make_stannis_dataset,
)

__all__ = ["DataConfig", "PrivateShardStore", "StannisDataset", "make_stannis_dataset"]
