"""Compat namespace: the data layer lives in :mod:`repro.storage` now."""
from repro.data.pipeline import (
    DataConfig, PrivateShardStore, StannisDataset, make_stannis_dataset,
)

__all__ = ["DataConfig", "PrivateShardStore", "StannisDataset", "make_stannis_dataset"]
