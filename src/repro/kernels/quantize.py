"""Int8 gradient quantization with stochastic rounding — the compressed-
allreduce building block (beyond-paper distributed optimization).

Per-row symmetric quantization: scale = absmax / 127.  Stochastic rounding
(floor(x/scale + uniform)) keeps E[q*scale] = x, so momentum-SGD stays
unbiased; the residual (error feedback) is handled by the caller in
:mod:`repro.distributed.allreduce`.

Kernel layout: rows tiled to (block_rows, N) VMEM blocks; absmax reduce and
the scale/round/clip are all VPU element ops — this kernel is purely
bandwidth-bound, which is the point: it converts an ICI-bandwidth-bound
allreduce into a (4x smaller) one at the cost of HBM traffic that overlaps.
The uniform noise is passed in as an operand (generated with the training
PRNG) so the kernel stays deterministic per seed on every backend.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_kernel(x_ref, noise_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                    # (br, N)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    y = x / scale
    q = jnp.floor(y + noise_ref[...].astype(jnp.float32))
    q = jnp.clip(q, -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale


def _quantize_rows(
    x: jax.Array,               # (R, N) float
    noise: jax.Array,           # (R, N) rounding offsets in [0, 1)
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """The one row-quantization core: pad, tile, kernel, un-pad.

    Every int8 producer in the repo funnels through here — the gradient-
    transport flat path, the KV-cache path, and the quantized-training
    residual path — so the rounding semantics (``floor(x/scale + noise)``,
    i.e. round-half-up at ``noise=0.5``) are pinned in exactly one place.
    """
    R, N = x.shape
    assert noise.shape == x.shape, (noise.shape, x.shape)
    # pad-and-mask for any R: the row block is sublane-aligned (multiple of
    # 8, so ragged R also compiles on TPU), rows pad with zeros — per-row
    # scales mean padding never contaminates real rows — and the pad rows
    # are sliced back off below.
    br = min(block_rows, ((R + 7) // 8) * 8)
    pad = (-R) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        noise = jnp.pad(noise, ((0, pad), (0, 0)))
    Rp = x.shape[0]
    q, scale = pl.pallas_call(
        _quant_kernel,
        grid=(Rp // br,),
        in_specs=[
            pl.BlockSpec((br, N), lambda i: (i, 0)),
            pl.BlockSpec((br, N), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, N), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, N), jnp.int8),
            jax.ShapeDtypeStruct((Rp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, noise)
    return q[:R], scale[:R]


def quantize_int8(
    x: jax.Array,               # (R, N) float
    noise: jax.Array,           # (R, N) uniform [0,1)
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    return _quantize_rows(x, noise, block_rows=block_rows, interpret=interpret)


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Flat-vector form — the cluster gradient transport's unit of work
# ---------------------------------------------------------------------------
#
# The host transport ships grads as flat f32 vectors (one per layer bucket).
# ``quantize_flat`` reshapes a vector into (ceil(n/chunk), chunk) rows so the
# shared ``_quantize_rows`` core gives one scale per ``chunk`` contiguous
# elements.  Rounding is the deterministic round-half-up (constant noise
# 0.5): every worker quantizes its OWN contribution once and every peer
# decodes the same int8 bytes, so determinism across replicas costs nothing;
# the quantization bias is absorbed by the caller's error-feedback residual.


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _quantize_flat_jit(vec: jax.Array, chunk: int, interpret: bool):
    n = vec.shape[0]
    rows = -(-n // chunk)
    pad = rows * chunk - n
    mat = jnp.pad(vec.astype(jnp.float32), (0, pad)).reshape(rows, chunk)
    noise = jnp.full((rows, chunk), 0.5, jnp.float32)
    return _quantize_rows(mat, noise, interpret=interpret)


def quantize_flat(
    vec: jax.Array,
    *,
    chunk: int = 512,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Quantize a flat f32 vector to (q int8 (rows, chunk), scale f32 (rows, 1))."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return _quantize_flat_jit(jnp.asarray(vec), chunk, interpret)


def dequantize_flat(q, scale, size: int):
    """Numpy-side inverse of :func:`quantize_flat` (peers decode on host)."""
    import numpy as np

    q = np.asarray(q)
    scale = np.asarray(scale, dtype=np.float32)
    return (q.astype(np.float32) * scale).reshape(-1)[:size]
