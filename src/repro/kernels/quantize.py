"""Int8 gradient quantization with stochastic rounding — the compressed-
allreduce building block (beyond-paper distributed optimization).

Per-row symmetric quantization: scale = absmax / 127.  Stochastic rounding
(floor(x/scale + uniform)) keeps E[q*scale] = x, so momentum-SGD stays
unbiased; the residual (error feedback) is handled by the caller in
:mod:`repro.distributed.allreduce`.

Kernel layout: rows tiled to (block_rows, N) VMEM blocks; absmax reduce and
the scale/round/clip are all VPU element ops — this kernel is purely
bandwidth-bound, which is the point: it converts an ICI-bandwidth-bound
allreduce into a (4x smaller) one at the cost of HBM traffic that overlaps.
The uniform noise is passed in as an operand (generated with the training
PRNG) so the kernel stays deterministic per seed on every backend.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_kernel(x_ref, noise_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                    # (br, N)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    y = x / scale
    q = jnp.floor(y + noise_ref[...].astype(jnp.float32))
    q = jnp.clip(q, -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale


def quantize_int8(
    x: jax.Array,               # (R, N) float
    noise: jax.Array,           # (R, N) uniform [0,1)
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    R, N = x.shape
    assert noise.shape == x.shape, (noise.shape, x.shape)
    # pad-and-mask for any R: the row block is sublane-aligned (multiple of
    # 8, so ragged R also compiles on TPU), rows pad with zeros — per-row
    # scales mean padding never contaminates real rows — and the pad rows
    # are sliced back off below.
    br = min(block_rows, ((R + 7) // 8) * 8)
    pad = (-R) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        noise = jnp.pad(noise, ((0, pad), (0, 0)))
    Rp = x.shape[0]
    q, scale = pl.pallas_call(
        _quant_kernel,
        grid=(Rp // br,),
        in_specs=[
            pl.BlockSpec((br, N), lambda i: (i, 0)),
            pl.BlockSpec((br, N), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, N), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, N), jnp.int8),
            jax.ShapeDtypeStruct((Rp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, noise)
    return q[:R], scale[:R]


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)
