"""Pallas TPU kernels for the framework's compute hot spots.

STANNIS itself contributes at the distribution layer; these kernels make the
per-chip layer fast: flash/decode attention (transformer hot spots), RG-LRU
and WKV6 scans (recurrent archs, chunked-parallel TPU forms), and int8
quantization (the compressed-allreduce building block).

Models call :mod:`repro.kernels.ops`; oracles live in :mod:`repro.kernels.ref`.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
