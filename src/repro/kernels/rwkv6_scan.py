"""RWKV-6 WKV recurrence as a chunked-parallel Pallas kernel.

The recurrence (per batch, head; state S in R^{DxD}):
    out_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

TPU adaptation — chunked linear attention (GLA-style), NOT a token-serial
port: for a chunk of length L with per-channel log-decays lw_t = log w_t and
prefix sums  cum_t = sum_{j<=t} lw_j:

    inter-chunk:  out  = (r_t * exp(cum_{t-1})) @ S_0          (one (L,D)x(D,D) MXU matmul)
    intra-chunk:  A_{t,j} = sum_d r_t[d] k_j[d] exp(cum_{t-1,d} - cum_{j,d}),  j <  t
                  A_{t,t} = sum_d r_t[d] u[d] k_t[d]
                  out += A @ V                                  ((L,L)x(L,D) MXU matmul)
    state:        S_L  = diag(exp(cum_L)) S_0 + (k * exp(cum_L - cum))^T @ V

Every exponent above is <= 0 (decays only accumulate), so the chunked form is
overflow-safe WITHOUT the unstable 1/decay factorization a naive CUDA port
would use.  The intra-chunk pairwise decay is materialized as an (L, L, D)
masked tensor — with L = 32, D = 64 that is 256 KB of VMEM, well inside
budget, and the two big matmuls dominate on the MXU.  The state (D, D) is
carried across chunks in VMEM scratch (sequential innermost grid dim).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_body(
    r, k, v, lw, u, o_ref, sfin_ref,
    s_ref,                    # (D, D) f32 scratch — the carried state
    *,
    L: int,
    n_chunks: int,
):
    """Shared chunked-WKV sweep over already-loaded f32 (L, D) tiles.

    Both the f32 and the int8 (in-kernel dequant) kernels call this; the
    only difference between them is how the r/k/v tiles reach f32."""
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    s0 = s_ref[...]                           # (D, D)

    cum = jnp.cumsum(lw, axis=0)              # (L, D), cum_t = sum_{j<=t}
    cum_prev = cum - lw                       # sum_{j<t}

    # inter-chunk: r_t scaled by accumulated decay hits the carried state
    q_eff = r * jnp.exp(cum_prev)             # exponent <= 0
    out = jax.lax.dot_general(
        q_eff, s0, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # (L, D)

    # intra-chunk: pairwise decayed attention, strictly lower triangular
    # decay[t, j, d] = exp(cum_prev[t, d] - cum[j, d])  for j < t  (<= 0 exp)
    expo = cum_prev[:, None, :] - cum[None, :, :]         # (L, L, D)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(tri[:, :, None], jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
    attn = jnp.einsum("td,jd,tjd->tj", r, k, decay)       # (L, L)
    bonus = jnp.sum(r * u[None, :] * k, axis=1)           # (L,) diagonal term
    attn = attn + jnp.diag(bonus)
    out = out + jax.lax.dot_general(
        attn, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0, 0] = out.astype(o_ref.dtype)

    # state update: S_L = diag(exp(cum_L)) S0 + (k * exp(cum_L - cum))^T V
    cum_L = cum[L - 1]                                     # (D,)
    k_dec = k * jnp.exp(cum_L[None, :] - cum)              # exponent <= 0
    s_new = jnp.exp(cum_L)[:, None] * s0 + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        sfin_ref[0, 0] = s_new


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, sfin_ref, s_ref, **kw):
    _wkv6_body(
        r_ref[0, 0].astype(jnp.float32),
        k_ref[0, 0].astype(jnp.float32),
        v_ref[0, 0].astype(jnp.float32),
        lw_ref[0, 0].astype(jnp.float32),
        u_ref[0].astype(jnp.float32),
        o_ref, sfin_ref, s_ref, **kw,
    )


def _wkv6_int8_kernel(
    r_ref, rs_ref, k_ref, ks_ref, v_ref, vs_ref, lw_ref, u_ref,
    o_ref, sfin_ref, s_ref, **kw,
):
    # int8 r/k/v tiles + (L, 1) per-row scales on the same index map; the
    # decay stays f32 (its log-cumsum is the numerically fragile part).
    # The recurrent state is f32 VMEM scratch either way — only the streamed
    # activations are narrow.
    _wkv6_body(
        r_ref[0, 0].astype(jnp.float32) * rs_ref[0, 0],
        k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0],
        v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0],
        lw_ref[0, 0].astype(jnp.float32),
        u_ref[0].astype(jnp.float32),
        o_ref, sfin_ref, s_ref, **kw,
    )


def rwkv6_scan(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,  # (B, S, H, D)
    u: jax.Array,                                            # (H, D)
    *,
    chunk: int = 32,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B, S, H, D), final_state (B, H, D, D))."""
    B, S, H, D = r.shape
    L = min(chunk, S)
    pad = (-S) % L
    # log-decay; padded steps get lw = 0 (w = 1: state passes through).
    # Floor 1e-30 (NOT 1e-38: that is subnormal in f32 and XLA's flush-to-zero
    # turns it into log(0) = -inf); e^-69 per step is already total decay.
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30))
    rt = jnp.moveaxis(r, 2, 1)        # (B, H, S, D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    lwt = jnp.moveaxis(lw, 2, 1)
    if pad:
        cfg = ((0, 0), (0, 0), (0, pad), (0, 0))
        rt, kt, vt = (jnp.pad(t, cfg) for t in (rt, kt, vt))
        lwt = jnp.pad(lwt, cfg)       # zeros: w = 1 pass-through
    Sp = rt.shape[2]
    n_chunks = Sp // L

    grid = (B, H, n_chunks)
    out, s_fin = pl.pallas_call(
        functools.partial(_wkv6_kernel, L=L, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, L, D), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, L, D), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, L, D), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, L, D), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, D), lambda b, h, ic: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, D), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sp, D), r.dtype),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, lwt, u)
    out = jnp.moveaxis(out, 1, 2)[:, :S]
    return out, s_fin


def rwkv6_scan_int8(
    r: jax.Array, r_scale: jax.Array,         # (B, S, H, D) int8 / (B, S, H, 1) f32
    k: jax.Array, k_scale: jax.Array,
    v: jax.Array, v_scale: jax.Array,
    w: jax.Array,                             # (B, S, H, D) float decay
    u: jax.Array,                             # (H, D)
    *,
    chunk: int = 32,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> Tuple[jax.Array, jax.Array]:
    """WKV scan over int8 r/k/v with in-kernel dequantization.

    Identical grid/blocking to :func:`rwkv6_scan`; each (L, D) activation
    tile arrives with its (L, 1) row scales on the same index map and is
    dequantized as it enters the sweep.  Decay/bonus stay f32 — their
    log-space math is the overflow-safety argument — and the (D, D) state
    scratch is f32 as always."""
    B, S, H, D = r.shape
    assert r.dtype == jnp.int8 and k.dtype == jnp.int8 and v.dtype == jnp.int8
    L = min(chunk, S)
    pad = (-S) % L
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30))
    rt, kt, vt = (jnp.moveaxis(t, 2, 1) for t in (r, k, v))
    rst, kst, vst = (jnp.moveaxis(t, 2, 1) for t in (r_scale, k_scale, v_scale))
    lwt = jnp.moveaxis(lw, 2, 1)
    if pad:
        cfg = ((0, 0), (0, 0), (0, pad), (0, 0))
        rt, kt, vt = (jnp.pad(t, cfg) for t in (rt, kt, vt))
        # zero scales: padded steps dequantize to 0 (and lw = 0 passes the
        # state through), so padding cannot perturb the carried state
        rst, kst, vst = (jnp.pad(t, cfg) for t in (rst, kst, vst))
        lwt = jnp.pad(lwt, cfg)
    Sp = rt.shape[2]
    n_chunks = Sp // L

    act_spec = pl.BlockSpec((1, 1, L, D), lambda b, h, ic: (b, h, ic, 0))
    sc_spec = pl.BlockSpec((1, 1, L, 1), lambda b, h, ic: (b, h, ic, 0))
    out, s_fin = pl.pallas_call(
        functools.partial(_wkv6_int8_kernel, L=L, n_chunks=n_chunks),
        grid=(B, H, n_chunks),
        in_specs=[
            act_spec, sc_spec, act_spec, sc_spec, act_spec, sc_spec,
            act_spec,
            pl.BlockSpec((1, D), lambda b, h, ic: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, D), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sp, D), out_dtype),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(rt, rst, kt, kst, vt, vst, lwt, u)
    out = jnp.moveaxis(out, 1, 2)[:, :S]
    return out, s_fin
