"""Jit'd public wrappers for every Pallas kernel, with CPU fallbacks.

The model code calls THESE (never pallas_call directly).  Each op:
  * dispatches to the Pallas kernel (interpret=True on CPU, compiled on TPU),
  * exposes a ``use_kernel=False`` escape hatch to the jnp oracle,
  * is differentiable: forward kernels carry a ``jax.custom_vjp`` whose
    backward recomputes through the reference (flash-style recompute — the
    residuals are the INPUTS, not the O(S^2) intermediates).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.decode_attention import (
    decode_attention_int8 as _decode_int8_pallas,
)
from repro.kernels.decode_attention import (
    paged_decode_attention as _paged_decode_pallas,
)
from repro.kernels.decode_attention import (
    paged_decode_attention_int8 as _paged_decode_int8_pallas,
)
from repro.kernels.flash_attention import flash_attention_fwd as _flash_pallas
from repro.kernels.flash_attention import (
    flash_attention_int8_fwd as _flash_int8_pallas,
)
from repro.kernels.fused_moe import fused_moe_mlp_fwd as _fused_moe_pallas
from repro.kernels.quantize import dequantize_int8 as _deq
from repro.kernels.quantize import quantize_int8 as _quant_pallas
from repro.kernels.rglru_scan import rglru_scan as _rglru_pallas
from repro.kernels.rglru_scan import rglru_scan_int8 as _rglru_int8_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan_int8 as _rwkv6_int8_pallas


# ---------------------------------------------------------------------------
# flash attention (differentiable)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention(q, k, v, causal, window, block, interpret):
    return _flash_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block, block_k=block, interpret=interpret,
    )


def _flash_fwd(q, k, v, causal, window, block, interpret):
    out = _flash_attention(q, k, v, causal, window, block, interpret)
    return out, (q, k, v)


def _flash_bwd(causal, window, block, interpret, res, g):
    q, k, v = res
    # recompute through the oracle; XLA fuses this into a memory-bounded bwd
    _, vjp = jax.vjp(
        lambda q_, k_, v_: R.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window
        ),
        q, k, v,
    )
    return vjp(g)


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    block: int = 128,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jax.Array:
    if not use_kernel:
        return R.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash_attention(q, k, v, causal, window, block, interpret)


# ---------------------------------------------------------------------------
# quantized-training (q8) ops: int8 streamed activations, int8 residuals
# ---------------------------------------------------------------------------
#
# Each q8 op quantizes its big streamed operands per-row to int8 (deterministic
# round-half-up — the Pallas quantize kernel with constant 0.5 noise, pinned
# bit-equal to the oracle), runs the fused kernel that dequantizes tiles
# inside VMEM, and saves the INT8 tensors + scales as the custom-vjp
# residuals — the saved-for-backward pytree shrinks ~4x.  Backward
# dequantizes once and recomputes through the reference (straight-through
# across the rounding, exactly the grad of the base op at the dequantized
# point — what the parity tests pin).


def _q8_quant(x, interpret, use_kernel):
    """Per-row round-half-up int8; Pallas kernel or its bit-equal oracle."""
    if not use_kernel:
        return R.quantize_int8_ref(x, jnp.full(x.shape, 0.5, jnp.float32))
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])
    q, s = _quant_pallas(
        x2, jnp.full(x2.shape, 0.5, jnp.float32), interpret=interpret
    )
    return q.reshape(shp), s.reshape(shp[:-1] + (1,))


def _dtype_tag(x):
    """Zero-size carrier smuggling a primal dtype through vjp residuals."""
    return jnp.zeros((0,), x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_q8(q, k, v, causal, window, block, interpret, use_kernel):
    out, _ = _flash_q8_fwd(q, k, v, causal, window, block, interpret, use_kernel)
    return out


def _flash_q8_fwd(q, k, v, causal, window, block, interpret, use_kernel):
    kq, ks = _q8_quant(k, interpret, use_kernel)
    vq, vs = _q8_quant(v, interpret, use_kernel)
    if use_kernel:
        out = _flash_int8_pallas(
            q, kq, ks, vq, vs, causal=causal, window=window,
            block_q=block, block_k=block, interpret=interpret,
        )
    else:
        out = R.flash_attention_ref(
            q, R.dequantize_int8_ref(kq, ks), R.dequantize_int8_ref(vq, vs),
            causal=causal, window=window,
        )
    return out, (q, kq, ks, vq, vs, _dtype_tag(k), _dtype_tag(v))


def _flash_q8_bwd(causal, window, block, interpret, use_kernel, res, g):
    q, kq, ks, vq, vs, ktag, vtag = res
    kd = R.dequantize_int8_ref(kq, ks)
    vd = R.dequantize_int8_ref(vq, vs)
    _, vjp = jax.vjp(
        lambda q_, k_, v_: R.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window
        ).astype(g.dtype),
        q, kd, vd,
    )
    dq, dk, dv = vjp(g)
    return dq.astype(q.dtype), dk.astype(ktag.dtype), dv.astype(vtag.dtype)


_flash_q8.defvjp(_flash_q8_fwd, _flash_q8_bwd)


def flash_attention_q8(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    block: int = 128,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jax.Array:
    """Int8-fused training attention: K/V live in int8 end to end.

    K/V are quantized per-row (scale = absmax/127, round-half-up), the
    online-softmax sweep dequantizes each tile inside VMEM with f32
    accumulation, and the backward residuals save the int8 K/V + scales
    instead of the f32 tensors.  ``use_kernel=False`` runs the same math
    off-Pallas (exact fallback)."""
    return _flash_q8(q, k, v, causal, window, block, interpret, use_kernel)


# ---------------------------------------------------------------------------
# decode attention (inference only — no vjp needed)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, valid_len: jax.Array,
    *,
    window: Optional[int] = None,
    block_k: int = 512,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jax.Array:
    if not use_kernel:
        return R.decode_attention_ref(q, k, v, valid_len, window=window)
    return _decode_pallas(
        q, k, v, valid_len, window=window, block_k=block_k, interpret=interpret
    )


def paged_decode_attention(
    q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
    block_table: jax.Array, valid_len: jax.Array,
    *,
    window: Optional[int] = None,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jax.Array:
    if not use_kernel:
        return R.paged_decode_attention_ref(
            q, k_pages, v_pages, block_table, valid_len, window=window
        )
    return _paged_decode_pallas(
        q, k_pages, v_pages, block_table, valid_len,
        window=window, interpret=interpret,
    )


def decode_attention_int8(
    q: jax.Array, k: jax.Array, k_scale: jax.Array,
    v: jax.Array, v_scale: jax.Array, valid_len: jax.Array,
    *,
    window: Optional[int] = None,
    block_k: int = 512,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jax.Array:
    """Decode over an int8 KV cache (+ per-row f32 scales), dequantized
    inside the kernel — the cache sweep moves ~4x fewer HBM bytes."""
    if not use_kernel:
        return R.decode_attention_int8_ref(
            q, k, k_scale, v, v_scale, valid_len, window=window
        )
    return _decode_int8_pallas(
        q, k, k_scale, v, v_scale, valid_len,
        window=window, block_k=block_k, interpret=interpret,
    )


def paged_decode_attention_int8(
    q: jax.Array, k_pages: jax.Array, k_scales: jax.Array,
    v_pages: jax.Array, v_scales: jax.Array,
    block_table: jax.Array, valid_len: jax.Array,
    *,
    window: Optional[int] = None,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jax.Array:
    """Paged decode over an int8 page pool; see :func:`decode_attention_int8`."""
    if not use_kernel:
        return R.paged_decode_attention_int8_ref(
            q, k_pages, k_scales, v_pages, v_scales, block_table, valid_len,
            window=window,
        )
    return _paged_decode_int8_pallas(
        q, k_pages, k_scales, v_pages, v_scales, block_table, valid_len,
        window=window, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# fused MoE dispatch + expert SwiGLU (differentiable)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _fused_moe(x, router, wg, wu, wo, k, capacity, block_c, interpret):
    return _fused_moe_pallas(
        x, router, wg, wu, wo,
        k=k, capacity=capacity, block_c=block_c, interpret=interpret,
    )


def _fused_moe_fwd(x, router, wg, wu, wo, k, capacity, block_c, interpret):
    out = _fused_moe(x, router, wg, wu, wo, k, capacity, block_c, interpret)
    return out, (x, router, wg, wu, wo)


def _fused_moe_bwd(k, capacity, block_c, interpret, res, g):
    x, router, wg, wu, wo = res
    # recompute through the oracle: re-derives routing + dispatch (cheap int
    # ops) and the expert GEMM intermediates rather than saving E*C*f floats
    _, vjp = jax.vjp(
        lambda x_, r_, wg_, wu_, wo_: R.fused_moe_mlp_ref(
            x_, r_, wg_, wu_, wo_, k=k, capacity=capacity
        ),
        x, router, wg, wu, wo,
    )
    return vjp(g)


_fused_moe.defvjp(_fused_moe_fwd, _fused_moe_bwd)


def fused_moe_mlp(
    x: jax.Array,               # (T, d) tokens
    router: jax.Array,          # (d, E)
    wg: jax.Array, wu: jax.Array, wo: jax.Array,  # expert SwiGLU weights
    *,
    k: int,
    capacity: int,
    block_c: int = 128,
    interpret: bool = False,
    use_kernel: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Fused top-k MoE layer: routing stays in XLA, dispatch gather + capacity
    mask + expert SwiGLU + gate scaling run in one Pallas kernel.  Returns
    ``(out (T, d), aux_loss)``; backward recomputes through the oracle."""
    if not use_kernel:
        return R.fused_moe_mlp_ref(x, router, wg, wu, wo, k=k, capacity=capacity)
    return _fused_moe(x, router, wg, wu, wo, k, capacity, block_c, interpret)


# ---------------------------------------------------------------------------
# RG-LRU scan (differentiable)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rglru(a, x, chunk, interpret):
    return _rglru_pallas(a, x, chunk=chunk, interpret=interpret)


def _rglru_fwd(a, x, chunk, interpret):
    y = _rglru(a, x, chunk, interpret)
    return y, (a, x)


def _rglru_bwd(chunk, interpret, res, g):
    a, x = res
    _, vjp = jax.vjp(lambda a_, x_: R.rglru_scan_ref(a_, x_), a, x)
    return vjp(g)


_rglru.defvjp(_rglru_fwd, _rglru_bwd)


def rglru_scan(
    a: jax.Array, x: jax.Array, *,
    chunk: int = 128, interpret: bool = False, use_kernel: bool = True,
) -> jax.Array:
    if not use_kernel:
        return R.rglru_scan_ref(a, x)
    return _rglru(a, x, chunk, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _rglru_q8(a, x, chunk, interpret, use_kernel):
    y, _ = _rglru_q8_fwd(a, x, chunk, interpret, use_kernel)
    return y


def _rglru_q8_fwd(a, x, chunk, interpret, use_kernel):
    xq, xs = _q8_quant(x, interpret, use_kernel)
    if use_kernel:
        y = _rglru_int8_pallas(
            a, xq, xs, chunk=chunk, interpret=interpret, out_dtype=x.dtype
        )
    else:
        y = R.rglru_scan_ref(a, R.dequantize_int8_ref(xq, xs)).astype(x.dtype)
    # decay stays f32 (its seq padding must be exactly 1.0); only the gated
    # input rides int8 — it is the larger, freshly-computed activation
    return y, (a, xq, xs, _dtype_tag(x))


def _rglru_q8_bwd(chunk, interpret, use_kernel, res, g):
    a, xq, xs, xtag = res
    xd = R.dequantize_int8_ref(xq, xs)
    _, vjp = jax.vjp(
        lambda a_, x_: R.rglru_scan_ref(a_, x_).astype(g.dtype), a, xd
    )
    da, dx = vjp(g)
    return da.astype(a.dtype), dx.astype(xtag.dtype)


_rglru_q8.defvjp(_rglru_q8_fwd, _rglru_q8_bwd)


def rglru_scan_q8(
    a: jax.Array, x: jax.Array, *,
    chunk: int = 128, interpret: bool = False, use_kernel: bool = True,
) -> jax.Array:
    """Int8-fused RG-LRU: the gated input streams as int8 + per-row scales,
    dequantized inside the scan (f32 carry), and the backward residual saves
    the int8 input instead of the f32 one."""
    return _rglru_q8(a, x, chunk, interpret, use_kernel)


# ---------------------------------------------------------------------------
# RWKV6 scan (differentiable)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _rwkv6(r, k, v, w, u, chunk, interpret):
    return _rwkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)


def _rwkv6_fwd(r, k, v, w, u, chunk, interpret):
    out = _rwkv6(r, k, v, w, u, chunk, interpret)
    return out, (r, k, v, w, u)


def _rwkv6_bwd(chunk, interpret, res, g):
    r, k, v, w, u = res
    _, vjp = jax.vjp(
        lambda r_, k_, v_, w_, u_: R.rwkv6_scan_ref(r_, k_, v_, w_, u_),
        r, k, v, w, u,
    )
    return vjp(g)


_rwkv6.defvjp(_rwkv6_fwd, _rwkv6_bwd)


def rwkv6_scan(
    r, k, v, w, u, *,
    chunk: int = 32, interpret: bool = False, use_kernel: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    if not use_kernel:
        return R.rwkv6_scan_ref(r, k, v, w, u)
    return _rwkv6(r, k, v, w, u, chunk, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _rwkv6_q8(r, k, v, w, u, chunk, interpret, use_kernel):
    out, _ = _rwkv6_q8_fwd(r, k, v, w, u, chunk, interpret, use_kernel)
    return out


def _rwkv6_q8_fwd(r, k, v, w, u, chunk, interpret, use_kernel):
    rq, rs = _q8_quant(r, interpret, use_kernel)
    kq, ks = _q8_quant(k, interpret, use_kernel)
    vq, vs = _q8_quant(v, interpret, use_kernel)
    if use_kernel:
        out, s_fin = _rwkv6_int8_pallas(
            rq, rs, kq, ks, vq, vs, w, u,
            chunk=chunk, interpret=interpret, out_dtype=r.dtype,
        )
    else:
        out, s_fin = R.rwkv6_scan_ref(
            R.dequantize_int8_ref(rq, rs), R.dequantize_int8_ref(kq, ks),
            R.dequantize_int8_ref(vq, vs), w.astype(jnp.float32), u,
        )
        out = out.astype(r.dtype)
    res = (rq, rs, kq, ks, vq, vs, w, u,
           _dtype_tag(r), _dtype_tag(k), _dtype_tag(v))
    return (out, s_fin), res


def _rwkv6_q8_bwd(chunk, interpret, use_kernel, res, g):
    rq, rs, kq, ks, vq, vs, w, u, rtag, ktag, vtag = res
    rd = R.dequantize_int8_ref(rq, rs)
    kd = R.dequantize_int8_ref(kq, ks)
    vd = R.dequantize_int8_ref(vq, vs)
    g_out, g_s = g

    def f(r_, k_, v_, w_, u_):
        o, s = R.rwkv6_scan_ref(r_, k_, v_, w_, u_)
        return o.astype(g_out.dtype), s

    _, vjp = jax.vjp(f, rd, kd, vd, w, u)
    dr, dk, dv, dw, du = vjp((g_out, g_s))
    return (dr.astype(rtag.dtype), dk.astype(ktag.dtype),
            dv.astype(vtag.dtype), dw, du)


_rwkv6_q8.defvjp(_rwkv6_q8_fwd, _rwkv6_q8_bwd)


def rwkv6_scan_q8(
    r, k, v, w, u, *,
    chunk: int = 32, interpret: bool = False, use_kernel: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Int8-fused WKV scan: r/k/v stream as int8 + per-row scales with
    in-kernel dequant (decay/bonus stay f32 — the log-space overflow-safety
    math), and the backward residuals save the int8 activations."""
    return _rwkv6_q8(r, k, v, w, u, chunk, interpret, use_kernel)


# ---------------------------------------------------------------------------
# int8 quantize / dequantize
# ---------------------------------------------------------------------------


def quantize_int8(
    x: jax.Array, noise: Optional[jax.Array] = None, *,
    block_rows: int = 256, interpret: bool = False, use_kernel: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """x: (R, N).  noise None => deterministic nearest rounding (oracle path)."""
    if noise is None or not use_kernel:
        return R.quantize_int8_ref(x, noise)
    return _quant_pallas(x, noise, block_rows=block_rows, interpret=interpret)


dequantize_int8 = _deq
