"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each function is the mathematical definition, written for clarity not speed;
tests sweep shapes/dtypes and assert the kernels match these.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def flash_attention_ref(
    q: jax.Array,               # (B, Sq, H, D)
    k: jax.Array,               # (B, Skv, Hkv, D)
    v: jax.Array,               # (B, Skv, Hkv, D)
    *,
    causal: bool = False,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    if Hkv != H:
        reps = H // Hkv
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,               # (B, 1, H, D)
    k: jax.Array,               # (B, Skv, Hkv, D)  (cache)
    v: jax.Array,               # (B, Skv, Hkv, D)
    valid_len: jax.Array,       # (B,) int32 — positions < valid_len attend
    *,
    window: Optional[int] = None,
) -> jax.Array:
    B, _, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    if Hkv != H:
        reps = H // Hkv
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    k_pos = jnp.arange(Skv)[None, :]
    mask = k_pos < valid_len[:, None]
    if window is not None:
        mask &= k_pos > (valid_len[:, None] - 1 - window)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32)).astype(q.dtype)


def paged_decode_attention_ref(
    q: jax.Array,               # (B, 1, H, D)
    k_pages: jax.Array,         # (P, page_size, Hkv, D)
    v_pages: jax.Array,
    block_table: jax.Array,     # (B, NP) int32
    valid_len: jax.Array,       # (B,) int32
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """Gather each row's pages into a contiguous cache, then dense decode."""
    B, NP = block_table.shape
    page_size, Hkv, D = k_pages.shape[1:]
    k = k_pages[block_table].reshape(B, NP * page_size, Hkv, D)
    v = v_pages[block_table].reshape(B, NP * page_size, Hkv, D)
    return decode_attention_ref(q, k, v, valid_len, window=window)


def decode_attention_int8_ref(
    q: jax.Array,               # (B, 1, H, D)
    k: jax.Array,               # (B, Skv, Hkv, D) int8 cache
    k_scale: jax.Array,         # (B, Skv, Hkv, 1) f32 per-row scales
    v: jax.Array,               # (B, Skv, Hkv, D) int8
    v_scale: jax.Array,         # (B, Skv, Hkv, 1) f32
    valid_len: jax.Array,       # (B,) int32
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """Dequantize the int8 cache, then dense decode (the fused kernel's target)."""
    kf = dequantize_int8_ref(k, k_scale, jnp.float32)
    vf = dequantize_int8_ref(v, v_scale, jnp.float32)
    return decode_attention_ref(q, kf, vf, valid_len, window=window)


def paged_decode_attention_int8_ref(
    q: jax.Array,               # (B, 1, H, D)
    k_pages: jax.Array,         # (P, page_size, Hkv, D) int8
    k_scales: jax.Array,        # (P, page_size, Hkv, 1) f32
    v_pages: jax.Array,
    v_scales: jax.Array,
    block_table: jax.Array,     # (B, NP) int32
    valid_len: jax.Array,       # (B,) int32
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """Gather int8 pages + scales, dequantize, then dense decode."""
    B, NP = block_table.shape
    page_size, Hkv, D = k_pages.shape[1:]
    k = dequantize_int8_ref(
        k_pages[block_table], k_scales[block_table], jnp.float32
    ).reshape(B, NP * page_size, Hkv, D)
    v = dequantize_int8_ref(
        v_pages[block_table], v_scales[block_table], jnp.float32
    ).reshape(B, NP * page_size, Hkv, D)
    return decode_attention_ref(q, k, v, valid_len, window=window)


# ---------------------------------------------------------------------------
# Mixture of experts
# ---------------------------------------------------------------------------


def fused_moe_mlp_ref(
    x: jax.Array,               # (T, d) tokens
    router: jax.Array,          # (d, E)
    wg: jax.Array,              # (E, d, f) gate proj
    wu: jax.Array,              # (E, d, f) up proj
    wo: jax.Array,              # (E, f, d) down proj
    *,
    k: int,
    capacity: int,
) -> Tuple[jax.Array, jax.Array]:
    """Capacity-layout top-k MoE with SwiGLU experts (Switch aux loss).

    The mathematical definition of the fused dispatch+GEMM kernel: top-k
    routing with renormalized gates, first-come-first-served capacity at
    ``capacity`` slots per expert (overflow copies dropped), per-expert
    SwiGLU, gate-weighted combine.  Returns ``(out (T, d), aux_loss)``.
    """
    T, d = x.shape
    E = router.shape[1]
    C = capacity

    logits = (x @ router.astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    tok_frac = jnp.mean(
        jax.nn.one_hot(expert_ids, E, dtype=jnp.float32).sum(axis=1), axis=0
    )
    prob_frac = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(tok_frac * prob_frac)

    flat_expert = expert_ids.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]

    counts = jnp.bincount(flat_expert, length=E)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(T * k) - offsets[se]
    keep = pos_in_expert < C
    slot = jnp.where(keep, se * C + pos_in_expert, E * C)

    gathered = x[st] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(gathered)[: E * C]
    buf = buf.reshape(E, C, d)

    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, wo).reshape(E * C, d)

    safe_slot = jnp.minimum(slot, E * C - 1)
    gate_w = (sg * keep).astype(y.dtype)
    out = jnp.zeros((T, d), y.dtype).at[st].add(y[safe_slot] * gate_w[:, None])
    return out, aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Linear recurrences
# ---------------------------------------------------------------------------


def rglru_scan_ref(
    a: jax.Array,               # (B, S, W) decay in (0, 1)
    x: jax.Array,               # (B, S, W) gated input
    h0: Optional[jax.Array] = None,  # (B, W)
) -> jax.Array:
    """h_t = a_t * h_{t-1} + x_t; returns all h_t. float32 internally."""
    af, xf = a.astype(jnp.float32), x.astype(jnp.float32)

    def step(h, inp):
        at, xt = inp
        h = at * h + xt
        return h, h

    h_init = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    _, ys = jax.lax.scan(
        step, h_init, (jnp.moveaxis(af, 1, 0), jnp.moveaxis(xf, 1, 0))
    )
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def rwkv6_scan_ref(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,  # (B, S, H, D)
    u: jax.Array,                                            # (H, D)
    s0: Optional[jax.Array] = None,                          # (B, H, D, D)
) -> Tuple[jax.Array, jax.Array]:
    """out_t = r_t @ (S_{t-1} + u*k_t (x) v_t);  S_t = diag(w_t) S_{t-1} + k_t (x) v_t."""
    B, S, H, D = r.shape
    s = jnp.zeros((B, H, D, D), jnp.float32) if s0 is None else s0.astype(jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhd,bhde->bhe", rt, s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    s, outs = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), s


# ---------------------------------------------------------------------------
# Gradient quantization (compressed allreduce)
# ---------------------------------------------------------------------------


def quantize_int8_ref(
    x: jax.Array,               # (..., N) float
    noise: Optional[jax.Array] = None,  # same shape, U[0,1) for stochastic rounding
) -> Tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8: scale = absmax/127; stochastic or nearest round.

    Returns (q int8, scale f32 with trailing dim 1).
    """
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    y = xf / scale
    if noise is None:
        q = jnp.round(y)
    else:
        q = jnp.floor(y + noise.astype(jnp.float32))
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_ref(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Quantized-training (q8) ops: quantize → dequantize → base oracle
# ---------------------------------------------------------------------------
#
# Each q8 op quantizes its streamed activations with the deterministic
# round-half-up the Pallas quantize kernel uses (constant 0.5 noise — pinned
# by the quantize parity tests), then runs the base math on the dequantized
# values.  The fused kernels dequantize in-VMEM instead, so op and oracle see
# the SAME int8 values and differ only by the usual kernel-vs-ref float
# reassociation.


def _q8_rows(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-row round-half-up int8 (the q8 training quantizer)."""
    return quantize_int8_ref(x, jnp.full(x.shape, 0.5, jnp.float32))


def _q8_roundtrip(x: jax.Array) -> jax.Array:
    q, s = _q8_rows(x)
    return dequantize_int8_ref(q, s, jnp.float32)


def flash_attention_q8_ref(
    q: jax.Array,               # (B, Sq, H, D)
    k: jax.Array,               # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = False,
    window: Optional[int] = None,
) -> jax.Array:
    """Flash attention with K/V squeezed through per-row int8."""
    return flash_attention_ref(
        q, _q8_roundtrip(k), _q8_roundtrip(v), causal=causal, window=window
    )


def rwkv6_scan_q8_ref(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,  # (B, S, H, D)
    u: jax.Array,                                            # (H, D)
) -> Tuple[jax.Array, jax.Array]:
    """WKV scan with r/k/v squeezed through per-row int8 (decay stays f32)."""
    out, s = rwkv6_scan_ref(
        _q8_roundtrip(r), _q8_roundtrip(k), _q8_roundtrip(v),
        w.astype(jnp.float32), u,
    )
    return out.astype(r.dtype), s


def rglru_scan_q8_ref(
    a: jax.Array,               # (B, S, W) decay in (0, 1)
    x: jax.Array,               # (B, S, W) gated input
) -> jax.Array:
    """RG-LRU scan with the gated input squeezed through per-row int8."""
    return rglru_scan_ref(a.astype(jnp.float32), _q8_roundtrip(x)).astype(x.dtype)
