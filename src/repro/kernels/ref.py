"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each function is the mathematical definition, written for clarity not speed;
tests sweep shapes/dtypes and assert the kernels match these.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def flash_attention_ref(
    q: jax.Array,               # (B, Sq, H, D)
    k: jax.Array,               # (B, Skv, Hkv, D)
    v: jax.Array,               # (B, Skv, Hkv, D)
    *,
    causal: bool = False,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    if Hkv != H:
        reps = H // Hkv
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,               # (B, 1, H, D)
    k: jax.Array,               # (B, Skv, Hkv, D)  (cache)
    v: jax.Array,               # (B, Skv, Hkv, D)
    valid_len: jax.Array,       # (B,) int32 — positions < valid_len attend
    *,
    window: Optional[int] = None,
) -> jax.Array:
    B, _, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    if Hkv != H:
        reps = H // Hkv
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    k_pos = jnp.arange(Skv)[None, :]
    mask = k_pos < valid_len[:, None]
    if window is not None:
        mask &= k_pos > (valid_len[:, None] - 1 - window)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32)).astype(q.dtype)


def paged_decode_attention_ref(
    q: jax.Array,               # (B, 1, H, D)
    k_pages: jax.Array,         # (P, page_size, Hkv, D)
    v_pages: jax.Array,
    block_table: jax.Array,     # (B, NP) int32
    valid_len: jax.Array,       # (B,) int32
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """Gather each row's pages into a contiguous cache, then dense decode."""
    B, NP = block_table.shape
    page_size, Hkv, D = k_pages.shape[1:]
    k = k_pages[block_table].reshape(B, NP * page_size, Hkv, D)
    v = v_pages[block_table].reshape(B, NP * page_size, Hkv, D)
    return decode_attention_ref(q, k, v, valid_len, window=window)


# ---------------------------------------------------------------------------
# Linear recurrences
# ---------------------------------------------------------------------------


def rglru_scan_ref(
    a: jax.Array,               # (B, S, W) decay in (0, 1)
    x: jax.Array,               # (B, S, W) gated input
    h0: Optional[jax.Array] = None,  # (B, W)
) -> jax.Array:
    """h_t = a_t * h_{t-1} + x_t; returns all h_t. float32 internally."""
    af, xf = a.astype(jnp.float32), x.astype(jnp.float32)

    def step(h, inp):
        at, xt = inp
        h = at * h + xt
        return h, h

    h_init = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    _, ys = jax.lax.scan(
        step, h_init, (jnp.moveaxis(af, 1, 0), jnp.moveaxis(xf, 1, 0))
    )
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def rwkv6_scan_ref(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,  # (B, S, H, D)
    u: jax.Array,                                            # (H, D)
    s0: Optional[jax.Array] = None,                          # (B, H, D, D)
) -> Tuple[jax.Array, jax.Array]:
    """out_t = r_t @ (S_{t-1} + u*k_t (x) v_t);  S_t = diag(w_t) S_{t-1} + k_t (x) v_t."""
    B, S, H, D = r.shape
    s = jnp.zeros((B, H, D, D), jnp.float32) if s0 is None else s0.astype(jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhd,bhde->bhe", rt, s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    s, outs = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), s


# ---------------------------------------------------------------------------
# Gradient quantization (compressed allreduce)
# ---------------------------------------------------------------------------


def quantize_int8_ref(
    x: jax.Array,               # (..., N) float
    noise: Optional[jax.Array] = None,  # same shape, U[0,1) for stochastic rounding
) -> Tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8: scale = absmax/127; stochastic or nearest round.

    Returns (q int8, scale f32 with trailing dim 1).
    """
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    y = xf / scale
    if noise is None:
        q = jnp.round(y)
    else:
        q = jnp.floor(y + noise.astype(jnp.float32))
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_ref(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)
