"""Fused MoE dispatch + expert-matmul as a single Pallas TPU kernel.

The unfused capacity-layout MoE (``models/moe.py::_moe_mlp_dense``) round-trips
five O(E·C·d)-to-O(E·C·f) tensors through HBM per layer: the gathered token
copies, the scattered dispatch buffer, and the g/u/h SwiGLU intermediates.
This kernel keeps all of them in VMEM:

  * **dispatch as a one-hot matmul** — each grid block (e, cb) owns ``bc``
    capacity slots of expert ``e``.  The slot→token table (built by
    :func:`moe_routing`, ordinary int ops) arrives as a ``(E·C, 1)`` int32
    operand; the block compares it against a token iota and multiplies the
    resulting selection matrix into the resident ``(T, d)`` activations on
    the MXU.  The gather never materializes in HBM, and empty slots (token
    index ``T``) select the zero row for free.
  * **capacity masking + combine scaling fused** — the per-slot gate (zero
    for empty slots, the normalized top-k weight otherwise) is applied to
    the expert output inside the kernel, so the only HBM write is the final
    gated ``(E·C, d)`` slot buffer.
  * **expert GEMMs** — wg/wu/wo blocks are index-mapped by the expert id,
    so each expert's weights are fetched once per ``C/bc`` blocks (Pallas
    revolving-buffer reuse) and the SwiGLU runs entirely in VMEM.

  * **combine as the transposed one-hot matmul** — the scatter-add of gated
    slot rows back to token rows is the dispatch selection matrix applied
    the other way: ``out[t] = Σ_s 1[slot_tok[s] = t] · y[s]``.  A second
    kernel (:func:`fused_moe_combine`) builds the same one-hot from the same
    ``(E·C, 1)`` slot table per token block and contracts it against the
    gated slot buffer on the MXU, so expert outputs never round-trip through
    an XLA scatter.  Each token row receives at most ``k`` nonzero addends
    (adding the 0 rows is exact in f32), which keeps the combine bit-exact
    vs the scatter-add (property-tested, including capacity-overflow drops).

What stays outside (in ordinary XLA, by necessity): the router matmul +
top-k + the stable sort that assigns capacity slots (Pallas TPU has no sort
primitive — vLLM's fused_moe splits the same way).  Those are O(T·k) index
ops, not the O(T·d·f) hot loop.

Scaling note: this variant holds the full ``(T, d)`` activation block in
VMEM (fine for the per-device token counts this repo runs; a production
kernel would double-buffer token tiles from HBM).  Tests run in interpret
mode; block shapes are MXU-aligned so the same kernel compiles on TPU.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def moe_routing(
    x: jax.Array,               # (T, d) tokens
    router: jax.Array,          # (d, E)
    k: int,
    capacity: int,
) -> Tuple[jax.Array, ...]:
    """Top-k routing + capacity-slot assignment (the sort stays in XLA).

    Returns ``(slot_tok, slot_gate, st, slot, keep, aux)``:
      * ``slot_tok``  (E·C, 1) int32 — token index per capacity slot, ``T``
        for empty slots (the kernel's one-hot then selects nothing);
      * ``slot_gate`` (E·C, 1) f32  — normalized gate per slot, 0 if empty;
      * ``st``/``slot``/``keep``    — the (T·k,) combine tables in dispatch
        order (token id, slot id with E·C as the drop sentinel, kept mask);
      * ``aux``                     — the Switch load-balance loss.
    """
    T, _ = x.shape
    E = router.shape[1]
    C = capacity

    logits = (x @ router.astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    tok_frac = jnp.mean(
        jax.nn.one_hot(expert_ids, E, dtype=jnp.float32).sum(axis=1), axis=0
    )
    prob_frac = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(tok_frac * prob_frac)

    flat_expert = expert_ids.reshape(-1)                        # (T*k,)
    flat_token = jnp.repeat(jnp.arange(T), k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert, stable=True)
    se = flat_expert[order]
    st = flat_token[order]
    sg = flat_gate[order]

    counts = jnp.bincount(flat_expert, length=E)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(T * k) - offsets[se]
    keep = pos_in_expert < C
    slot = jnp.where(keep, se * C + pos_in_expert, E * C)

    # slot tables: empty slots keep the sentinel token index T / gate 0
    slot_tok = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        st.astype(jnp.int32))[: E * C]
    slot_gate = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sg, 0.0))[: E * C]
    return (slot_tok.reshape(-1, 1), slot_gate.reshape(-1, 1),
            st, slot, keep, aux.astype(jnp.float32))


def _fused_moe_kernel(
    tok_ref,                    # (bc, 1) int32 slot->token table block
    gate_ref,                   # (bc, 1) f32 slot gate block
    x_ref,                      # (T, d) resident tokens
    wg_ref, wu_ref, wo_ref,     # (1, d, f) / (1, d, f) / (1, f, d)
    y_ref,                      # (bc, d) gated expert output block
    *,
    bc: int,
    T: int,
):
    x = x_ref[...].astype(jnp.float32)                          # (T, d)
    idx = tok_ref[...]                                          # (bc, 1)
    # dispatch gather as a one-hot matmul: sentinel index T matches no token
    sel = (idx == jax.lax.broadcasted_iota(jnp.int32, (bc, T), 1)
           ).astype(jnp.float32)
    xs = jax.lax.dot_general(                                   # (bc, d) MXU
        sel, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    wg = wg_ref[0].astype(jnp.float32)
    wu = wu_ref[0].astype(jnp.float32)
    wo = wo_ref[0].astype(jnp.float32)
    g = jax.lax.dot_general(
        xs, wg, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(
        xs, wu, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    h = jax.nn.silu(g) * u
    y = jax.lax.dot_general(
        h, wo, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[...] = (y * gate_ref[...]).astype(y_ref.dtype)


def fused_moe_gemm(
    x: jax.Array,               # (T, d)
    wg: jax.Array,              # (E, d, f)
    wu: jax.Array,              # (E, d, f)
    wo: jax.Array,              # (E, f, d)
    slot_tok: jax.Array,        # (E*C, 1) int32
    slot_gate: jax.Array,       # (E*C, 1) f32
    *,
    block_c: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Dispatch + expert SwiGLU + gate scaling; returns gated (E·C, d) slots."""
    T, d = x.shape
    E, _, f = wg.shape
    S = slot_tok.shape[0]
    C = S // E
    assert S == E * C and slot_gate.shape == (S, 1), (slot_tok.shape, E, C)
    bc = min(block_c, C)
    assert C % bc == 0, (C, bc)
    n_cb = C // bc

    kernel = functools.partial(_fused_moe_kernel, bc=bc, T=T)
    return pl.pallas_call(
        kernel,
        grid=(E, n_cb),
        in_specs=[
            pl.BlockSpec((bc, 1), lambda e, cb, n=n_cb: (e * n + cb, 0)),
            pl.BlockSpec((bc, 1), lambda e, cb, n=n_cb: (e * n + cb, 0)),
            pl.BlockSpec((T, d), lambda e, cb: (0, 0)),
            pl.BlockSpec((1, d, f), lambda e, cb: (e, 0, 0)),
            pl.BlockSpec((1, d, f), lambda e, cb: (e, 0, 0)),
            pl.BlockSpec((1, f, d), lambda e, cb: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bc, d), lambda e, cb, n=n_cb: (e * n + cb, 0)),
        out_shape=jax.ShapeDtypeStruct((S, d), x.dtype),
        interpret=interpret,
    )(slot_tok, slot_gate, x, wg, wu, wo)


def _combine_kernel(
    tok_ref,                    # (S, 1) int32 slot->token table
    y_ref,                      # (S, d) gated expert outputs
    o_ref,                      # (bt, d) token-row output block
    *,
    bt: int,
    S: int,
):
    it = pl.program_id(0)
    tok = tok_ref[...]                                          # (S, 1)
    # transposed one-hot: column t of `sel` marks the slots owned by token
    # t0+t; empty slots carry the sentinel token index (>= T) and their y
    # rows are gate-zeroed anyway, so they contribute exact +0.0
    t_iota = it * bt + jax.lax.broadcasted_iota(jnp.int32, (S, bt), 1)
    sel = (tok == t_iota).astype(jnp.float32)                   # (S, bt)
    y = y_ref[...].astype(jnp.float32)
    o_ref[...] = jax.lax.dot_general(                           # (bt, d) MXU
        sel, y, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def fused_moe_combine(
    y: jax.Array,               # (E*C, d) gated slot rows
    slot_tok: jax.Array,        # (E*C, 1) int32 (sentinel T for empty slots)
    T: int,
    *,
    block_t: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Combine gated slot rows into (T, d) token rows as a one-hot matmul.

    Bit-exact vs the XLA ``.at[st].add`` scatter: every token sums the same
    <= k gated slot rows, and summing them with interleaved exact zeros is
    the same f32 value as the sequential scatter-add.
    """
    S, d = y.shape
    assert slot_tok.shape == (S, 1), slot_tok.shape
    bt = min(block_t, max(T, 8))
    pad_t = (-T) % bt
    Tp = T + pad_t
    # padded token rows only ever match the sentinel's gate-zeroed slots (or
    # nothing at all), and are sliced back off below
    out = pl.pallas_call(
        functools.partial(_combine_kernel, bt=bt, S=S),
        grid=(Tp // bt,),
        in_specs=[
            pl.BlockSpec((S, 1), lambda it: (0, 0)),
            pl.BlockSpec((S, d), lambda it: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda it: (it, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, d), y.dtype),
        interpret=interpret,
    )(slot_tok, y)
    return out[:T]


def _combine_xla(y, st, slot, keep, T, E, C):
    """The scatter-add combine the kernel replaced — kept as the bit-exact
    A/B target (`combine="xla"`) and the off-Pallas fallback."""
    safe_slot = jnp.minimum(slot, E * C - 1)
    out_copies = y[safe_slot] * keep[:, None].astype(y.dtype)
    return jnp.zeros((T, y.shape[1]), y.dtype).at[st].add(out_copies)


def fused_moe_mlp_fwd(
    x: jax.Array,               # (T, d)
    router: jax.Array,          # (d, E)
    wg: jax.Array, wu: jax.Array, wo: jax.Array,
    *,
    k: int,
    capacity: int,
    block_c: int = 128,
    interpret: bool = False,
    combine: str = "kernel",    # "kernel" | "xla" (the A/B + fallback)
) -> Tuple[jax.Array, jax.Array]:
    """Full fused MoE forward: routing → fused kernel → in-kernel combine.

    Returns ``(out (T, d), aux)``; matches
    :func:`repro.kernels.ref.fused_moe_mlp_ref` (parity-tested), and the
    two combine paths match each other bit-exactly (property-tested).
    """
    T, _ = x.shape
    E = router.shape[1]
    C = capacity
    slot_tok, slot_gate, st, slot, keep, aux = moe_routing(x, router, k, C)
    y = fused_moe_gemm(x, wg, wu, wo, slot_tok, slot_gate,
                       block_c=block_c, interpret=interpret)
    if combine == "kernel":
        # gates were applied in-kernel; dropped copies never got a slot and
        # empty slots are gate-zeroed, so the one-hot contraction is the
        # whole combine
        out = fused_moe_combine(y, slot_tok, T, interpret=interpret)
    else:
        out = _combine_xla(y, st, slot, keep, T, E, C)
    return out, aux
