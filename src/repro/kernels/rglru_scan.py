"""RG-LRU linear recurrence (h_t = a_t * h_{t-1} + x_t) as a Pallas kernel.

TPU adaptation: the recurrence is diagonal, so the channel dimension is
embarrassingly parallel — we tile W into 128-lane blocks (VPU native) and the
grid walks (batch, channel-block, seq-chunk) with the sequence chunk
INNERMOST; the carry h lives in VMEM scratch across chunks.  Inside a chunk,
a fori_loop runs the recurrence on (1, bw) rows — for seq chunk L and lane
block bw the work is L fused multiply-adds over 128-wide vectors, which is
exactly what the VPU wants; no log-depth scan tricks are needed because the
FLOP count is tiny and the kernel is bandwidth-bound (the roofline term is
bytes, not flops).

Numerical note: a_t in (0, 1) and x pre-scaled by sqrt(1 - a^2) upstream; the
recurrence is run in float32 regardless of the input dtype (bf16-safe).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_body(a, x, y_ref, h_ref, *, chunk: int):
    """Shared recurrence over already-loaded f32 (chunk, bw) tiles; the f32
    and int8 (in-kernel dequant) kernels differ only in how x reaches f32."""
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, carry):
        h = carry
        h = a[t] * h + x[t]
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[0])
    h_ref[0] = h


def _rglru_kernel(a_ref, x_ref, y_ref, h_ref, *, chunk: int):
    _rglru_body(
        a_ref[0].astype(jnp.float32), x_ref[0].astype(jnp.float32),
        y_ref, h_ref, chunk=chunk,
    )


def _rglru_int8_kernel(a_ref, x_ref, xs_ref, y_ref, h_ref, *, chunk: int):
    # int8 gated-input tile + (chunk, 1) per-row scales; the decay stays f32
    # because the seq padding must be exactly 1.0 (carry pass-through) and
    # its values in (0, 1) drive the recurrence's stability.  The carry h is
    # f32 VMEM scratch in both variants.
    _rglru_body(
        a_ref[0].astype(jnp.float32),
        x_ref[0].astype(jnp.float32) * xs_ref[0],
        y_ref, h_ref, chunk=chunk,
    )


def rglru_scan(
    a: jax.Array,               # (B, S, W) decay in (0, 1)
    x: jax.Array,               # (B, S, W)
    *,
    block_w: int = 256,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, W = a.shape
    bw = min(block_w, W)
    L = min(chunk, S)
    pad_s = (-S) % L
    pad_w = (-W) % bw
    if pad_s or pad_w:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_w)))
        # pad decay with ones so the carry passes through harmlessly
        if pad_s:
            a = a.at[:, S:].set(1.0)
        x = jnp.pad(x, ((0, 0), (0, pad_s), (0, pad_w)))
    Sp, Wp = a.shape[1], a.shape[2]
    n_chunks, n_w = Sp // L, Wp // bw

    grid = (B, n_w, n_chunks)
    out = pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=L),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, bw), lambda b, iw, ic: (b, ic, iw)),
            pl.BlockSpec((1, L, bw), lambda b, iw, ic: (b, ic, iw)),
        ],
        out_specs=pl.BlockSpec((1, L, bw), lambda b, iw, ic: (b, ic, iw)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, Wp), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, x)
    return out[:, :S, :W]


def rglru_scan_int8(
    a: jax.Array,               # (B, S, W) decay in (0, 1), float
    x: jax.Array,               # (B, S, W) int8 gated input
    x_scale: jax.Array,         # (B, S, 1) f32 per-row scales
    *,
    block_w: int = 256,
    chunk: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """RG-LRU scan over an int8 gated input with in-kernel dequantization.

    Same grid/blocking as :func:`rglru_scan`; the (1, L, 1) scale block
    rides the x index map with the channel coordinate pinned to 0 (one
    scale per timestep row serves every channel block)."""
    B, S, W = a.shape
    assert x.dtype == jnp.int8, x.dtype
    bw = min(block_w, W)
    L = min(chunk, S)
    pad_s = (-S) % L
    pad_w = (-W) % bw
    if pad_s or pad_w:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_w)))
        if pad_s:
            a = a.at[:, S:].set(1.0)
        x = jnp.pad(x, ((0, 0), (0, pad_s), (0, pad_w)))
        # zero scales on padded steps: x dequantizes to 0, a = 1 passes the
        # carry through — padding cannot perturb real rows
        x_scale = jnp.pad(x_scale, ((0, 0), (0, pad_s), (0, 0)))
    Sp, Wp = a.shape[1], a.shape[2]
    n_chunks, n_w = Sp // L, Wp // bw

    out = pl.pallas_call(
        functools.partial(_rglru_int8_kernel, chunk=L),
        grid=(B, n_w, n_chunks),
        in_specs=[
            pl.BlockSpec((1, L, bw), lambda b, iw, ic: (b, ic, iw)),
            pl.BlockSpec((1, L, bw), lambda b, iw, ic: (b, ic, iw)),
            pl.BlockSpec((1, L, 1), lambda b, iw, ic: (b, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, bw), lambda b, iw, ic: (b, ic, iw)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, Wp), out_dtype),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, x, x_scale)
    return out[:, :S, :W]
