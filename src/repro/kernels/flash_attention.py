"""Flash attention (forward) as a Pallas TPU kernel.

TPU-native design (not a CUDA port):
  * Grid ``(B, H, Sq/bq, Skv/bk)`` — the KV dimension iterates INNERMOST, so
    the online-softmax running stats (m, l, acc) live in VMEM scratch and are
    carried across grid steps on the same core (TPU grids execute
    sequentially per core; no atomics / shared-memory reductions needed).
  * Block shapes: q (bq, D), k/v (bk, D) with bq/bk multiples of the 128-lane
    MXU tile; the two matmuls per block (q @ k^T and p @ v) hit the MXU at
    full tile occupancy for D in {64, 128, 256}.
  * GQA without materialization: the kv BlockSpec index_map divides the head
    index (h -> h // group) so K/V blocks are fetched once per kv-head group
    straight from HBM — the repeat happens in the dataflow, never in memory.
  * Causal/local-window masking is done by block skip (pl.when over the whole
    block) + within-block iota masks, so fully-masked blocks cost no FLOPs.

Backward runs through the same reference einsums via a custom_vjp residual
recompute (standard flash recompute strategy) — on CPU it falls back to the
pure-jnp oracle, keeping training differentiable everywhere.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_body(
    q_ref, kv_load, o_ref,               # q block, kv loader, out block
    m_ref, l_ref, acc_ref,               # VMEM scratch carried over kv steps
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    bq: int,
    bk: int,
    n_kv: int,
    seq_q: int,
    seq_kv: int,
):
    """Shared online-softmax sweep; ``kv_load() -> (k, v)`` f32 (bk, D) tiles.

    The int8 variant dequantizes inside ``kv_load`` — the running stats,
    masking, and MXU matmuls are identical, so both precisions share one
    sweep implementation."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale    # (bq, D)
        k, v = kv_load()                               # (bk, D) each, f32
        s = jax.lax.dot_general(                       # (bq, bk) on the MXU
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        mask = k_pos < seq_kv                          # right padding
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > (q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal or window is not None:
        # whole-block skip: block is live iff any (q, k) pair is unmasked
        first_q, last_q = iq * bq, iq * bq + bq - 1
        first_k, last_k = ik * bk, ik * bk + bk - 1
        live = jnp.bool_(True)
        if causal:
            live &= first_k <= last_q
        if window is not None:
            live &= last_k > first_q - window
        pl.when(live)(_compute)
    else:
        _compute()

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, **kw):
    _attn_body(
        q_ref,
        lambda: (k_ref[0, 0].astype(jnp.float32), v_ref[0, 0].astype(jnp.float32)),
        o_ref, m_ref, l_ref, acc_ref, **kw,
    )


def _attn_int8_kernel(
    q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, **kw
):
    # int8 K/V tiles ride with (bk, 1) f32 per-row scales on the same index
    # map; dequantize as the tile enters the sweep — K/V never exist in f32
    # outside this VMEM-resident block.
    _attn_body(
        q_ref,
        lambda: (
            k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0],
            v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0],
        ),
        o_ref, m_ref, l_ref, acc_ref, **kw,
    )


def flash_attention_fwd(
    q: jax.Array,                # (B, Sq, H, D)
    k: jax.Array,                # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Skv, 8))

    # (B, S, H, D) -> (B, H, S, D): contiguous (S, D) blocks per (batch, head)
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)

    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = qt.shape[2] // bq
    n_kv = kt.shape[2] // bk

    grid = (B, H, n_q, n_kv)
    kernel = functools.partial(
        _attn_kernel,
        scale=1.0 / math.sqrt(D),
        causal=causal,
        window=window,
        bq=bq,
        bk=bk,
        n_kv=n_kv,
        seq_q=Sq,
        seq_kv=Skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # m
            pltpu.VMEM((bq, 1), jnp.float32),     # l
            pltpu.VMEM((bq, D), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(qt, kt, vt)
    if pad_q:
        out = out[:, :, :Sq]
    return jnp.moveaxis(out, 1, 2)


def flash_attention_int8_fwd(
    q: jax.Array,                # (B, Sq, H, D) float
    k: jax.Array,                # (B, Skv, Hkv, D) int8
    k_scale: jax.Array,          # (B, Skv, Hkv, 1) f32 per-row scales
    v: jax.Array,                # (B, Skv, Hkv, D) int8
    v_scale: jax.Array,          # (B, Skv, Hkv, 1) f32
    *,
    causal: bool = False,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention over int8 K/V with in-sweep dequantization.

    Same grid/blocking as :func:`flash_attention_fwd`; the scale operands
    ride (1, 1, bk, 1) BlockSpecs on the K/V index map (GQA head-group
    divide included), so a K/V tile and its row scales always arrive
    together and the f32 K/V tile exists only inside VMEM.
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    assert k.dtype == jnp.int8 and v.dtype == jnp.int8, (k.dtype, v.dtype)
    group = H // Hkv
    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Skv, 8))

    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    kst = jnp.moveaxis(k_scale, 2, 1)
    vst = jnp.moveaxis(v_scale, 2, 1)

    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kv_pad = ((0, 0), (0, 0), (0, pad_k), (0, 0))
        kt = jnp.pad(kt, kv_pad)
        vt = jnp.pad(vt, kv_pad)
        kst = jnp.pad(kst, kv_pad)   # zero scales: pad rows dequantize to 0
        vst = jnp.pad(vst, kv_pad)
    n_q = qt.shape[2] // bq
    n_kv = kt.shape[2] // bk

    grid = (B, H, n_q, n_kv)
    kernel = functools.partial(
        _attn_int8_kernel,
        scale=1.0 / math.sqrt(D),
        causal=causal,
        window=window,
        bq=bq,
        bk=bk,
        n_kv=n_kv,
        seq_q=Sq,
        seq_kv=Skv,
    )
    kv_spec = pl.BlockSpec(
        (1, 1, bk, D), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)
    )
    sc_spec = pl.BlockSpec(
        (1, 1, bk, 1), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            kv_spec, sc_spec, kv_spec, sc_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # m
            pltpu.VMEM((bq, 1), jnp.float32),     # l
            pltpu.VMEM((bq, D), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(qt, kt, kst, vt, vst)
    if pad_q:
        out = out[:, :, :Sq]
    return jnp.moveaxis(out, 1, 2)
