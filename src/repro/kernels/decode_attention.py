"""Single-token decode attention (the serving hot spot) as a Pallas kernel.

Flash-decoding adapted to TPU: one query row per (batch, head) attends to the
KV cache in VMEM-sized chunks; running (m, l, acc) stats carried in scratch
across the innermost grid dimension (TPU sequential grid), masked by each
batch row's valid cache length.  The valid length arrives as a (B, 1) int32
block in SMEM-like VMEM — no scalar prefetch needed in interpret mode and the
layout is also legal on hardware.

q block is a single row (1, D); to keep the MXU fed the kv chunk (bk, D) is
multiplied as (bk, D) x (D, 1) — a skinny matmul the TPU lowers to VPU+MXU
hybrid; bk = 512 amortizes control overhead across the cache sweep.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _online_update(q, k, v, first_k, valid, window, m_ref, l_ref, acc_ref):
    """One online-softmax step: fold the (bk, D) chunk at offset ``first_k``
    into the running (m, l, acc) scratch stats.  q is pre-scaled (1, D) f32;
    k/v are already-dequantized (bk, D) f32."""
    bk = k.shape[0]
    s = jax.lax.dot_general(                                # (1, bk)
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    k_pos = first_k + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = k_pos < valid
    if window is not None:
        mask &= k_pos > (valid - 1 - window)
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new


def _decode_kernel(
    valid_ref, q_ref, k_ref, v_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *,
    scale: float,
    window: Optional[int],
    bk: int,
    n_kv: int,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = valid_ref[0, 0]                                 # () int32
    first_k = ik * bk
    live = first_k < valid

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                 # (bk, D)
        _online_update(q, k, v, first_k, valid, window, m_ref, l_ref, acc_ref)

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _decode_int8_kernel(
    valid_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *,
    scale: float,
    window: Optional[int],
    bk: int,
    n_kv: int,
):
    """:func:`_decode_kernel` over an int8 cache: the (bk, D) int8 chunk and
    its (bk, 1) per-row scales are dequantized in VMEM — HBM only ever moves
    the int8 bytes (+1/4·D scale column), ~4x less than the f32 cache."""
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = valid_ref[0, 0]
    first_k = ik * bk
    live = first_k < valid

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (1, D)
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0]  # (bk, D) * (bk, 1)
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0]
        _online_update(q, k, v, first_k, valid, window, m_ref, l_ref, acc_ref)

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_decode_kernel(
    table_ref,                  # scalar-prefetch: (B, NP) int32 block table
    valid_ref, q_ref, k_ref, v_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *,
    scale: float,
    window: Optional[int],
    page_size: int,
    n_pages: int,
):
    """Online-softmax decode over pool-resident KV pages.

    Identical math to :func:`_decode_kernel`, but the KV chunk for grid step
    (b, h, j) is DMA'd straight from page ``table[b, j]`` of the shared pool —
    the block table is scalar-prefetched so the index map can address pages
    before the body runs.  Shared prefix pages are fetched per-sequence but
    stored once (ref-counted by the serve-side BlockAllocator)."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = valid_ref[0, 0]
    first_k = j * page_size
    live = first_k < valid

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (1, D)
        k = k_ref[0, :, 0].astype(jnp.float32)              # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        _online_update(q, k, v, first_k, valid, window, m_ref, l_ref, acc_ref)

    @pl.when(j == n_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_decode_int8_kernel(
    table_ref,                  # scalar-prefetch: (B, NP) int32 block table
    valid_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *,
    scale: float,
    window: Optional[int],
    page_size: int,
    n_pages: int,
):
    """:func:`_paged_decode_kernel` over int8 pages + per-row scale pages;
    dequantize happens in VMEM after the page DMA."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = valid_ref[0, 0]
    first_k = j * page_size
    live = first_k < valid

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (1, D)
        k = k_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0]  # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0]
        _online_update(q, k, v, first_k, valid, window, m_ref, l_ref, acc_ref)

    @pl.when(j == n_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,               # (B, 1, H, D)
    k_pages: jax.Array,         # (P, page_size, Hkv, D)  shared page pool
    v_pages: jax.Array,
    block_table: jax.Array,     # (B, NP) int32 page ids per sequence
    valid_len: jax.Array,       # (B,) int32 valid positions per sequence
    *,
    window: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Decode attention driven by a block table over a paged KV pool.

    The block-table counterpart of :func:`decode_attention`: instead of a
    per-sequence contiguous cache, KV lives once in a ref-counted page pool
    and each sequence brings a table of page ids — the serving engine's
    paged-gather hot path (prefix blocks shared between sequences are read
    in place, never materialized per sequence)."""
    B, _, H, D = q.shape
    n_pool, page_size, Hkv = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    NP = block_table.shape[1]
    assert H % Hkv == 0
    group = H // Hkv

    qt = jnp.moveaxis(q, 2, 1)                              # (B, H, 1, D)
    valid2 = valid_len.astype(jnp.int32).reshape(B, 1)
    table = block_table.astype(jnp.int32)

    kernel = functools.partial(
        _paged_decode_kernel,
        scale=1.0 / math.sqrt(D), window=window,
        page_size=page_size, n_pages=NP,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, NP),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j, tbl: (b, 0)),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j, tbl: (b, h, 0, 0)),
            pl.BlockSpec(
                (1, page_size, 1, D),
                lambda b, h, j, tbl, g=group: (tbl[b, j], 0, h // g, 0),
            ),
            pl.BlockSpec(
                (1, page_size, 1, D),
                lambda b, h, j, tbl, g=group: (tbl[b, j], 0, h // g, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, j, tbl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        interpret=interpret,
    )(table, valid2, qt, k_pages, v_pages)
    return jnp.moveaxis(out, 1, 2)                          # (B, 1, H, D)


def paged_decode_attention_int8(
    q: jax.Array,               # (B, 1, H, D)
    k_pages: jax.Array,         # (P, page_size, Hkv, D) int8 page pool
    k_scales: jax.Array,        # (P, page_size, Hkv, 1) f32 per-row scales
    v_pages: jax.Array,
    v_scales: jax.Array,
    block_table: jax.Array,     # (B, NP) int32
    valid_len: jax.Array,       # (B,) int32
    *,
    window: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """:func:`paged_decode_attention` over an int8 page pool.

    The pool stores int8 KV rows + f32 per-row scales; each page is DMA'd as
    int8 (plus its scale column) and dequantized inside the kernel — the
    decode sweep moves ~1/4 the KV bytes of the f32 pool."""
    B, _, H, D = q.shape
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    NP = block_table.shape[1]
    assert H % Hkv == 0
    group = H // Hkv

    qt = jnp.moveaxis(q, 2, 1)                              # (B, H, 1, D)
    valid2 = valid_len.astype(jnp.int32).reshape(B, 1)
    table = block_table.astype(jnp.int32)

    kernel = functools.partial(
        _paged_decode_int8_kernel,
        scale=1.0 / math.sqrt(D), window=window,
        page_size=page_size, n_pages=NP,
    )
    page_spec = lambda shape: pl.BlockSpec(
        shape, lambda b, h, j, tbl, g=group: (tbl[b, j], 0, h // g, 0)
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, NP),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j, tbl: (b, 0)),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j, tbl: (b, h, 0, 0)),
            page_spec((1, page_size, 1, D)),
            page_spec((1, page_size, 1, 1)),
            page_spec((1, page_size, 1, D)),
            page_spec((1, page_size, 1, 1)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, j, tbl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        interpret=interpret,
    )(table, valid2, qt, k_pages, k_scales, v_pages, v_scales)
    return jnp.moveaxis(out, 1, 2)                          # (B, 1, H, D)


def decode_attention(
    q: jax.Array,               # (B, 1, H, D)
    k: jax.Array,               # (B, Skv, Hkv, D)  cache
    v: jax.Array,
    valid_len: jax.Array,       # (B,) int32
    *,
    window: Optional[int] = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, _, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    group = H // Hkv
    bk = min(block_k, max(Skv, 8))

    qt = jnp.moveaxis(q, 2, 1)                    # (B, H, 1, D)
    kt = jnp.moveaxis(k, 2, 1)                    # (B, Hkv, Skv, D)
    vt = jnp.moveaxis(v, 2, 1)
    pad_k = (-Skv) % bk
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_kv = kt.shape[2] // bk
    valid2 = valid_len.astype(jnp.int32).reshape(B, 1)

    grid = (B, H, n_kv)
    kernel = functools.partial(
        _decode_kernel, scale=1.0 / math.sqrt(D), window=window, bk=bk, n_kv=n_kv
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ik: (b, 0)),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(valid2, qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)                # (B, 1, H, D)


def decode_attention_int8(
    q: jax.Array,               # (B, 1, H, D)
    k: jax.Array,               # (B, Skv, Hkv, D) int8 cache
    k_scale: jax.Array,         # (B, Skv, Hkv, 1) f32 per-row scales
    v: jax.Array,
    v_scale: jax.Array,
    valid_len: jax.Array,       # (B,) int32
    *,
    window: Optional[int] = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """:func:`decode_attention` over an int8 cache + per-row scales,
    dequantized chunk-by-chunk inside the kernel."""
    B, _, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    assert k_scale.shape == (B, Skv, Hkv, 1), k_scale.shape
    group = H // Hkv
    bk = min(block_k, max(Skv, 8))

    qt = jnp.moveaxis(q, 2, 1)                    # (B, H, 1, D)
    kt = jnp.moveaxis(k, 2, 1)                    # (B, Hkv, Skv, D)
    vt = jnp.moveaxis(v, 2, 1)
    kst = jnp.moveaxis(k_scale, 2, 1)             # (B, Hkv, Skv, 1)
    vst = jnp.moveaxis(v_scale, 2, 1)
    pad_k = (-Skv) % bk
    if pad_k:
        pad = ((0, 0), (0, 0), (0, pad_k), (0, 0))
        kt, vt, kst, vst = (jnp.pad(t, pad) for t in (kt, vt, kst, vst))
    n_kv = kt.shape[2] // bk
    valid2 = valid_len.astype(jnp.int32).reshape(B, 1)

    grid = (B, H, n_kv)
    kernel = functools.partial(
        _decode_int8_kernel,
        scale=1.0 / math.sqrt(D), window=window, bk=bk, n_kv=n_kv,
    )
    kv_spec = lambda shape: pl.BlockSpec(
        shape, lambda b, h, ik, g=group: (b, h // g, ik, 0)
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ik: (b, 0)),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ik: (b, h, 0, 0)),
            kv_spec((1, 1, bk, D)),
            kv_spec((1, 1, bk, 1)),
            kv_spec((1, 1, bk, D)),
            kv_spec((1, 1, bk, 1)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(valid2, qt, kt, kst, vt, vst)
    return jnp.moveaxis(out, 1, 2)                # (B, 1, H, D)
