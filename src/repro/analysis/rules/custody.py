"""custody-taint: private-shard bytes must never reach a serialization /
network / checkpoint sink, and may only cross the host->device feed boundary
under a transfer guard (or with a CustodyEvent audit trail in scope).

STANNIS's core promise — private data never leaves the storage device — is
enforced at runtime by ``PermissionError`` guards that only fire on executed
paths.  This rule proves the complement statically: any value *derived from a
custody-guarded device read* (``device.read(...)``, ``device.assemble(...)``,
``device._materialize(...)``) is tainted, taint propagates through
assignments, containers, arithmetic, and method returns
(interprocedural-lite: one global summary pass marks methods like
``FleetBatcher.next_batch`` as taint-returning), and tainted values must not
reach:

  * serialization sinks — ``pickle/json/marshal.dump(s)``, ``np.save*``,
    ``.tofile(...)``, ``open(...)'d file .write(...)``;
  * network sinks — ``.send/.sendall/.post/.put`` method calls,
    ``socket.*``;
  * checkpoint sinks — ``.save(...)`` on a receiver whose name or
    constructor type mentions checkpoints (``ckpt.save``,
    ``CheckpointManager(...)``), ``save_checkpoint(...)``;
  * the feed boundary — ``.feed(...)`` / ``.feed_addressable(...)`` /
    ``jax.device_put(...)`` — UNLESS (a) the call is lexically inside a
    ``with jax.transfer_guard*`` block, (b) the resolved callee's own body
    establishes the guard (``MeshFeeder.feed_addressable`` does), or (c) the
    calling scope logs a ``CustodyEvent`` / appends to a custody log.

A guarded feed *sanitizes*: its result is the sanctioned on-device batch.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Rule, Violation, register
from repro.analysis.project import Module, Project, dotted_path
from repro.analysis.scopes import Scope, function_scopes, is_prefix

Path_ = Tuple[str, ...]

DEVICE_BASES = {"BaseStorageDevice", "StorageDevice"}
SOURCE_METHODS = {"read", "assemble", "_materialize"}
SERIALIZE_FUNCS = {
    ("pickle", "dump"), ("pickle", "dumps"),
    ("json", "dump"), ("json", "dumps"),
    ("marshal", "dump"), ("marshal", "dumps"),
    ("numpy", "save"), ("numpy", "savez"), ("numpy", "savez_compressed"),
}
NETWORK_METHODS = {"send", "sendall", "send_bytes", "post"}
FEED_METHODS = {"feed", "feed_addressable"}
CHECKPOINT_NAME_HINTS = ("ckpt", "checkpoint")


def _is_device_class(project: Project, name: Optional[str]) -> bool:
    if name is None:
        return False
    if name in DEVICE_BASES:
        return True
    return any(b in DEVICE_BASES for b in project.class_bases(name))


def _with_has_guard(withs) -> bool:
    for w in withs:
        for item in w.items:
            expr = item.context_expr
            call = expr if isinstance(expr, ast.Call) else None
            p = dotted_path(call.func if call else expr)
            if p and any("transfer_guard" in seg for seg in p):
                return True
    return False


def _feedish(name: str) -> bool:
    """Method names worth following when hunting for a transfer guard —
    the feed methods themselves plus wrappers like ``to_device_batch``."""
    return name in FEED_METHODS or "feed" in name or "device" in name


def _body_has_guard(project: Project, node: ast.AST, depth: int = 2) -> bool:
    """Does this function body establish a transfer guard — directly, via a
    self-call, or via a feed-ish helper (``to_device_batch`` ->
    ``feed_addressable``)?"""
    for n in ast.walk(node):
        if isinstance(n, ast.With):
            if _with_has_guard((n,)):
                return True
    if depth <= 0:
        return False
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            p = dotted_path(n.func)
            if not p:
                continue
            target = None
            if len(p) == 2 and p[0] == "self":
                target = _find_any_method(project, p[1])
            elif _feedish(p[-1]):
                target = _find_any_method(project, p[-1])
            if target is not None and _body_has_guard(
                    project, target, depth - 1):
                return True
    return False


def _find_any_method(project: Project, name: str) -> Optional[ast.AST]:
    for cls_name in project.classes:
        got = project.class_method(cls_name, name)
        if got is not None:
            return got[1]
    return None


def _scope_logs_custody(scope: Scope) -> bool:
    for info in scope.stmts:
        for call in info.calls:
            p = dotted_path(call.func)
            if p is None:
                continue
            if p[-1] == "CustodyEvent":
                return True
            if p[-1] == "append" and len(p) >= 2 and "custody" in p[-2]:
                return True
    return False


class _Tainter:
    """Statement-ordered taint propagation for one function scope."""

    def __init__(self, project: Project, mod: Module, scope: Scope,
                 taint_returning: Set[Tuple[str, str]],
                 tainted_attrs: Set[Path_]):
        self.project = project
        self.mod = mod
        self.scope = scope
        self.taint_returning = taint_returning
        self.tainted: Set[Path_] = set(tainted_attrs)
        self.local_types: Dict[str, str] = {}
        self.open_files: Set[str] = set()
        self._withs: Tuple[ast.With, ...] = ()
        args = getattr(scope.node, "args", None)
        if args is not None:
            for a in list(args.posonlyargs) + list(args.args) \
                    + list(args.kwonlyargs):
                t = self._ann_class(a.annotation)
                if t:
                    self.local_types[a.arg] = t
        if scope.class_name and _is_device_class(project, scope.class_name):
            self.local_types["self"] = scope.class_name

    def _ann_class(self, ann) -> Optional[str]:
        if ann is None:
            return None
        if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
            name = ann.value.split(".")[-1].split("[")[0]
        else:
            p = dotted_path(ann)
            name = p[-1] if p else None
        if name and (name in self.project.classes or name in DEVICE_BASES):
            return name
        return None

    # -- classification ----------------------------------------------------

    def _recv_type(self, recv: Path_) -> Optional[str]:
        if len(recv) == 1:
            return self.local_types.get(recv[0])
        if recv[0] == "self" and len(recv) == 2 and self.scope.class_name:
            return self.project.attr_types(self.scope.class_name).get(recv[1])
        return None

    def is_source_call(self, call: ast.Call) -> bool:
        p = dotted_path(call.func)
        if p is None or len(p) < 2:
            return False
        recv, meth = p[:-1], p[-1]
        if meth not in SOURCE_METHODS:
            return False
        # device-typed receiver, `self` inside a device class, a name that
        # smells like a device, or the result of `.device(...)`
        t = self._recv_type(recv)
        if _is_device_class(self.project, t):
            return True
        if recv[-1] in ("device", "dev") or "device" in recv[-1]:
            return True
        return False

    def is_sanitizing_call(self, call: ast.Call) -> bool:
        """A guarded feed sanitizes: its result is the sanctioned on-device
        batch, so taint dies at the boundary instead of contaminating every
        downstream loss scalar and trained parameter.  Guarded means the call
        is lexically under ``with jax.transfer_guard*``, or the resolved
        callee's own body establishes the guard (``MeshFeeder.
        feed_addressable`` does), transitively through feed-ish wrappers
        (``to_device_batch``, ``next_device_batch``)."""
        p = dotted_path(call.func)
        resolved = self.mod.resolve(p) if p else None
        is_feed = bool(p and p[-1] in FEED_METHODS)
        is_dput = bool(
            resolved and tuple(resolved[-2:]) == ("jax", "device_put"))
        if is_feed or is_dput:
            if _with_has_guard(self._withs):
                return True
            if is_feed:
                target = _find_any_method(self.project, p[-1])
                return target is not None and _body_has_guard(
                    self.project, target)
            return False
        if p and _feedish(p[-1]):
            target = _find_any_method(self.project, p[-1])
            if target is None:
                target = self._local_func(p[-1])
            return target is not None and _body_has_guard(
                self.project, target)
        return False

    def _local_func(self, name: str) -> Optional[ast.AST]:
        for node in self.mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == name:
                return node
        return None

    def call_taints(self, call: ast.Call) -> bool:
        if self.is_sanitizing_call(call):
            return False
        if self.is_source_call(call):
            return True
        p = dotted_path(call.func)
        if p is not None and self._summary_taints(p):
            return True
        # X.read/.assemble where X itself is a call (fleet.device(w).read)
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in SOURCE_METHODS \
                and isinstance(call.func.value, ast.Call):
            inner = dotted_path(call.func.value.func)
            if inner and inner[-1] == "device":
                return True
        # any call with a tainted argument conservatively returns taint
        for a in list(call.args) + [kw.value for kw in call.keywords]:
            if self.expr_tainted(a):
                return True
        return False

    def _summary_taints(self, p: Path_) -> bool:
        """Does the (owner, name)-keyed summary mark this call
        taint-returning?  Bare calls match module-level functions (owner
        ``""``); method calls match the receiver's resolved class and its
        bases when known, else fall back to any same-named METHOD — a
        module-level ``run()`` that returns taint must not poison every
        ``obj.run()`` in the repo."""
        name = p[-1]
        if len(p) == 1:
            return ("", name) in self.taint_returning
        t = self._recv_type(p[:-1])
        if t is not None:
            owners = {t, *self.project.class_bases(t)}
            return any((o, name) in self.taint_returning for o in owners)
        return any(owner and n == name for owner, n in self.taint_returning)

    def expr_tainted(self, expr: Optional[ast.AST]) -> bool:
        if expr is None:
            return False
        if isinstance(expr, ast.Call):
            if self.is_sanitizing_call(expr):
                return False  # taint dies at the guarded feed boundary
            if self.call_taints(expr):
                return True
            return any(self.expr_tainted(c)
                       for c in ast.iter_child_nodes(expr))
        if isinstance(expr, (ast.Name, ast.Attribute)):
            path = dotted_path(expr)
            if path is not None:  # a maximal load chain — don't descend
                return any(is_prefix(t, path) or is_prefix(path, t)
                           for t in self.tainted)
        return any(self.expr_tainted(c) for c in ast.iter_child_nodes(expr))

    # -- propagation -------------------------------------------------------

    def propagate(self, info) -> None:
        self._withs = info.withs
        node = info.node
        if isinstance(node, ast.Assign):
            self._assign(node.targets, node.value)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            self._assign([node.target], node.value)
        elif isinstance(node, ast.AugAssign):
            if self.expr_tainted(node.value):
                p = dotted_path(node.target)
                if p:
                    self.tainted.add(p)
        elif isinstance(node, ast.For):
            if self.expr_tainted(node.iter):
                for p in [dotted_path(node.target)] if dotted_path(
                        node.target) else []:
                    self.tainted.add(p)
                if isinstance(node.target, (ast.Tuple, ast.List)):
                    for el in node.target.elts:
                        p = dotted_path(el)
                        if p:
                            self.tainted.add(p)
        elif isinstance(node, ast.With):
            for item in node.items:
                if item.optional_vars is None:
                    continue
                p = dotted_path(item.optional_vars)
                if p is None:
                    continue
                cp = dotted_path(item.context_expr.func) if isinstance(
                    item.context_expr, ast.Call) else None
                if cp and cp[-1] == "open":
                    self.open_files.add(p[0])
                if self.expr_tainted(item.context_expr):
                    self.tainted.add(p)

    def _assign(self, targets, value) -> None:
        # type tracking: x = SomeClass(...) / f = open(...)
        if isinstance(value, ast.Call):
            callee = dotted_path(value.func)
            if callee and len(targets) == 1:
                tp = dotted_path(targets[0])
                if tp and len(tp) == 1:
                    if callee[-1] in self.project.classes:
                        self.local_types[tp[0]] = callee[-1]
                    if callee[-1] == "open":
                        self.open_files.add(tp[0])
        value_tainted = self.expr_tainted(value)
        for tgt in targets:
            self._taint_target(tgt, value_tainted)

    def _taint_target(self, tgt, value_tainted: bool) -> None:
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for el in tgt.elts:
                self._taint_target(el, value_tainted)
            return
        if isinstance(tgt, ast.Starred):
            self._taint_target(tgt.value, value_tainted)
            return
        if isinstance(tgt, ast.Subscript):
            base = dotted_path(tgt.value)
            if base and value_tainted:
                self.tainted.add(base)  # out[r] = device.read(...) taints out
            return
        p = dotted_path(tgt)
        if p is None:
            return
        if value_tainted:
            self.tainted.add(p)
        else:
            self.tainted.discard(p)


def _method_summaries(
    project: Project,
) -> Tuple[Set[Tuple[str, str]], Dict[str, Set[Path_]]]:
    """((owner class or "", name) pairs whose return value is tainted,
        class name -> tainted ``self.x`` attribute paths)."""
    taint_returning: Set[Tuple[str, str]] = set()
    tainted_attrs: Dict[str, Set[Path_]] = {}
    for _round in range(2):  # 2 passes reach a fixpoint for 1-deep chains
        for mod in project.modules.values():
            if not project.is_analyzed(mod.path):
                continue
            for scope in function_scopes(mod.tree):
                attrs = tainted_attrs.get(scope.class_name or "", set())
                t = _Tainter(project, mod, scope, taint_returning, attrs)
                returns_taint = False
                for info in scope.stmts:
                    t.propagate(info)
                    if isinstance(info.node, ast.Return) \
                            and t.expr_tainted(info.node.value):
                        returns_taint = True
                if returns_taint:
                    taint_returning.add(
                        (scope.class_name or "", scope.node.name))
                if scope.class_name:
                    new_attrs = {p for p in t.tainted
                                 if len(p) >= 2 and p[0] == "self"}
                    if new_attrs:
                        tainted_attrs.setdefault(
                            scope.class_name, set()).update(new_attrs)
    return taint_returning, tainted_attrs


@register
class CustodyTaint(Rule):
    name = "custody-taint"
    description = (
        "values derived from StorageDevice custody reads must not reach "
        "serialization/network/checkpoint sinks, and may cross the "
        "feed/device_put boundary only under a transfer guard (or with a "
        "CustodyEvent audit in scope)"
    )

    def run(self, project: Project) -> List[Violation]:
        taint_returning, tainted_attrs = _method_summaries(project)
        out: List[Violation] = []
        for mod in project.analyzed_modules():
            for scope in function_scopes(mod.tree):
                out.extend(self._check_scope(
                    project, mod, scope, taint_returning,
                    tainted_attrs.get(scope.class_name or "", set())))
        return out

    def _check_scope(self, project: Project, mod: Module, scope: Scope,
                     taint_returning: Set[str],
                     tainted_attrs: Set[Path_]) -> List[Violation]:
        t = _Tainter(project, mod, scope, taint_returning, tainted_attrs)
        logs_custody = _scope_logs_custody(scope)
        out: List[Violation] = []
        for info in scope.stmts:
            t._withs = info.withs
            for call in info.calls:
                v = self._check_call(project, mod, scope, t, info, call,
                                     logs_custody)
                if v is not None:
                    out.append(v)
            t.propagate(info)
        return out

    def _check_call(self, project: Project, mod: Module, scope: Scope,
                    t: _Tainter, info, call: ast.Call,
                    logs_custody: bool) -> Optional[Violation]:
        p = dotted_path(call.func)
        resolved = mod.resolve(p) if p else None
        argexprs = list(call.args) + [kw.value for kw in call.keywords]
        tainted_arg = any(t.expr_tainted(a) for a in argexprs)
        if not tainted_arg:
            return None

        # -- serialization sinks ------------------------------------------
        if resolved and (tuple(resolved[-2:]) in SERIALIZE_FUNCS
                         or tuple(resolved[:1]) == ("socket",)):
            return self.violation(
                mod.path, call,
                f"custody-tainted value reaches serialization/network sink "
                f"'{'.'.join(p)}' — private shard bytes must never be "
                f"persisted or sent off-device",
                symbol=scope.qualname)
        if p and p[-1] == "tofile":
            return self.violation(
                mod.path, call,
                "custody-tainted array written to disk via .tofile()",
                symbol=scope.qualname)
        if p and p[-1] in NETWORK_METHODS and len(p) >= 2:
            return self.violation(
                mod.path, call,
                f"custody-tainted value sent through '{'.'.join(p)}'",
                symbol=scope.qualname)
        if p and p[-1] == "write" and len(p) >= 2 \
                and p[0] in t.open_files:
            return self.violation(
                mod.path, call,
                "custody-tainted value written to an open()'d file",
                symbol=scope.qualname)

        # -- checkpoint sinks ---------------------------------------------
        if p and p[-1] in ("save", "save_checkpoint", "write_checkpoint"):
            recv = p[:-1]
            recv_type = t._recv_type(recv) if recv else None
            hinted = (
                p[-1] != "save"
                or (recv and any(h in recv[-1].lower()
                                 for h in CHECKPOINT_NAME_HINTS))
                or (recv_type and "checkpoint" in recv_type.lower())
            )
            if hinted:
                return self.violation(
                    mod.path, call,
                    f"custody-tainted value reaches checkpoint sink "
                    f"'{'.'.join(p)}' — private shard bytes must not be "
                    f"checkpointed",
                    symbol=scope.qualname)

        # -- the feed boundary --------------------------------------------
        is_feed = bool(p and p[-1] in FEED_METHODS)
        is_device_put = bool(
            resolved and tuple(resolved[-2:]) == ("jax", "device_put"))
        if is_feed or is_device_put:
            if _with_has_guard(info.withs):
                return None
            if is_feed:
                target = _find_any_method(project, p[-1])
                if target is not None and _body_has_guard(project, target):
                    return None
            if logs_custody:
                return None
            what = "jax.device_put" if is_device_put else "." + p[-1] + "()"
            return self.violation(
                mod.path, call,
                f"custody-tainted batch crosses the host->device boundary "
                f"via {what} without a transfer_guard context or a "
                f"CustodyEvent audit in scope",
                symbol=scope.qualname)
        return None
