"""jit-purity: functions that get traced (``jax.jit``, ``pl.pallas_call``)
must be pure — host-side effects bake a single stale value into the compiled
program (or silently differ between trace and execution):

  * calls into stdlib ``random`` / ``time`` / ``datetime`` / ``uuid`` /
    ``secrets`` and ``numpy.random`` — traced ONCE, constant thereafter
    (``jax.random`` is of course fine);
  * host I/O: ``print`` / ``input`` / ``open`` / ``os.environ`` /
    ``os.getenv`` — executes at trace time, not at step time;
  * iteration over a set literal / ``set(...)`` — hash-order varies across
    processes, so two hosts can trace different programs (the SPMD
    divergence failure mode);
  * capturing a mutable (list/dict/set) that the enclosing scope mutates —
    the trace snapshots the value at trace time; later mutations are
    silently ignored.

Discovery: ``jax.jit(f)`` / ``jax.jit(self._f)`` / ``@jax.jit`` /
``@partial(jax.jit, ...)`` / ``pl.pallas_call(kernel, ...)``, plus lambdas
passed directly.  Checks recurse depth-3 into same-module callees and
same-class ``self._helper`` methods.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Rule, Violation, register
from repro.analysis.project import Module, Project, dotted_path
from repro.analysis.scopes import function_scopes

IMPURE_MODULES = {"random", "time", "datetime", "uuid", "secrets"}
IMPURE_BUILTINS = {"print", "input", "open"}
MUTATORS = {"append", "extend", "update", "pop", "insert", "setdefault",
            "clear", "remove", "add", "popitem"}


def _resolved(mod: Module, node: ast.AST) -> Optional[Tuple[str, ...]]:
    p = dotted_path(node)
    return mod.resolve(p) if p else None


def _is_jit(mod: Module, call: ast.Call) -> bool:
    r = _resolved(mod, call.func)
    return bool(r) and r[-2:] == ("jax", "jit")


def _is_pallas_call(mod: Module, call: ast.Call) -> bool:
    r = _resolved(mod, call.func)
    return bool(r) and r[-1] == "pallas_call"


def _is_partial_jit(mod: Module, call: ast.Call) -> bool:
    r = _resolved(mod, call.func)
    if not r or r[-1] != "partial":
        return False
    return bool(call.args) and isinstance(call.args[0], (ast.Name,
                                                         ast.Attribute)) \
        and _resolved(mod, call.args[0]) is not None \
        and _resolved(mod, call.args[0])[-2:] == ("jax", "jit")


class _FnIndex:
    """Function definitions reachable by name within one module."""

    def __init__(self, mod: Module):
        self.mod = mod
        self.by_name: Dict[str, ast.AST] = {}
        self.methods: Dict[Tuple[str, str], ast.AST] = {}
        for scope in function_scopes(mod.tree):
            self.by_name.setdefault(scope.node.name, scope.node)
            if scope.class_name:
                self.methods[(scope.class_name, scope.node.name)] = scope.node


@register
class JitPurity(Rule):
    name = "jit-purity"
    description = (
        "traced (jitted / pallas) functions must not call random/time/"
        "datetime/print/open, iterate sets, or capture mutated mutables"
    )

    def run(self, project: Project) -> List[Violation]:
        out: List[Violation] = []
        for mod in project.analyzed_modules():
            out.extend(self._check_module(mod))
        # dedupe: the same function may be jitted from several sites
        seen = set()
        uniq = []
        for v in out:
            key = (v.path, v.line, v.message)
            if key not in seen:
                seen.add(key)
                uniq.append(v)
        return uniq

    def _check_module(self, mod: Module) -> List[Violation]:
        index = _FnIndex(mod)
        out: List[Violation] = []

        # 1. decorated defs
        for scope in function_scopes(mod.tree):
            for dec in getattr(scope.node, "decorator_list", []):
                jitted = False
                if isinstance(dec, ast.Call):
                    jitted = _is_jit(mod, dec) or _is_partial_jit(mod, dec)
                else:
                    r = _resolved(mod, dec)
                    jitted = bool(r) and r[-2:] == ("jax", "jit")
                if jitted:
                    out.extend(self._check_traced(
                        mod, index, scope.node, scope.qualname,
                        scope.class_name))

        # 2. jax.jit(f, ...) / pl.pallas_call(kernel, ...) call sites
        for scope in function_scopes(mod.tree):
            for node in ast.walk(scope.node):
                if not isinstance(node, ast.Call):
                    continue
                if not (_is_jit(mod, node) or _is_pallas_call(mod, node)):
                    continue
                if not node.args:
                    continue
                target = node.args[0]
                fn = self._resolve_target(index, scope.class_name, target)
                if fn is None:
                    continue
                qual = getattr(fn, "name", "<lambda>")
                out.extend(self._check_traced(
                    mod, index, fn, qual, scope.class_name))
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    out.extend(self._check_capture(
                        mod, scope.node, fn, node))
        return out

    def _resolve_target(self, index: _FnIndex, cls: Optional[str],
                        target: ast.AST) -> Optional[ast.AST]:
        if isinstance(target, ast.Lambda):
            return target
        p = dotted_path(target)
        if p is None:
            return None
        if len(p) == 1:
            return index.by_name.get(p[0])
        if p[0] == "self" and len(p) == 2 and cls:
            return index.methods.get((cls, p[1]))
        return None

    # -- purity of the traced body ----------------------------------------

    def _check_traced(self, mod: Module, index: _FnIndex, fn: ast.AST,
                      qual: str, cls: Optional[str],
                      depth: int = 3,
                      seen: Optional[Set[int]] = None) -> List[Violation]:
        seen = seen if seen is not None else set()
        if id(fn) in seen:
            return []
        seen.add(id(fn))
        out: List[Violation] = []
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for node in ast.walk(ast.Module(body=body, type_ignores=[])):
            if isinstance(node, ast.Call):
                out.extend(self._check_call(mod, node, qual))
                if depth > 0:
                    callee = self._resolve_target(index, cls, node.func)
                    if callee is not None and id(callee) not in seen:
                        out.extend(self._check_traced(
                            mod, index, callee, qual, cls,
                            depth - 1, seen))
            elif isinstance(node, (ast.For, ast.comprehension)):
                it = node.iter
                if isinstance(it, ast.Set) or (
                        isinstance(it, ast.Call)
                        and dotted_path(it.func) == ("set",)):
                    out.append(self.violation(
                        mod.path, node if isinstance(node, ast.For) else it,
                        "iteration over a set inside a traced function — "
                        "hash order varies per process, so SPMD hosts can "
                        "trace different programs (sort it first)",
                        symbol=qual))
            elif isinstance(node, ast.Attribute):
                r = _resolved(mod, node)
                if r and r[:2] == ("os", "environ"):
                    out.append(self.violation(
                        mod.path, node,
                        "os.environ read inside a traced function is baked "
                        "in at trace time",
                        symbol=qual))
        return out

    def _check_call(self, mod: Module, call: ast.Call,
                    qual: str) -> List[Violation]:
        r = _resolved(mod, call.func)
        if not r:
            return []
        root = r[0]
        if root in IMPURE_MODULES and len(r) > 1:
            return [self.violation(
                mod.path, call,
                f"'{'.'.join(r)}' called inside a traced function — the "
                f"value is frozen at trace time (use jax.random / pass it "
                f"in as an argument)", symbol=qual)]
        if r[:2] == ("numpy", "random") or (root == "numpy"
                                            and "random" in r):
            return [self.violation(
                mod.path, call,
                f"'{'.'.join(r)}' inside a traced function — host RNG is "
                f"frozen at trace time; thread a jax.random key instead",
                symbol=qual)]
        if len(r) == 1 and r[0] in IMPURE_BUILTINS:
            return [self.violation(
                mod.path, call,
                f"host '{r[0]}()' inside a traced function executes at "
                f"trace time only (use jax.debug.print / move it out)",
                symbol=qual)]
        if r[:2] in (("os", "getenv"), ("os", "urandom")):
            return [self.violation(
                mod.path, call,
                f"'{'.'.join(r)}' inside a traced function is baked in at "
                f"trace time", symbol=qual)]
        return []

    # -- mutable closure capture ------------------------------------------

    def _check_capture(self, mod: Module, enclosing: ast.AST,
                       fn: ast.AST, jit_call: ast.Call) -> List[Violation]:
        free = _free_names(fn)
        if not free:
            return []
        mutable_locals: Dict[str, ast.AST] = {}
        for node in ast.walk(enclosing):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name = node.targets[0].id
                v = node.value
                is_mut = isinstance(v, (ast.List, ast.Dict, ast.Set)) or (
                    isinstance(v, ast.Call)
                    and dotted_path(v.func) in (("list",), ("dict",),
                                                ("set",)))
                if is_mut:
                    mutable_locals[name] = node
        out = []
        for name in sorted(free & set(mutable_locals)):
            if _is_mutated(enclosing, name):
                out.append(self.violation(
                    mod.path, jit_call,
                    f"traced function captures mutable '{name}' that the "
                    f"enclosing scope mutates — the trace snapshots it "
                    f"once; later mutations are silently ignored",
                    symbol=getattr(fn, "name", "<lambda>")))
        return out


def _free_names(fn: ast.AST) -> Set[str]:
    bound: Set[str] = set()
    args = getattr(fn, "args", None)
    if args is not None:
        for a in list(args.posonlyargs) + list(args.args) \
                + list(args.kwonlyargs):
            bound.add(a.arg)
        if args.vararg:
            bound.add(args.vararg.arg)
        if args.kwarg:
            bound.add(args.kwarg.arg)
    loaded: Set[str] = set()
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for node in ast.walk(ast.Module(body=body, type_ignores=[])):
        if isinstance(node, ast.Name):
            if isinstance(node.ctx, ast.Store):
                bound.add(node.id)
            elif isinstance(node.ctx, ast.Load):
                loaded.add(node.id)
    return loaded - bound


def _is_mutated(enclosing: ast.AST, name: str) -> bool:
    for node in ast.walk(enclosing):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr in MUTATORS \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id == name:
                return True
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Subscript) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id == name:
                    return True
    return False
