"""Rule modules — importing this package registers every rule."""
from repro.analysis.rules import coverage, custody, donation, purity  # noqa: F401
