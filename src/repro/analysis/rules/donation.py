"""use-after-donate: a buffer passed at a ``donate_argnums`` position of a
jitted callable is dead after the call — XLA may alias its memory to an
output — so reading it afterwards in the same scope is a latent
use-of-freed-buffer bug (it "works" on CPU today and corrupts on TPU).

Resolution is interprocedural-lite:

  * ``f = jax.jit(fn, donate_argnums=...)`` — local and module names;
  * ``self.decode = jax.jit(..., donate_argnums=(2,))`` — class attributes
    (``serve/runner.py`` style), reached through ``self.decode(...)``,
    ``obj.decode(...)`` where ``obj = StepRunner(...)``, and
    ``self.runner.decode(...)`` via constructor-assigned attribute types;
  * ``CompiledStep(step_fn=jitted, ...)`` — jitted callables stored into
    constructor keywords, reached through return-annotated accessors
    (``compiled = self.compile()  # -> CompiledStep``).

A call whose result rebinds the donated path in the same statement
(``tok, _, cache = self._serve(params, tok, cache, pos)``) is the sanctioned
idiom.  ``jax.jit(...).lower(...)`` never *executes* the program, so AOT
lowering chains are exempt.  Donating inside a loop without rebinding the
donated name anywhere in the loop body is flagged even without a later read:
the next iteration feeds the donated buffer back in.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.core import Rule, Violation, register
from repro.analysis.project import Module, Project, dotted_path
from repro.analysis.scopes import Scope, function_scopes, is_prefix

Path_ = Tuple[str, ...]


def _donate_positions(module: Module, call: ast.Call) -> Optional[Tuple[int, ...]]:
    """donate_argnums of a ``jax.jit(...)`` call, or None if not one."""
    resolved = module.resolve_call(call)
    if not resolved or resolved[-2:] != ("jax", "jit"):
        return None
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)):
            got = []
            for el in v.elts:
                if isinstance(el, ast.Constant) and isinstance(el.value, int):
                    got.append(el.value)
            return tuple(got) if got else None
    return None


def _annotation_class(project: Project, ann: Optional[ast.AST]) -> Optional[str]:
    if ann is None:
        return None
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        name = ann.value.split(".")[-1].split("[")[0]
    else:
        p = dotted_path(ann)
        name = p[-1] if p else None
    return name if name and name in project.classes else None


class _DonationIndex:
    """Which (class, attr) / local names are donating callables."""

    def __init__(self, project: Project):
        self.project = project
        # (class name, attr) -> donated positions
        self.class_attrs: Dict[Tuple[str, str], Tuple[int, ...]] = {}
        # class name -> {attr: class-name-of-value}  (constructor types)
        self.attr_types: Dict[str, Dict[str, str]] = {}
        # (class name, method) -> return-annotation class
        self.returns: Dict[Tuple[Optional[str], str], str] = {}
        for mod in project.modules.values():
            for scope in function_scopes(mod.tree):
                fn = scope.node
                ret = _annotation_class(project, getattr(fn, "returns", None))
                if ret:
                    self.returns[(scope.class_name, fn.name)] = ret
                self._scan_scope(mod, scope)
        for name in project.classes:
            self.attr_types[name] = project.attr_types(name)

    def _scan_scope(self, mod: Module, scope: Scope) -> None:
        local_jit: Dict[str, Tuple[int, ...]] = {}
        for info in scope.stmts:
            node = info.node
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                pos = _donate_positions(mod, node.value)
                for tgt in node.targets:
                    p = dotted_path(tgt)
                    if p is None:
                        continue
                    if pos is not None:
                        if len(p) == 1:
                            local_jit[p[0]] = pos
                        elif len(p) == 2 and p[0] == "self" and scope.class_name:
                            self.class_attrs[(scope.class_name, p[1])] = pos
            # jitted locals stored into constructor keywords:
            #   CompiledStep(step_fn=step_fn, ...)
            for call in info.calls:
                callee = dotted_path(call.func)
                if not callee or callee[-1] not in self.project.classes:
                    continue
                for kw in call.keywords:
                    if kw.arg is None:
                        continue
                    pos = None
                    if isinstance(kw.value, ast.Call):
                        pos = _donate_positions(mod, kw.value)
                    elif isinstance(kw.value, ast.Name):
                        pos = local_jit.get(kw.value.id)
                    if pos is not None:
                        self.class_attrs[(callee[-1], kw.arg)] = pos


@register
class UseAfterDonate(Rule):
    name = "use-after-donate"
    description = (
        "a buffer passed at a donate_argnums position of a jitted call must "
        "not be read again in the same scope (rebind it from the call's "
        "result); .lower() AOT chains are exempt"
    )

    def run(self, project: Project) -> List[Violation]:
        index = _DonationIndex(project)
        out: List[Violation] = []
        for mod in project.analyzed_modules():
            for scope in function_scopes(mod.tree):
                out.extend(self._check_scope(project, index, mod, scope))
        return out

    # -- per-scope ---------------------------------------------------------

    def _check_scope(self, project: Project, index: _DonationIndex,
                     mod: Module, scope: Scope) -> List[Violation]:
        local_jit: Dict[str, Tuple[int, ...]] = {}
        local_types: Dict[str, str] = {}
        # parameter annotations give local types too
        args = getattr(scope.node, "args", None)
        if args is not None:
            for a in list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs):
                t = _annotation_class(project, a.annotation)
                if t:
                    local_types[a.arg] = t

        def callee_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
            func = call.func
            # jax.jit(f, donate_argnums=...)(args) — immediate invocation
            if isinstance(func, ast.Call):
                return _donate_positions(mod, func)
            if isinstance(func, ast.Name):
                return local_jit.get(func.id)
            if isinstance(func, ast.Attribute):
                # AOT: jax.jit(...).lower(...) never executes the program
                if func.attr == "lower" and isinstance(func.value, ast.Call) \
                        and _donate_positions(mod, func.value) is not None:
                    return None
                p = dotted_path(func)
                if p is None:
                    return None
                recv, attr = p[:-1], p[-1]
                cls = None
                if recv == ("self",):
                    cls = scope.class_name
                elif len(recv) == 1:
                    cls = local_types.get(recv[0])
                elif len(recv) == 2 and recv[0] == "self" and scope.class_name:
                    cls = index.attr_types.get(scope.class_name, {}).get(recv[1])
                if cls is None:
                    return None
                return index.class_attrs.get((cls, attr))
            return None

        stmts = scope.stmts
        out: List[Violation] = []
        for info in stmts:
            node = info.node
            # track `f = jax.jit(...)` and `x = Cls(...)` / annotated returns
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                pos = _donate_positions(mod, node.value)
                callee = dotted_path(node.value.func)
                tgt0 = dotted_path(node.targets[0]) if len(node.targets) == 1 \
                    else None
                if tgt0 and len(tgt0) == 1:
                    if pos is not None:
                        local_jit[tgt0[0]] = pos
                    elif callee and callee[-1] in project.classes:
                        local_types[tgt0[0]] = callee[-1]
                    elif callee and len(callee) >= 2:
                        # x = self.compile()  ->  return annotation
                        recv = callee[:-1]
                        rcls = scope.class_name if recv == ("self",) \
                            else local_types.get(recv[0]) if len(recv) == 1 \
                            else None
                        ret = index.returns.get((rcls, callee[-1])) \
                            if rcls else None
                        if ret:
                            local_types[tgt0[0]] = ret

            for call in info.calls:
                positions = callee_positions(call)
                if not positions:
                    continue
                for argnum in positions:
                    if argnum >= len(call.args):
                        continue
                    donated = dotted_path(call.args[argnum])
                    if donated is None:
                        continue  # fresh expression — nothing aliases it
                    out.extend(self._check_donation(
                        mod, scope, stmts, info, call, donated))
        return out

    def _check_donation(self, mod: Module, scope: Scope,
                        stmts, info, call: ast.Call,
                        donated: Path_) -> List[Violation]:
        rebinds_here = any(is_prefix(s, donated) for s in info.stores)
        if rebinds_here:
            return []
        out: List[Violation] = []
        if info.loops:
            loop = info.loops[-1]
            in_loop = [s for s in stmts if loop in s.loops]
            if not any(is_prefix(st, donated)
                       for s in in_loop for st in s.stores):
                out.append(self.violation(
                    mod.path, call,
                    f"'{'.'.join(donated)}' is donated inside a loop but "
                    f"never rebound in the loop body — the next iteration "
                    f"passes a donated buffer",
                    symbol=scope.qualname,
                ))
                return out
        for later in stmts[info.index + 1:]:
            if any(is_prefix(st, donated) for st in later.stores):
                break
            hit = next((l for l in later.loads if is_prefix(donated, l)), None)
            if hit is not None:
                out.append(self.violation(
                    mod.path, later.node,
                    f"'{'.'.join(donated)}' read after being donated to a "
                    f"jitted call at line {call.lineno} (donate_argnums) — "
                    f"rebind it from the call's result",
                    symbol=scope.qualname,
                ))
                break
        return out
