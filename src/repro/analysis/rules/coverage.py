"""Coverage rules: conventions the repo relies on, promoted to checks.

kernel-parity-coverage — every public kernel exported from
``kernels/ops.py`` (defs AND public assignments like ``dequantize_int8 =
_deq``) must have a ``<name>_ref`` oracle in ``kernels/ref.py`` and a parity
test in ``tests/test_kernels.py`` that references BOTH ``ops.<name>`` and
``R.<name>_ref`` — an op whose oracle exists but is never compared against
is unverified.

sharding-rule-coverage — every logical axis name used in ``models/`` param
declarations (``builder.param(name, shape, axes)``), activation constraints
(``wlc(x, "batch", ...)``) and ``cache_logical_axes`` tables must appear in
the ``distributed/sharding.py`` rule tables (the ``make_rules`` dict literal
or a ``rules.setdefault(...)`` amendment) — an unlisted axis silently
replicates its tensor.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Rule, Violation, register
from repro.analysis.project import Module, Project, dotted_path

OPS_PATH = "src/repro/kernels/ops.py"
REF_PATH = "src/repro/kernels/ref.py"
KERNEL_TESTS_PATH = "tests/test_kernels.py"
SHARDING_PATH = "src/repro/distributed/sharding.py"
MODELS_PREFIX = "src/repro/models/"


def _public_exports(mod: Module) -> List[Tuple[str, int]]:
    out: List[Tuple[str, int]] = []
    for node in mod.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not node.name.startswith("_"):
                out.append((node.name, node.lineno))
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if name.startswith("_") or not name.islower():
                continue  # _private / CONSTANTS / TypeAliases
            if isinstance(node.value, ast.Constant):
                continue
            out.append((name, node.lineno))
    return out


def _toplevel_names(mod: Module) -> Set[str]:
    names: Set[str] = set()
    for node in mod.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
    return names


def _referenced_attrs(mod: Module) -> Set[Tuple[str, str]]:
    """Every ``base.attr`` reference in a module, as (base, attr) pairs."""
    out: Set[Tuple[str, str]] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Attribute):
            p = dotted_path(node)
            if p and len(p) >= 2:
                out.add((p[0], p[-1]))
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Name):
            out.add(("", node.id))
    return out


@register
class KernelParityCoverage(Rule):
    name = "kernel-parity-coverage"
    description = (
        "every public kernel in kernels/ops.py needs a *_ref oracle in "
        "kernels/ref.py and a parity test in tests/test_kernels.py that "
        "references both ops.<name> and <name>_ref"
    )

    def run(self, project: Project) -> List[Violation]:
        ops = project.module(OPS_PATH)
        if ops is None:
            return []
        ref = project.module(REF_PATH)
        tests = project.module(KERNEL_TESTS_PATH)
        ref_names = _toplevel_names(ref) if ref else set()
        test_refs = _referenced_attrs(tests) if tests else set()

        def referenced(attr: str) -> bool:
            return any(a == attr for _, a in test_refs)

        out: List[Violation] = []
        for name, line in _public_exports(ops):
            oracle = f"{name}_ref"
            if oracle not in ref_names:
                out.append(Violation(
                    path=OPS_PATH, line=line, rule=self.name,
                    message=(f"public kernel '{name}' has no '{oracle}' "
                             f"oracle in kernels/ref.py"),
                    symbol=name))
                continue
            if not referenced(name):
                out.append(Violation(
                    path=OPS_PATH, line=line, rule=self.name,
                    message=(f"public kernel '{name}' is never exercised in "
                             f"tests/test_kernels.py (no ops.{name} "
                             f"reference)"),
                    symbol=name))
            elif not referenced(oracle):
                out.append(Violation(
                    path=OPS_PATH, line=line, rule=self.name,
                    message=(f"tests/test_kernels.py never compares "
                             f"'{name}' against its oracle '{oracle}' — "
                             f"the op is exercised but unverified"),
                    symbol=name))
        return out


def _const_strs(node: ast.AST) -> Set[str]:
    return {n.value for n in ast.walk(node)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)}


def _axes_used(mod: Module) -> Dict[str, int]:
    """logical axis name -> first use line, from one models/ module."""
    used: Dict[str, int] = {}

    def note(names: Set[str], line: int) -> None:
        for n in names:
            used.setdefault(n, line)

    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        p = dotted_path(node.func)
        if p is None:
            continue
        if p[-1] == "param":
            # builder.param(name, shape, axes, ...): axes is arg 2 or kw
            if len(node.args) >= 3:
                note(_const_strs(node.args[2]), node.lineno)
            for kw in node.keywords:
                if kw.arg == "axes":
                    note(_const_strs(kw.value), node.lineno)
        elif p[-1] in ("wlc", "with_logical_constraint"):
            for a in node.args[1:]:
                note(_const_strs(a), node.lineno)
    # cache_logical_axes tables: every all-string/None tuple inside
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == "cache_logical_axes":
            for t in ast.walk(node):
                if isinstance(t, ast.Tuple) and t.elts and all(
                        isinstance(e, ast.Constant)
                        and (e.value is None or isinstance(e.value, str))
                        for e in t.elts):
                    note(_const_strs(t), t.lineno)
    return used


def _rule_keys(project: Project) -> Set[str]:
    keys: Set[str] = set()
    sharding = project.module(SHARDING_PATH)
    if sharding is not None:
        for node in ast.walk(sharding.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == "make_rules":
                for d in ast.walk(node):
                    if isinstance(d, ast.Dict):
                        for k in d.keys:
                            if isinstance(k, ast.Constant) \
                                    and isinstance(k.value, str):
                                keys.add(k.value)
    # rule-table amendments anywhere: rules.setdefault("seq_data", ...)
    for mod in project.modules.values():
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "setdefault":
                recv = dotted_path(node.func.value)
                if recv and "rule" in recv[-1] and node.args \
                        and isinstance(node.args[0], ast.Constant) \
                        and isinstance(node.args[0].value, str):
                    keys.add(node.args[0].value)
    return keys


@register
class ShardingRuleCoverage(Rule):
    name = "sharding-rule-coverage"
    description = (
        "every logical axis name used in models/ param declarations and "
        "activation constraints must appear in the distributed/sharding.py "
        "rule tables (unlisted axes silently replicate)"
    )

    def run(self, project: Project) -> List[Violation]:
        keys = _rule_keys(project)
        if not keys:
            return []  # synthetic projects without a rule table
        out: List[Violation] = []
        for path, mod in sorted(project.modules.items()):
            if not path.startswith(MODELS_PREFIX):
                continue
            for axis, line in sorted(_axes_used(mod).items()):
                if axis not in keys:
                    out.append(Violation(
                        path=path, line=line, rule=self.name,
                        message=(f"logical axis '{axis}' is used here but "
                                 f"missing from the make_rules table in "
                                 f"distributed/sharding.py — tensors on it "
                                 f"silently replicate"),
                        symbol=axis))
        return out
