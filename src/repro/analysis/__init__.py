"""repro.analysis: AST-based static checks of the repo's invariants.

Five rules turn runtime conventions into a CI gate (see RULES.md):

  custody-taint            private device reads never reach serialization /
                           network / checkpoint sinks; feed crossings need a
                           transfer guard or CustodyEvent audit
  use-after-donate         donate_argnums buffers are dead after the call
  jit-purity               traced functions stay host-effect-free
  kernel-parity-coverage   every public kernel has an oracle + parity test
  sharding-rule-coverage   every logical axis is in the rule tables

Run: ``python -m repro.analysis [--json out.json] [--baseline file.json]``
"""
from repro.analysis.core import (
    AnalysisResult, Baseline, Rule, Suppression, Violation, all_rules,
    register, run_analysis,
)
from repro.analysis.project import Module, Project

__all__ = [
    "AnalysisResult", "Baseline", "Module", "Project", "Rule", "Suppression",
    "Violation", "all_rules", "register", "run_analysis",
]
