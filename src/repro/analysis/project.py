"""Parsed-repo model shared by every analysis rule.

A :class:`Project` holds the AST of every Python file in the scan roots plus
the cheap cross-module indices the rules need for "interprocedural-lite"
resolution: classes by name, per-module import aliases, and per-class
attribute types inferred from constructor assignments.  Nothing here imports
the analyzed code — analysis is purely syntactic (stdlib ``ast``).
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

# Directories (relative to the project root) whose files are *analyzed* —
# i.e. rules report violations in them.  Everything else that is loaded
# (tests/, scripts/) is only *consulted* as evidence (e.g. the kernel
# coverage rule reads tests/test_kernels.py).
DEFAULT_ANALYZED = ("src/repro", "benchmarks", "examples")
# The analyzer itself talks about sinks/sources by name; don't self-flag.
DEFAULT_EXCLUDED = ("src/repro/analysis",)
DEFAULT_LOADED = ("src", "benchmarks", "examples", "tests")


def dotted_path(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """``a.b.c`` -> ("a","b","c"); None for anything not a pure name chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


@dataclasses.dataclass
class Module:
    path: str                       # project-root-relative, posix separators
    tree: ast.Module
    source: str

    def __post_init__(self):
        # import alias map: local name -> absolute dotted prefix
        self.imports: Dict[str, Tuple[str, ...]] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    full = tuple(a.name.split("."))
                    self.imports[a.asname or full[0]] = (
                        full if a.asname else full[:1]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module:
                base = tuple(node.module.split("."))
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.imports[a.asname or a.name] = base + (a.name,)

    def resolve(self, path: Tuple[str, ...]) -> Tuple[str, ...]:
        """Expand the first segment of a dotted path through the imports."""
        if path and path[0] in self.imports:
            return self.imports[path[0]] + path[1:]
        return path

    def resolve_call(self, call: ast.Call) -> Optional[Tuple[str, ...]]:
        p = dotted_path(call.func)
        return self.resolve(p) if p else None


def _toplevel_classes(tree: ast.Module) -> Iterable[ast.ClassDef]:
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            yield node


class Project:
    """All parsed modules plus the cross-module lookup tables."""

    def __init__(self, root: Path, modules: Dict[str, Module],
                 analyzed: Tuple[str, ...] = DEFAULT_ANALYZED,
                 excluded: Tuple[str, ...] = DEFAULT_EXCLUDED):
        self.root = Path(root)
        self.modules = modules
        self._analyzed_prefixes = analyzed
        self._excluded_prefixes = excluded
        # class name -> (module, ClassDef).  Class names are effectively
        # unique in this repo; on a collision the first definition wins and
        # resolution just gets more conservative.
        self.classes: Dict[str, Tuple[Module, ast.ClassDef]] = {}
        for mod in modules.values():
            for cls in _toplevel_classes(mod.tree):
                self.classes.setdefault(cls.name, (mod, cls))

    # -- construction ------------------------------------------------------

    @classmethod
    def load(cls, root, paths: Optional[Iterable[str]] = None,
             analyzed: Tuple[str, ...] = DEFAULT_ANALYZED,
             excluded: Tuple[str, ...] = DEFAULT_EXCLUDED) -> "Project":
        root = Path(root)
        rels: List[str] = []
        if paths is not None:
            rels = [str(p) for p in paths]
        else:
            for prefix in DEFAULT_LOADED:
                base = root / prefix
                if not base.is_dir():
                    continue
                for f in sorted(base.rglob("*.py")):
                    rels.append(f.relative_to(root).as_posix())
        modules: Dict[str, Module] = {}
        for rel in rels:
            f = root / rel
            try:
                src = f.read_text()
                tree = ast.parse(src, filename=rel)
            except (OSError, SyntaxError):
                continue
            modules[rel] = Module(path=rel, tree=tree, source=src)
        return cls(root, modules, analyzed=analyzed, excluded=excluded)

    # -- queries -----------------------------------------------------------

    def is_analyzed(self, path: str) -> bool:
        if any(path.startswith(e) for e in self._excluded_prefixes):
            return False
        return any(path.startswith(a) for a in self._analyzed_prefixes)

    def analyzed_modules(self) -> List[Module]:
        return [m for p, m in sorted(self.modules.items())
                if self.is_analyzed(p)]

    def module(self, path: str) -> Optional[Module]:
        return self.modules.get(path)

    def class_method(self, cls_name: str,
                     meth: str) -> Optional[Tuple[Module, ast.FunctionDef]]:
        got = self.classes.get(cls_name)
        if got is None:
            return None
        mod, cls = got
        for node in cls.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == meth:
                return mod, node
        return None

    def class_bases(self, cls_name: str) -> Tuple[str, ...]:
        """Transitive base-class names resolvable inside the project."""
        out: List[str] = []
        seen = set()
        stack = [cls_name]
        while stack:
            name = stack.pop()
            got = self.classes.get(name)
            if got is None:
                continue
            for b in got[1].bases:
                p = dotted_path(b)
                if not p:
                    continue
                base = p[-1]
                if base not in seen:
                    seen.add(base)
                    out.append(base)
                    stack.append(base)
        return tuple(out)

    def attr_types(self, cls_name: str) -> Dict[str, str]:
        """``self.x = SomeClass(...)`` assignments anywhere in the class."""
        got = self.classes.get(cls_name)
        if got is None:
            return {}
        mod, cls = got
        types: Dict[str, str] = {}
        for node in ast.walk(cls):
            if not isinstance(node, ast.Assign):
                continue
            if not isinstance(node.value, ast.Call):
                continue
            callee = dotted_path(node.value.func)
            if not callee or callee[-1] not in self.classes:
                continue
            for tgt in node.targets:
                p = dotted_path(tgt)
                if p and len(p) == 2 and p[0] == "self":
                    types[p[1]] = callee[-1]
        return types
