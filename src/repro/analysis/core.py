"""Rule engine: violations, registry, baseline suppression, reporting.

The analyzer turns the repo's runtime invariants (custody guards, donation
discipline, trace purity, parity/sharding coverage) into a static CI gate:

    PYTHONPATH=src python -m repro.analysis --baseline analysis-baseline.json

Exit code 0 means every enabled rule is clean (modulo baselined
suppressions, each of which must carry a one-line reason).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Type

from repro.analysis.project import Project


@dataclasses.dataclass(frozen=True, order=True)
class Violation:
    path: str                 # project-root-relative
    line: int
    rule: str
    message: str
    symbol: str = ""          # enclosing qualname (Class.method / function)

    def format(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{sym}"

    def to_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


class Rule:
    """One invariant checker.  Subclasses set ``name``/``description`` and
    implement ``run(project) -> list[Violation]``."""

    name: str = ""
    description: str = ""

    def run(self, project: Project) -> List[Violation]:  # pragma: no cover
        raise NotImplementedError

    def violation(self, module_path: str, node, message: str,
                  symbol: str = "") -> Violation:
        return Violation(path=module_path, line=getattr(node, "lineno", 0),
                         rule=self.name, message=message, symbol=symbol)


_REGISTRY: Dict[str, Type[Rule]] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    assert cls.name, f"{cls} needs a name"
    _REGISTRY[cls.name] = cls
    return cls


def all_rules() -> Dict[str, Type[Rule]]:
    # importing the rules package populates the registry
    import repro.analysis.rules  # noqa: F401
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# Baseline suppression
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Suppression:
    rule: str
    path: str
    reason: str
    symbol: Optional[str] = None

    def matches(self, v: Violation) -> bool:
        if v.rule != self.rule or v.path != self.path:
            return False
        if self.symbol is not None and v.symbol != self.symbol:
            return False
        return True


class Baseline:
    """``analysis-baseline.json``: intentional, justified suppressions.

    Format::

        {"version": 1,
         "suppressions": [
             {"rule": "...", "path": "...", "symbol": "...", "reason": "..."}
         ]}

    ``symbol`` is optional (omit to suppress the rule for the whole file);
    ``reason`` is mandatory — an unexplained suppression is itself an error.
    """

    def __init__(self, suppressions: Sequence[Suppression] = ()):
        self.suppressions = list(suppressions)
        self._hits = [0] * len(self.suppressions)

    @classmethod
    def load(cls, path) -> "Baseline":
        data = json.loads(Path(path).read_text())
        sups = []
        for i, entry in enumerate(data.get("suppressions", [])):
            if not entry.get("reason"):
                raise ValueError(
                    f"baseline entry #{i} ({entry.get('rule')}, "
                    f"{entry.get('path')}) has no reason"
                )
            sups.append(Suppression(
                rule=entry["rule"], path=entry["path"],
                symbol=entry.get("symbol"), reason=entry["reason"],
            ))
        return cls(sups)

    def filter(self, violations: Sequence[Violation]) -> List[Violation]:
        kept = []
        for v in violations:
            for i, s in enumerate(self.suppressions):
                if s.matches(v):
                    self._hits[i] += 1
                    break
            else:
                kept.append(v)
        return kept

    def unused(self) -> List[Suppression]:
        return [s for s, h in zip(self.suppressions, self._hits) if h == 0]


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AnalysisResult:
    violations: List[Violation]           # after baseline filtering
    suppressed: int
    unused_suppressions: List[Suppression]
    rules_run: List[str]

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> Dict[str, object]:
        return {
            "ok": self.ok,
            "rules": self.rules_run,
            "suppressed": self.suppressed,
            "unused_suppressions": [
                dataclasses.asdict(s) for s in self.unused_suppressions
            ],
            "violations": [v.to_json() for v in self.violations],
        }


def run_analysis(
    root,
    *,
    rules: Optional[Sequence[str]] = None,
    baseline: Optional[Baseline] = None,
    project: Optional[Project] = None,
) -> AnalysisResult:
    registry = all_rules()
    names = list(rules) if rules else sorted(registry)
    unknown = [n for n in names if n not in registry]
    if unknown:
        raise KeyError(f"unknown rule(s): {', '.join(unknown)}; "
                       f"available: {', '.join(sorted(registry))}")
    proj = project if project is not None else Project.load(root)
    violations: List[Violation] = []
    for n in names:
        violations.extend(registry[n]().run(proj))
    violations.sort()
    if baseline is None:
        return AnalysisResult(violations, 0, [], names)
    kept = baseline.filter(violations)
    return AnalysisResult(
        kept, len(violations) - len(kept), baseline.unused(), names,
    )
