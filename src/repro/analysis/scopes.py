"""Function-scope and statement-order utilities for the dataflow rules.

The use-after-donate and custody-taint rules both reason about *statement
order inside one function scope*: something happens at statement i (a buffer
is donated, a value becomes tainted) and something later must / must not
happen to the same dotted path.  This module linearizes a function body into
source-ordered :class:`StmtInfo` records carrying, per statement:

  * the dotted paths it loads (``self.adapter.cache`` -> ("self","adapter",
    "cache")),
  * the dotted paths it stores (assignment targets; subscript stores count
    as loads of the base, not stores — writing into an object is a *use*),
  * the enclosing loop and ``with`` statements.

Nested function/class definitions are separate scopes and their bodies are
not traversed.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterator, List, Optional, Tuple

from repro.analysis.project import dotted_path

Path_ = Tuple[str, ...]


def is_prefix(p: Path_, q: Path_) -> bool:
    """True when path ``p`` is a (non-strict) prefix of path ``q``."""
    return len(p) <= len(q) and q[: len(p)] == p


def collect_load_paths(expr: ast.AST) -> List[Path_]:
    """Maximal dotted name chains loaded anywhere inside ``expr``."""
    out: List[Path_] = []

    def visit(n: ast.AST) -> None:
        p = dotted_path(n)
        if p is not None:
            out.append(p)
            return
        for c in ast.iter_child_nodes(n):
            visit(c)

    visit(expr)
    return out


def _target_paths(target: ast.AST) -> Tuple[List[Path_], List[Path_]]:
    """(stored paths, loaded paths) of one assignment target.

    ``x``/``a.b.c`` store that path; tuple/list targets recurse;
    ``x[i] = ...`` *loads* ``x`` (mutation of an existing object) and the
    index expression.
    """
    stores: List[Path_] = []
    loads: List[Path_] = []
    if isinstance(target, (ast.Tuple, ast.List)):
        for el in target.elts:
            s, l = _target_paths(el)
            stores.extend(s)
            loads.extend(l)
    elif isinstance(target, ast.Starred):
        return _target_paths(target.value)
    elif isinstance(target, ast.Subscript):
        loads.extend(collect_load_paths(target.value))
        loads.extend(collect_load_paths(target.slice))
    else:
        p = dotted_path(target)
        if p is not None:
            stores.append(p)
        else:
            loads.extend(collect_load_paths(target))
    return stores, loads


@dataclasses.dataclass
class StmtInfo:
    node: ast.stmt
    index: int
    loops: Tuple[ast.stmt, ...]       # enclosing For/While within the scope
    withs: Tuple[ast.With, ...]       # enclosing with-statements
    loads: List[Path_]
    stores: List[Path_]
    calls: List[ast.Call]             # every Call evaluated by this statement
    value: Optional[ast.AST]          # the "header" expression, if any


def _header(stmt: ast.stmt):
    """(exprs evaluated by the statement itself, store targets)."""
    if isinstance(stmt, ast.Assign):
        return [stmt.value], stmt.targets
    if isinstance(stmt, ast.AugAssign):
        return [stmt.value, stmt.target], [stmt.target]
    if isinstance(stmt, ast.AnnAssign):
        return ([stmt.value] if stmt.value else []), [stmt.target]
    if isinstance(stmt, (ast.Expr, ast.Return)):
        return ([stmt.value] if stmt.value else []), []
    if isinstance(stmt, ast.If):
        return [stmt.test], []
    if isinstance(stmt, ast.While):
        return [stmt.test], []
    if isinstance(stmt, ast.For):
        return [stmt.iter], [stmt.target]
    if isinstance(stmt, ast.With):
        tgts = [i.optional_vars for i in stmt.items if i.optional_vars]
        return [i.context_expr for i in stmt.items], tgts
    if isinstance(stmt, ast.Raise):
        return [e for e in (stmt.exc, stmt.cause) if e], []
    if isinstance(stmt, ast.Assert):
        return [e for e in (stmt.test, stmt.msg) if e], []
    if isinstance(stmt, ast.Delete):
        return [], stmt.targets
    return [], []


def _children(stmt: ast.stmt) -> List[ast.stmt]:
    out: List[ast.stmt] = []
    for field in ("body", "orelse", "finalbody"):
        out.extend(getattr(stmt, field, []) or [])
    for h in getattr(stmt, "handlers", []) or []:
        out.extend(h.body)
    return out


def linearize(body: List[ast.stmt]) -> List[StmtInfo]:
    """Source-ordered StmtInfo records for a function body."""
    infos: List[StmtInfo] = []

    def walk(stmts: List[ast.stmt], loops, withs) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                # separate scope; its *name* is a store in this one
                infos.append(StmtInfo(
                    node=stmt, index=len(infos), loops=loops, withs=withs,
                    loads=[], stores=[(stmt.name,)], calls=[], value=None,
                ))
                continue
            exprs, targets = _header(stmt)
            loads: List[Path_] = []
            stores: List[Path_] = []
            calls: List[ast.Call] = []
            for e in exprs:
                loads.extend(collect_load_paths(e))
                calls.extend(n for n in ast.walk(e)
                             if isinstance(n, ast.Call))
            for t in targets:
                s, l = _target_paths(t)
                stores.extend(s)
                loads.extend(l)
            infos.append(StmtInfo(
                node=stmt, index=len(infos), loops=loops, withs=withs,
                loads=loads, stores=stores, calls=calls,
                value=exprs[0] if exprs else None,
            ))
            inner_loops = loops + ((stmt,) if isinstance(
                stmt, (ast.For, ast.While)) else ())
            inner_withs = withs + ((stmt,) if isinstance(
                stmt, ast.With) else ())
            walk(_children(stmt), inner_loops, inner_withs)

    walk(body, (), ())
    return infos


@dataclasses.dataclass
class Scope:
    qualname: str
    node: ast.AST                     # FunctionDef
    class_name: Optional[str]         # enclosing class, if a method
    stmts: List[StmtInfo]


def function_scopes(tree: ast.Module) -> Iterator[Scope]:
    """Every function/method scope of a module, outermost first."""

    def visit(node: ast.AST, qual: str, cls: Optional[str]) -> Iterator[Scope]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{qual}.{child.name}" if qual else child.name
                yield Scope(qualname=q, node=child, class_name=cls,
                            stmts=linearize(child.body))
                yield from visit(child, q, cls)
            elif isinstance(child, ast.ClassDef):
                q = f"{qual}.{child.name}" if qual else child.name
                yield from visit(child, q, child.name)

    yield from visit(tree, "", None)
