"""CLI: ``python -m repro.analysis`` — the static CI gate.

Exit codes: 0 clean, 1 violations (after baseline filtering), 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.core import Baseline, all_rules, run_analysis


def _find_root(start: Path) -> Path:
    """Walk up from ``start`` to the directory holding pyproject.toml."""
    cur = start.resolve()
    for cand in (cur, *cur.parents):
        if (cand / "pyproject.toml").is_file():
            return cand
    return cur


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis of the repo's custody/jit invariants.",
    )
    ap.add_argument("--root", default=None,
                    help="project root (default: nearest pyproject.toml)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset (default: all)")
    ap.add_argument("--baseline", default=None,
                    help="baseline suppression file (analysis-baseline.json)")
    ap.add_argument("--json", dest="json_out", default=None, metavar="FILE",
                    help="write the full report as JSON ('-' for stdout)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the available rules and exit")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-violation text output")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for name, cls in sorted(all_rules().items()):
            print(f"{name}: {cls.description}")
        return 0

    root = Path(args.root) if args.root else _find_root(Path.cwd())
    rules = [r.strip() for r in args.rules.split(",")] if args.rules else None

    baseline = None
    if args.baseline:
        bl_path = Path(args.baseline)
        if not bl_path.is_absolute():
            bl_path = root / bl_path
        if not bl_path.is_file():
            print(f"error: baseline file not found: {bl_path}",
                  file=sys.stderr)
            return 2
        try:
            baseline = Baseline.load(bl_path)
        except (ValueError, KeyError, json.JSONDecodeError) as e:
            print(f"error: bad baseline file: {e}", file=sys.stderr)
            return 2

    try:
        result = run_analysis(root, rules=rules, baseline=baseline)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2

    if args.json_out:
        payload = json.dumps(result.to_json(), indent=2)
        if args.json_out == "-":
            print(payload)
        else:
            Path(args.json_out).write_text(payload + "\n")

    if not args.quiet:
        for v in result.violations:
            print(v.format())
        for s in result.unused_suppressions:
            print(f"warning: unused baseline suppression: {s.rule} "
                  f"{s.path}" + (f" [{s.symbol}]" if s.symbol else ""),
                  file=sys.stderr)
        n = len(result.violations)
        sup = f" ({result.suppressed} baselined)" if result.suppressed else ""
        status = "clean" if result.ok else f"{n} violation(s)"
        print(f"repro.analysis: {status}{sup} "
              f"[{', '.join(result.rules_run)}]")
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
