"""Parse collective wire-bytes out of post-partitioning HLO text.

``compiled.cost_analysis()`` does not attribute collective traffic, so we
scan the optimized (per-device) HLO for collective ops and convert each to
*bytes on the wire per device* using the ring-schedule accounting:

    all-reduce          2 * result * (n-1)/n     (reduce-scatter + all-gather)
    all-gather          result * (n-1)/n         (result is the gathered size)
    reduce-scatter      result * (n-1)           (operand = result * n)
    all-to-all          result * (n-1)/n
    collective-permute  result                   (pairwise)

``n`` is the collective's group size parsed from ``replica_groups`` — this is
what lets the roofline distinguish a 16-way intra-pod ring from a 2-way
cross-pod hop.  Shapes in the compiled module are already per-device.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")

# %name = f32[128,1024]{1,0} all-reduce(...), ... replica_groups=[4,4]<=[16]
_LINE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"                     # result shape (or tuple)
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)"
    r"\[([0-9,]*)\]"
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        dims = m.group(2)
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))           # [num_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2                             # pairwise / unknown: conservative


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-device wire bytes per collective kind (see module docstring)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        kind, suffix = m.group(3), m.group(4)
        if suffix == "-done":
            continue                     # async pair: count the -start only
        result_bytes = _shape_bytes(m.group(1) or m.group(2))
        n = _group_size(line)
        if kind == "all-reduce":
            wire = 2 * result_bytes * (n - 1) / n
        elif kind == "all-gather":
            wire = result_bytes * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = result_bytes * (n - 1)
        elif kind == "all-to-all":
            wire = result_bytes * (n - 1) / n
        else:                            # collective-permute
            wire = result_bytes
        out[kind] = out.get(kind, 0) + int(wire)
    return out


def collective_ops_from_hlo(hlo_text: str) -> List[Tuple[str, int, int]]:
    """(kind, result_bytes, group_size) per op — for the perf-loop's HLO diffs."""
    ops = []
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m or m.group(4) == "-done":
            continue
        ops.append(
            (m.group(3), _shape_bytes(m.group(1) or m.group(2)), _group_size(line))
        )
    return ops
