"""Three-term roofline from dry-run records.

    compute    = HLO_FLOPs_per_chip / 197e12 FLOP/s
    memory     = HLO_bytes_per_chip / 819e9  B/s
    collective = wire_bytes_per_chip / 50e9  B/s per link

``compiled.cost_analysis()`` analyzes the post-SPMD-partitioning module, so
its FLOPs/bytes are already PER-DEVICE (verified: a (64x1024)@(1024x1024)
matmul on 16 devices reports 8.4e6 = 2*64*1024*1024/16).  Collective wire
bytes are parsed per-device from the same HLO.  MODEL_FLOPS = 6·N·D
(active-N for MoE) gives the useful-compute ratio — remat recompute, padding
waste, and replicated math all show up as HLO/MODEL > 1.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass(frozen=True)
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float       # MODEL_FLOPS / HLO_FLOPs
    dominant: str
    roofline_fraction: float  # dominant-term share of the ideal (compute) time

    def row(self) -> str:
        return (
            f"| {self.arch} | {self.shape} | {self.mesh} | "
            f"{self.compute_s:.4f} | {self.memory_s:.4f} | {self.collective_s:.4f} | "
            f"{self.dominant} | {self.useful_ratio:.2f} | {self.roofline_fraction:.2f} |"
        )


def _tokens_for(rec: Dict[str, Any]) -> float:
    """Tokens processed by one step of this cell (decode: one per row)."""
    from repro.configs.shapes import SHAPES

    s = SHAPES[rec["shape"]]
    if s.kind == "train":
        return s.global_batch * s.seq_len
    if s.kind == "prefill":
        return s.global_batch * s.seq_len
    return s.global_batch  # decode: 1 new token per sequence


def roofline_terms(rec: Dict[str, Any]) -> Optional[RooflineReport]:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    # cost_analysis numbers are per-device (post-partitioning module)
    compute_s = rec["flops"] / PEAK_FLOPS_BF16
    memory_s = rec["hbm_bytes"] / HBM_BW
    coll_bytes = sum(rec.get("collective_bytes", {}).values())
    collective_s = coll_bytes / ICI_BW  # per-device wire bytes

    from repro.configs.shapes import SHAPES

    s = SHAPES[rec["shape"]]
    n = rec["active_params"] if rec["active_params"] else rec["params"]
    tokens = _tokens_for(rec)
    if s.kind == "train":
        model_flops = 6.0 * n * tokens
    else:  # forward only
        model_flops = 2.0 * n * tokens

    hlo = max(rec["flops"], 1.0)          # per-device
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    dominant = max(terms, key=terms.get)
    total = max(terms.values())
    # fraction of roofline: the unavoidable compute time over the actual
    # bottleneck time (1.0 = running at the compute roofline)
    ideal = model_flops / (chips * PEAK_FLOPS_BF16)
    frac = ideal / total if total > 0 else 0.0
    return RooflineReport(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=model_flops, hlo_flops=rec["flops"],
        useful_ratio=model_flops / chips / hlo,
        dominant=dominant, roofline_fraction=min(frac, 1.0),
    )


def report_table(records: List[Dict[str, Any]]) -> str:
    head = (
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "bottleneck | useful | roofline |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    rows = []
    for rec in records:
        r = roofline_terms(rec)
        if r is not None:
            rows.append(r.row())
        elif rec.get("status") == "skipped":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | — | "
                f"skipped: {rec['why'][:40]} | — | — |"
            )
    return "\n".join([head, *rows])


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("results", help="dry-run JSON")
    args = ap.parse_args(argv)
    with open(args.results) as f:
        records = json.load(f)
    print(report_table(records))


if __name__ == "__main__":
    main()
