from repro.roofline.collectives import collective_bytes_from_hlo
from repro.roofline.analysis import roofline_terms, RooflineReport

__all__ = ["collective_bytes_from_hlo", "roofline_terms", "RooflineReport"]
