"""`ServeSession`: the inference-side counterpart of :class:`~repro.api.Session`.

Wraps prefill + KV-cache decode behind one object so serving drivers stop
hand-rolling the per-family control flow (recurrent archs feed the prompt
token-by-token with O(1) state; attention archs run a batched prefill).

    serve = ServeSession(model=model, params=params)
    out = serve.generate(prompt_tokens, max_new_tokens=16)
    print(out.tokens, out.decode_tok_s)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.train.steps import make_serve_step

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GenerateResult:
    tokens: jax.Array            # (B, 1 + max_new_tokens) generated ids
    decode_time: float           # seconds spent in the decode loop
    decode_tok_s: float          # aggregate decode throughput
    ms_per_step: float


class ServeSession:
    """Compiled prefill/decode pair with a family-aware generate loop."""

    def __init__(self, *, model: Model, params: PyTree):
        self.model = model
        self.params = params
        self._serve = jax.jit(make_serve_step(model))
        self._prefill = None     # (cache_len, jitted fn), built lazily

    @property
    def recurrent(self) -> bool:
        return self.model.cfg.family in ("rglru", "rwkv6")

    def _prefill_recurrent(self, prompt: jax.Array, cache_len: int):
        B, P = prompt.shape
        cache = self.model.init_cache(B, cache_len)
        nxt = prompt[:, 0:1]
        for t in range(P):
            pos = jnp.full((B,), t, jnp.int32)
            nxt, _, cache = self._serve(
                self.params, prompt[:, t:t + 1], cache, pos
            )
        return nxt, cache

    def _prefill_attention(self, prompt: jax.Array, cache_len: int):
        if self._prefill is None or self._prefill[0] != cache_len:
            self._prefill = (cache_len, jax.jit(
                lambda p, t: self.model.prefill(p, t, cache_len)
            ))
        logits, cache = self._prefill[1](self.params, prompt)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return tok, cache

    def generate(
        self,
        prompt: jax.Array,                 # (B, P) int32 token ids
        *,
        max_new_tokens: int = 16,
        cache_len: Optional[int] = None,
    ) -> GenerateResult:
        B, P = prompt.shape
        cache_len = cache_len or (P + max_new_tokens + 1)
        if self.recurrent:
            tok, cache = self._prefill_recurrent(prompt, cache_len)
        else:
            tok, cache = self._prefill_attention(prompt, cache_len)

        out = [tok]
        t0 = time.time()
        for t in range(max_new_tokens):
            pos = jnp.full((B,), P + t, jnp.int32)
            tok, _, cache = self._serve(self.params, tok, cache, pos)
            out.append(tok)
        jax.block_until_ready(tok)
        dt = max(time.time() - t0, 1e-9)
        return GenerateResult(
            tokens=jnp.concatenate(out, axis=1),
            decode_time=dt,
            decode_tok_s=max_new_tokens * B / dt,
            ms_per_step=dt / max(1, max_new_tokens) * 1e3,
        )
