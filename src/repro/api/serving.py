"""`ServeSession`: the inference-side counterpart of :class:`~repro.api.Session`.

Wraps prefill + KV-cache decode behind one object so serving drivers stop
hand-rolling the per-family control flow (recurrent archs prefill with one
compiled ``lax.scan`` over the prompt; attention archs run a batched prefill).
The decode step donates its cache argument, so the loop never copies the
KV/state buffers.

    serve = ServeSession(model=model, params=params)
    out = serve.generate(prompt_tokens, max_new_tokens=16)
    print(out.tokens, out.decode_tok_s)

One-shot ``generate`` is deliberately self-contained — it is the independent
oracle the engine-parity tests compare against.  For queued / continuously
batched serving, ``engine()`` and ``generate_many()`` hand off to
:class:`repro.serve.ServeEngine`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.serve.engine import EngineConfig, GenOutput, ServeEngine
from repro.serve.runner import StepRunner
from repro.serve.sampling import (
    GREEDY, SamplingParams, make_sample_fn, request_key,
)
from repro.train.steps import make_serve_step

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GenerateResult:
    tokens: jax.Array            # (B, 1 + max_new_tokens) generated ids
    decode_time: float           # seconds spent in the decode loop
    decode_tok_s: float          # aggregate decode throughput
    ms_per_step: float


class ServeSession:
    """Compiled prefill/decode pair with a family-aware generate loop."""

    def __init__(self, *, model: Model, params: PyTree):
        self.model = model
        self.params = params
        raw = make_serve_step(model)
        self._serve = jax.jit(raw, donate_argnums=(2,))
        self._sample = make_sample_fn()
        self._sample_jit = jax.jit(self._sample)

        def sampled_step(p, tok, cache, pos, roots, temp, topk, tidx):
            _, logits, cache = raw(p, tok, cache, pos)
            keys = jax.vmap(jax.random.fold_in, (0, None))(roots, tidx)
            nxt = self._sample(logits[:, -1], keys, temp, topk)
            return nxt[:, None], cache

        self._serve_sampled = jax.jit(sampled_step, donate_argnums=(2,))
        self._prefill = None     # (cache_len, jitted fn), built lazily
        self._runner: Optional[StepRunner] = None

    @property
    def recurrent(self) -> bool:
        return self.model.cfg.family in ("rglru", "rwkv6")

    def _prefill_recurrent(self, prompt: jax.Array, cache_len: int):
        """One compiled ``lax.scan`` over the prompt (was a per-token Python
        loop with O(prompt_len) host round-trips)."""
        if self._runner is None:
            self._runner = StepRunner(self.model)
        B = prompt.shape[0]
        cache = self.model.init_cache(B, cache_len)
        start = jnp.zeros((B,), jnp.int32)
        logits, cache = self._runner.extend(self.params, prompt, cache, start)
        return logits, cache

    def _prefill_attention(self, prompt: jax.Array, cache_len: int):
        if self._prefill is None or self._prefill[0] != cache_len:
            self._prefill = (cache_len, jax.jit(
                lambda p, t: self.model.prefill(p, t, cache_len)
            ))
        logits, cache = self._prefill[1](self.params, prompt)
        return logits[:, -1], cache

    def generate(
        self,
        prompt: jax.Array,                 # (B, P) int32 token ids
        *,
        max_new_tokens: int = 16,
        cache_len: Optional[int] = None,
        sampling: SamplingParams = GREEDY,
    ) -> GenerateResult:
        B, P = prompt.shape
        cache_len = cache_len or (P + max_new_tokens + 1)
        if self.recurrent:
            logits, cache = self._prefill_recurrent(prompt, cache_len)
        else:
            logits, cache = self._prefill_attention(prompt, cache_len)

        greedy = sampling.temperature <= 0.0
        if greedy:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            roots = temp = topk = None
        else:
            # row i samples from request stream i: batched generate draws the
            # same chains as submitting the rows to the engine one by one
            roots = jnp.stack([request_key(sampling, i) for i in range(B)])
            temp = jnp.full((B,), sampling.temperature, jnp.float32)
            topk = jnp.full((B,), sampling.top_k, jnp.int32)
            keys0 = jax.vmap(jax.random.fold_in, (0, None))(roots, 0)
            tok = self._sample_jit(logits, keys0, temp, topk)[:, None]

        out = [tok]
        t0 = time.time()
        for t in range(max_new_tokens):
            pos = jnp.full((B,), P + t, jnp.int32)
            if greedy:
                tok, _, cache = self._serve(self.params, tok, cache, pos)
            else:
                tok, cache = self._serve_sampled(
                    self.params, tok, cache, pos, roots, temp, topk, t + 1
                )
            out.append(tok)
        jax.block_until_ready(tok)
        dt = max(time.time() - t0, 1e-9)
        return GenerateResult(
            tokens=jnp.concatenate(out, axis=1),
            decode_time=dt,
            decode_tok_s=max_new_tokens * B / dt,
            ms_per_step=dt / max(1, max_new_tokens) * 1e3,
        )

    # -- continuous batching (delegates to repro.serve) -----------------------

    def engine(self, config: Optional[EngineConfig] = None) -> ServeEngine:
        """A :class:`ServeEngine` over this session's model + params."""
        return ServeEngine(model=self.model, params=self.params,
                           config=config or EngineConfig())

    def generate_many(
        self,
        prompts: Sequence[Sequence[int]],
        *,
        max_new_tokens: int = 16,
        sampling: SamplingParams = GREEDY,
        config: Optional[EngineConfig] = None,
    ) -> List[GenOutput]:
        """Queue many variable-length prompts through the engine."""
        if config is None:
            need = max(len(p) for p in prompts) + max_new_tokens
            base = EngineConfig()
            config = dataclasses.replace(
                base, max_len=max(base.max_len, need),
                max_slots=min(base.max_slots, len(prompts)),
            )
        eng = self.engine(config)
        return eng.generate_batch(
            prompts, max_new_tokens=max_new_tokens, sampling=sampling
        )
