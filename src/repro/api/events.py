"""Elastic fleet events — the ONE way session state changes mid-run.

The seed trainer had three divergent mutation paths (``retune``,
``drop_workers``, and nothing at all for growth).  Here every elastic change
is an event applied through :meth:`repro.api.Session.apply`, which funnels
all three into a single replanning code path:

  * :class:`WorkerLost`    — node failure: dp-groups removed, the dead
    workers' private shards are gone (privacy constraint: nobody else may
    read them), survivors re-plan with the paper's backfill remedy.
  * :class:`WorkerJoined`  — elastic growth: a class gains workers and the
    whole pipeline re-tunes around the new counts.
  * :class:`DriftDetected` — step-time spread breached the tuner's 1/E
    margin: re-tune batch shares in place.  Shapes are pinned to the current
    row capacity, so this never recompiles.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class FleetEvent:
    """Base class for all elastic events (see subclasses)."""


@dataclasses.dataclass(frozen=True)
class WorkerLost(FleetEvent):
    """One or more physical workers (e.g. ``"csd/1"``) died."""

    workers: Tuple[str, ...]

    def __init__(self, workers: Sequence[str]):
        if isinstance(workers, str):
            workers = (workers,)
        object.__setattr__(self, "workers", tuple(workers))


@dataclasses.dataclass(frozen=True)
class WorkerJoined(FleetEvent):
    """``count`` new workers of an existing class came online."""

    class_name: str
    count: int = 1

    def __post_init__(self):
        if self.count <= 0:
            raise ValueError(f"WorkerJoined.count must be positive, "
                             f"got {self.count}")


@dataclasses.dataclass(frozen=True)
class DriftDetected(FleetEvent):
    """Per-class step times drifted past the tune margin; re-tune shares."""

    source: str = "manual"        # "monitor" when raised by the DriftMonitor
