"""`repro.api` — the public, staged entry point for the Stannis pipeline.

    from repro.api import Session, SessionConfig, FleetSpec

    spec = FleetSpec.demo(n_csds=2)
    session = Session(
        model=model, optimizer=adamw(), fleet=spec,
        data=DataConfig(vocab=cfg.vocab, seq_len=32),
        shards=spec.shards(private_per_worker={"csd": 64}, public=4096),
        config=SessionConfig(total_steps=20),
    )
    report = session.run()          # tune -> plan -> place -> shard -> compile -> train

See :mod:`repro.api.session` for the stage-by-stage contract and
:mod:`repro.api.events` for the elastic-event model.
"""
from repro.api.artifacts import (
    CompiledStep, ReplanResult, ShardingPlan, TrainReport, TunePlan,
)
from repro.api.callbacks import CallbackRegistry
from repro.api.events import (
    DriftDetected, FleetEvent, WorkerJoined, WorkerLost,
)
from repro.api.fleet import FleetSpec
from repro.api.membership import (
    DirMembershipSource, ElasticController, HeartbeatWriter, MemberInfo,
    MembershipWatcher,
)
from repro.api.serving import GenerateResult, ServeSession
from repro.api.session import Session, SessionConfig
from repro.core.topology import ClusterSpec, ProcessMap
from repro.serve import EngineConfig, SamplingParams, ServeEngine
from repro.storage import DeviceFleet, FleetManifest, StorageSpec

__all__ = [
    "CallbackRegistry",
    "ClusterSpec",
    "CompiledStep",
    "DeviceFleet",
    "DirMembershipSource",
    "DriftDetected",
    "ElasticController",
    "EngineConfig",
    "FleetEvent",
    "FleetManifest",
    "FleetSpec",
    "GenerateResult",
    "HeartbeatWriter",
    "MemberInfo",
    "MembershipWatcher",
    "ProcessMap",
    "ReplanResult",
    "SamplingParams",
    "ServeEngine",
    "ServeSession",
    "Session",
    "SessionConfig",
    "ShardingPlan",
    "StorageSpec",
    "TrainReport",
    "TunePlan",
    "WorkerJoined",
    "WorkerLost",
]
