"""Frozen stage artifacts produced by the Session pipeline.

Each pipeline stage returns one immutable artifact:

    Session.tune()    -> TunePlan            (Algorithm 1 + group schedule)
    Session.plan()    -> core EpochPlan      (Eq. 1 dataset shares)
    Session.place()   -> core PlacementManifest  (privacy placement)
    Session.shard()   -> ShardingPlan        (rule table resolved on the mesh)
    Session.compile() -> CompiledStep        (the jitted SPMD step)
    Session.run()     -> TrainReport

``EpochPlan`` and ``PlacementManifest`` already live in :mod:`repro.core`
(they are the paper's own objects); this module adds the session-level ones.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

from repro.core.hetero import BatchSchedule
from repro.core.tuner import TuneResult

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TunePlan:
    """Algorithm-1 output expanded to physical dp-groups.

    ``schedule.capacity`` pins the row capacity: re-tunes that fit under it
    keep the compiled step's shapes (and therefore never recompile).
    """

    result: TuneResult
    schedule: BatchSchedule
    group_workers: Tuple[str, ...]

    @property
    def batches(self) -> Dict[str, int]:
        return self.result.batches


# The ShardingPlan artifact class lives in :mod:`repro.distributed.sharding`
# (beside the rule engine that resolves it) so layers below the api package
# — train/steps, storage/meshfeed, checkpoint — can type against it without
# importing the whole Session surface; it is re-exported here because it IS
# a Session stage artifact (``Session.shard()``'s return value).
from repro.distributed.sharding import ShardingPlan  # noqa: E402,F401


@dataclasses.dataclass(frozen=True)
class CompiledStep:
    """The jitted train step plus the shape signature it was built for.

    ``build_id`` is the session-wide compile counter — the probe tests use
    to assert that a drift re-tune did NOT trigger a rebuild.
    ``in_shardings``/``out_shardings`` record the explicit ShardingPlan trees
    the step was jitted with (``None`` only for externally built steps).
    """

    step_fn: Callable
    global_rows: int
    seq_len: int
    valid_rows: int           # lr-schedule anchor at build time
    build_id: int
    config_key: Tuple = ()    # the SessionConfig values baked into the step
    in_shardings: Any = None  # (params, opt, batch) NamedSharding trees
    out_shardings: Any = None

    def signature(self) -> Tuple[int, int]:
        return (self.global_rows, self.seq_len)


@dataclasses.dataclass(frozen=True)
class TrainReport:
    """What a training run produced (``Session.run``'s return value).

    ``opt_state`` lets a caller continue training seamlessly after an
    elastic event: ``session.run(report.params, opt_state=report.opt_state)``
    keeps optimizer moments and the lr-schedule step counter.
    """

    params: PyTree
    opt_state: Any
    history: Tuple[Dict[str, float], ...]
    steps_run: int
    start_step: int
    compile_count: int
    wall_time: float

    @property
    def final_loss(self) -> float:
        return self.history[-1]["loss"] if self.history else float("nan")


@dataclasses.dataclass(frozen=True)
class ReplanResult:
    """Outcome of ``Session.apply(event)`` — one per elastic event."""

    event: Any
    tune_plan: TunePlan
    recompiled: bool          # False => shapes survived, no XLA rebuild
    dropped_shards: Tuple[str, ...] = ()
