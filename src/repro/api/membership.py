"""Membership watching: observed process join/death -> elastic fleet events.

HyperTune-style elasticity needs a *membership source* — some ground truth
about which worker processes are alive — and a converter from membership
deltas to the session's :mod:`~repro.api.events` vocabulary.  This module
is that converter, deliberately split in three pluggable pieces:

  * a **source** (:class:`DirMembershipSource`, or anything matching
    :class:`MembershipSource`) answers "who is alive right now".  The
    directory source reads heartbeat files worker processes refresh every
    few hundred ms; a process that dies (including SIGKILL — nothing to
    trap) simply stops refreshing and goes stale.  Swap in an etcd/k8s
    watcher by implementing ``poll()``.
  * a **watcher** (:class:`MembershipWatcher`) diffs successive polls into
    ``WorkerLost`` / ``WorkerJoined`` events — the SAME events every other
    elastic path uses, so membership-driven replanning exercises zero new
    session code.
  * a **controller** (:class:`ElasticController`) routes those events
    through ``session.apply()`` and, when a checkpoint directory is
    configured, restores the newest checkpoint straight onto the re-derived
    (resized) ShardingPlan — the checkpoint-coordinated half of a
    process-count change.

The worker side is :class:`HeartbeatWriter` — a daemon thread
:class:`~repro.launch.cluster.WorkerRuntime` runs for the whole life of the
process.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Dict, List, Optional, Protocol, Tuple

from repro.api.events import FleetEvent, WorkerJoined, WorkerLost

_SUFFIX = ".member.json"


@dataclasses.dataclass(frozen=True)
class MemberInfo:
    """One live member as reported by a membership source."""

    member: str                        # membership id (e.g. "proc-1")
    workers: Tuple[str, ...]           # dp-group workers it hosts
    pid: int = 0
    heartbeat: float = 0.0             # source timestamp of the last beat

    @property
    def class_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for w in self.workers:
            cls = w.rsplit("/", 1)[0]
            out[cls] = out.get(cls, 0) + 1
        return out


class MembershipSource(Protocol):
    """Anything that can answer "who is alive right now"."""

    def poll(self) -> Dict[str, MemberInfo]:
        """Current live members, keyed by member id."""
        ...


def write_heartbeat(
    directory: str, member: str, workers: Tuple[str, ...], pid: int
) -> str:
    """Refresh ``member``'s heartbeat file (atomic rename; mtime is the
    liveness signal, the JSON body is the custody claim)."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, member + _SUFFIX)
    tmp = path + f".tmp{pid}"
    with open(tmp, "w") as f:
        json.dump({
            "member": member,
            "workers": list(workers),
            "pid": pid,
            "time": time.time(),
        }, f)
    os.replace(tmp, path)
    return path


class DirMembershipSource:
    """File/dir membership: one heartbeat file per member, freshness by
    mtime.  A member is alive iff its file's mtime is within
    ``stale_after`` seconds — a killed process stops beating and ages out;
    a cleanly leaving process may also just delete its file.
    """

    def __init__(self, directory: str, *, stale_after: float = 2.0):
        self.directory = directory
        self.stale_after = float(stale_after)

    def poll(self) -> Dict[str, MemberInfo]:
        out: Dict[str, MemberInfo] = {}
        if not os.path.isdir(self.directory):
            return out
        now = time.time()
        for name in sorted(os.listdir(self.directory)):
            if not name.endswith(_SUFFIX):
                continue
            path = os.path.join(self.directory, name)
            try:
                mtime = os.path.getmtime(path)
                if now - mtime > self.stale_after:
                    continue
                with open(path) as f:
                    body = json.load(f)
                info = MemberInfo(
                    member=body["member"],
                    workers=tuple(body.get("workers", ())),
                    pid=int(body.get("pid", 0)),
                    heartbeat=mtime,
                )
                out[info.member] = info
            except (OSError, ValueError, KeyError):
                continue           # torn write / vanished mid-poll: not alive
        return out


class HeartbeatWriter:
    """Worker-side daemon thread refreshing this process's heartbeat."""

    def __init__(
        self,
        directory: str,
        member: str,
        workers: Tuple[str, ...],
        *,
        interval: float = 0.25,
    ):
        self.directory = directory
        self.member = member
        self.workers = tuple(workers)
        self.interval = float(interval)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "HeartbeatWriter":
        write_heartbeat(self.directory, self.member, self.workers, os.getpid())

        def beat():
            while not self._stop.wait(self.interval):
                try:
                    write_heartbeat(
                        self.directory, self.member, self.workers, os.getpid()
                    )
                except OSError:
                    return
        self._thread = threading.Thread(target=beat, daemon=True)
        self._thread.start()
        return self

    def stop(self, *, deregister: bool = False) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=self.interval * 4)
        if deregister:
            try:
                os.remove(
                    os.path.join(self.directory, self.member + _SUFFIX)
                )
            except OSError:
                pass


class MembershipWatcher:
    """Diff successive membership polls into elastic fleet events.

    The FIRST poll establishes the baseline (starting a watcher next to a
    running cluster must not replay the whole fleet as joins) unless the
    expected membership is given up front via ``baseline``.  After that,
    every vanished member yields one ``WorkerLost`` with its workers, and
    every new member yields ``WorkerJoined`` per worker class it hosts.
    """

    def __init__(
        self,
        source: MembershipSource,
        *,
        baseline: Optional[Dict[str, MemberInfo]] = None,
    ):
        self.source = source
        self._known: Optional[Dict[str, MemberInfo]] = (
            dict(baseline) if baseline is not None else None
        )

    @property
    def known(self) -> Dict[str, MemberInfo]:
        return dict(self._known or {})

    def events(self) -> List[FleetEvent]:
        """Poll once; return the fleet events since the previous poll."""
        live = self.source.poll()
        if self._known is None:
            self._known = live
            return []
        out: List[FleetEvent] = []
        for member in sorted(set(self._known) - set(live)):
            workers = self._known[member].workers
            if workers:
                out.append(WorkerLost(workers))
        for member in sorted(set(live) - set(self._known)):
            for cls, count in sorted(live[member].class_counts.items()):
                out.append(WorkerJoined(cls, count))
        self._known = live
        return out

    def wait_for(
        self, n_members: int, *, timeout: float = 30.0, interval: float = 0.1
    ) -> Dict[str, MemberInfo]:
        """Block until ``n_members`` are alive (cluster start barrier)."""
        deadline = time.time() + timeout
        while True:
            live = self.source.poll()
            if len(live) >= n_members:
                if self._known is None:
                    self._known = live
                return live
            if time.time() > deadline:
                raise TimeoutError(
                    f"{len(live)}/{n_members} members after {timeout}s"
                )
            time.sleep(interval)


class ElasticController:
    """Membership events -> ``session.apply()`` -> checkpoint-coordinated
    restore, in one ``step()`` the control loop calls on a timer.

    The controller holds the FULL fleet view (it is the coordinator's
    session, not a worker's): applying ``WorkerLost`` shrinks the plan and
    re-derives the mesh; the newest checkpoint then restores straight onto
    the resized plan via ``session.run()``'s standard resume path — no
    bespoke elastic restore code.
    """

    def __init__(self, session, watcher: MembershipWatcher):
        self.session = session
        self.watcher = watcher
        self.applied: List[FleetEvent] = []

    def step(self) -> List:
        """Poll membership once and replan for every event observed."""
        results = []
        for event in self.watcher.events():
            try:
                results.append(self.session.apply(event))
                self.applied.append(event)
            except (KeyError, ValueError):
                # a member the session never planned for (e.g. lost before
                # its join was applied) — membership and plan re-converge
                # on the next poll
                continue
        return results
