"""`FleetSpec`: declarative fleet construction with named presets.

Every driver used to hand-roll its own ``WorkerClass(...)`` tuple (five
slightly-divergent copies across launch/, examples/, and tests/).  A
``FleetSpec`` is the one place fleet shapes are described: presets reproduce
the paper's AIC server (``FleetSpec.paper``) and the laptop-scaled demo rig
(``FleetSpec.demo``); ``FleetSpec.custom().add(...)`` covers everything else.

A spec is immutable; ``add`` returns a new spec, so specs chain:

    spec = FleetSpec.custom("bench").add("fast", 1, 100.0, 8, 64,
                                         active_power=100.0)
    fleet = spec.build()
    shards = spec.shards(private_per_worker={"csd": 256}, public=65536)
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Mapping, Optional, Tuple

from repro.core.privacy import Shard
from repro.core.topology import (
    ClusterSpec, Fleet, WorkerClass, paper_fleet, tpu_fleet,
)
from repro.storage import StorageSpec


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Immutable description of a heterogeneous fleet.

    ``storage`` selects the data plane: which
    :class:`~repro.storage.StorageDevice` backend every worker's device uses
    (``synthetic`` | ``flash`` | ``meshfeed``); see
    :meth:`with_storage`.

    ``sharding`` carries fleet-wide logical-axis rule OVERRIDES (see
    :meth:`with_sharding`): ``Session.shard()`` merges them into the rule
    table before resolving the :class:`~repro.api.artifacts.ShardingPlan`,
    so placement policy travels with the fleet description.
    """

    classes: Tuple[WorkerClass, ...] = ()
    name: str = "custom"
    storage: StorageSpec = dataclasses.field(default_factory=StorageSpec)
    sharding: Tuple[Tuple[str, Any], ...] = ()
    cluster: Optional[ClusterSpec] = None

    # -- presets -----------------------------------------------------------

    @classmethod
    def paper(cls, n_csds: int = 24, network: str = "mobilenetv2") -> "FleetSpec":
        """The paper's AIC server: 1 Xeon host + N Newport CSDs (Table I/II)."""
        return cls(classes=paper_fleet(n_csds, network).classes, name="paper")

    @classmethod
    def tpu(cls, n_fast_pods: int = 1, n_slow_pods: int = 1, **kw) -> "FleetSpec":
        """Mixed-generation TPU fleet (fast + slow pod classes)."""
        return cls(classes=tpu_fleet(n_fast_pods, n_slow_pods, **kw).classes,
                   name="tpu")

    @classmethod
    def demo(
        cls,
        n_csds: int = 2,
        *,
        host_tput: float = 100.0,
        csd_tput: float = 25.0,
        host_saturation: int = 8,
        csd_saturation: int = 2,
        host_max_batch: int = 16,
        csd_max_batch: int = 4,
        host_power: float = 400.0,
        csd_power: float = 7.0,
        host_idle: float = 0.0,
        csd_idle: float = 0.0,
        host_link: float = 8.0,
        csd_link: float = 2.0,
    ) -> "FleetSpec":
        """Paper-shaped fleet (1 host + N CSD-class workers), laptop-scaled."""
        host = WorkerClass(
            name="host", count=1, peak_throughput=host_tput,
            saturation_batch=host_saturation, max_batch=host_max_batch,
            active_power=host_power, idle_power=host_idle,
            link_bandwidth=host_link,
        )
        csd = WorkerClass(
            name="csd", count=n_csds, peak_throughput=csd_tput,
            saturation_batch=csd_saturation, max_batch=csd_max_batch,
            active_power=csd_power, idle_power=csd_idle,
            link_bandwidth=csd_link,
        )
        return cls(classes=(host, csd), name="demo")

    @classmethod
    def custom(cls, name: str = "custom") -> "FleetSpec":
        return cls(classes=(), name=name)

    # -- builder -----------------------------------------------------------

    def add(
        self,
        name: str,
        count: int,
        peak_throughput: float,
        saturation_batch: int,
        max_batch: int,
        *,
        active_power: float,
        idle_power: float = 0.0,
        link_bandwidth: float = 1.0,
    ) -> "FleetSpec":
        """Append a worker class; returns a NEW spec (specs are immutable)."""
        wc = WorkerClass(
            name=name, count=count, peak_throughput=peak_throughput,
            saturation_batch=saturation_batch, max_batch=max_batch,
            active_power=active_power, idle_power=idle_power,
            link_bandwidth=link_bandwidth,
        )
        return dataclasses.replace(self, classes=self.classes + (wc,))

    def with_storage(self, backend: str, **kw) -> "FleetSpec":
        """Select the storage backend for every device in the fleet:

            FleetSpec.demo(3).with_storage("flash", root="/data/spool")
            FleetSpec.demo(3).with_storage("meshfeed")
        """
        return dataclasses.replace(
            self, storage=StorageSpec(backend=backend, **kw)
        )

    def with_cluster(self, processes: int, **kw) -> "FleetSpec":
        """Run the fleet across ``processes`` worker PROCESSES, one global
        mesh (see :mod:`repro.launch.cluster`):

            FleetSpec.demo(3).with_cluster(processes=2, local_devices=4)

        Each process provisions only its own dp-groups' storage devices and
        ``device_put``s only its addressable slice of the plan's
        ``NamedSharding``s.  The data plane needs mesh delivery, so a spec
        still on the default ``synthetic`` backend is upgraded to
        ``meshfeed``; an explicit host-delivery choice is left for
        ``Session`` to reject with a clear error.

        The gradient-reduction wire is configured by ``transport=`` — a
        :class:`~repro.core.topology.TransportSpec` (or kwargs dict):
        compression (``"int8"``/``"topk"`` with error feedback), bucket
        overlap, and star vs peer-to-peer ring topology.
        ``TransportSpec.production()`` is the tuned preset:

            FleetSpec.demo(3).with_cluster(
                processes=2, transport=TransportSpec.production())
        """
        storage = self.storage
        if storage.backend == "synthetic":
            storage = dataclasses.replace(storage, backend="meshfeed")
        return dataclasses.replace(
            self,
            cluster=ClusterSpec(processes=processes, **kw),
            storage=storage,
        )

    def with_sharding(self, **rules: Any) -> "FleetSpec":
        """Override logical-axis -> mesh-axis rules fleet-wide:

            FleetSpec.demo(3).with_sharding(embed="data")      # FSDP weights
            FleetSpec.demo(3).with_sharding(experts=("data",)) # EP over data
            FleetSpec.demo(3).with_sharding(heads=None)        # replicate

        Values are a mesh-axis name, a tuple of axis names, or ``None``
        (replicate).  ``Session.shard()`` merges these over the per-arch
        defaults when it resolves the :class:`ShardingPlan`.
        """
        merged = dict(self.sharding)
        merged.update(rules)
        return dataclasses.replace(
            self, sharding=tuple(sorted(merged.items()))
        )

    def build(self) -> Fleet:
        if not self.classes:
            raise ValueError(f"FleetSpec {self.name!r} has no worker classes")
        return Fleet(classes=self.classes)

    # -- shard layout helper ----------------------------------------------

    def shards(
        self,
        *,
        private_per_worker: Optional[Mapping[str, int]] = None,
        public: int = 0,
        public_id: str = "public",
        prefix: str = "private",
    ) -> List[Shard]:
        """Standard shard layout: per-worker private shards + one public pool.

        ``private_per_worker`` maps a class name to the samples each of its
        workers owns privately (the paper's on-flash TinyImageNet slices);
        ``public`` is the shared pool size.
        """
        out: List[Shard] = []
        for cls in self.classes:
            n = (private_per_worker or {}).get(cls.name, 0)
            if n <= 0:
                continue
            for i in range(cls.count):
                worker = f"{cls.name}/{i}"
                out.append(Shard(f"{prefix}-{worker}", n, True, worker))
        if public > 0:
            out.append(Shard(public_id, public, False))
        return out
