"""Typed callback registry for the Session lifecycle.

Replaces the seed trainer's single ``on_metrics`` lambda with named hooks.
Registration methods double as decorators:

    cb = CallbackRegistry()

    @cb.on_step
    def log(step, metrics):
        print(step, metrics["loss"])

    cb.on_fleet_change(lambda event, result: alerting.page(event))
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List

OnStep = Callable[[int, Dict[str, float]], None]          # (step, metrics)
OnRetune = Callable[[Any, Any], None]                     # (event, tune_plan)
OnCheckpoint = Callable[[int, str], None]                 # (step, directory)
OnFleetChange = Callable[[Any, Any], None]                # (event, replan_result)


@dataclasses.dataclass
class CallbackRegistry:
    _step: List[OnStep] = dataclasses.field(default_factory=list)
    _retune: List[OnRetune] = dataclasses.field(default_factory=list)
    _checkpoint: List[OnCheckpoint] = dataclasses.field(default_factory=list)
    _fleet_change: List[OnFleetChange] = dataclasses.field(default_factory=list)

    # -- registration (usable as decorators) -------------------------------

    def on_step(self, fn: OnStep) -> OnStep:
        self._step.append(fn)
        return fn

    def on_retune(self, fn: OnRetune) -> OnRetune:
        self._retune.append(fn)
        return fn

    def on_checkpoint(self, fn: OnCheckpoint) -> OnCheckpoint:
        self._checkpoint.append(fn)
        return fn

    def on_fleet_change(self, fn: OnFleetChange) -> OnFleetChange:
        self._fleet_change.append(fn)
        return fn

    # -- unsubscription -----------------------------------------------------

    def remove_on_step(self, fn: OnStep) -> None:
        self._step.remove(fn)

    # -- emission (called by the Session) ----------------------------------

    def emit_step(self, step: int, metrics: Dict[str, float]) -> None:
        for fn in self._step:
            fn(step, metrics)

    def emit_retune(self, event: Any, tune_plan: Any) -> None:
        for fn in self._retune:
            fn(event, tune_plan)

    def emit_checkpoint(self, step: int, directory: str) -> None:
        for fn in self._checkpoint:
            fn(step, directory)

    def emit_fleet_change(self, event: Any, result: Any) -> None:
        for fn in self._fleet_change:
            fn(event, result)
