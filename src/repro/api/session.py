"""`Session`: the staged public entry point for the whole Stannis pipeline.

The paper's pipeline is tune -> balance -> place -> train (Algorithm 1,
Eq. 1, privacy placement).  The seed ``Trainer`` fused all four into one
opaque ``setup()``; a ``Session`` decomposes them into explicit, frozen,
cached, individually overridable stage artifacts:

    session = Session(model=model, optimizer=adamw(),
                      fleet=FleetSpec.demo(2), data=DataConfig(...),
                      shards=spec.shards(...), config=SessionConfig(...))
    tune_plan = session.tune()      # Algorithm 1 -> TunePlan
    epoch     = session.plan()      # Eq. 1       -> EpochPlan
    manifest  = session.place()     # privacy     -> FleetManifest (device-aware)
    shard     = session.shard()     # rule table x mesh -> ShardingPlan
    step      = session.compile()   # jitted SPMD -> CompiledStep
    report    = session.run()       # training    -> TrainReport

Execution is *sharding-explicit*: ``shard()`` resolves the logical-axis rule
table (:mod:`repro.distributed.sharding`) against the live mesh once into a
:class:`~repro.api.artifacts.ShardingPlan`; ``compile()`` jits the step with
the plan as explicit ``in_shardings``/``out_shardings``; model init is
jitted with ``out_shardings`` so parameters are BORN as mesh shards (a full
replicated param tree never exists on host); the meshfeed backend lands
batches with the plan's layout; and checkpoint restore places leaves
straight onto the plan's shardings for whatever mesh shape the restart has.
The plan is keyed by the pinned row capacity, so drift re-tunes keep both
the plan and the compiled step (the ``compile_count`` probe still holds),
while a node loss/join resizes the mesh and re-derives both.

The data plane is the :mod:`repro.storage` device fleet: ``session.devices``
is a :class:`~repro.storage.DeviceFleet` (one StorageDevice per dp-group
worker, backend chosen by ``StorageSpec`` / ``FleetSpec.with_storage``), and
``run()`` pulls every batch through it — each group's rows are assembled in
its own device, and elastic events re-home custody through the fleet API
(WorkerLost quarantines the dead device's private shards and re-homes its
public custody; WorkerJoined provisions a fresh device).

Stages are lazy and memoized: calling ``run()`` directly executes the whole
chain; calling a stage twice returns the SAME artifact object.  A stage can
be overridden (``session.override("tune", my_plan)``), which invalidates
everything downstream of it — that is the hook online re-tuners and elastic
schedulers build on.

All mid-run fleet changes go through ONE replanning path,
:meth:`Session.apply`:

    session.apply(WorkerLost(["csd/1"]))   # paper's backfill remedy
    session.apply(WorkerJoined("csd", 2))  # elastic growth
    session.apply(DriftDetected())         # online re-tune, zero recompile

``apply`` preserves the pinned row capacity across events, so a drift
re-tune keeps tensor shapes bit-identical (the compiled step is reused; the
``compile_count`` probe proves it), and a node loss keeps ``max_local``
stable so only the group dimension changes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.artifacts import (
    CompiledStep, ReplanResult, ShardingPlan, TrainReport, TunePlan,
)
from repro.api.callbacks import CallbackRegistry
from repro.api.events import DriftDetected, FleetEvent, WorkerJoined, WorkerLost
from repro.api.fleet import FleetSpec
from repro.checkpoint.manager import CheckpointManager, ClusterCheckpointManager
from repro.compat import set_mesh as compat_set_mesh
from repro.core.hetero import BatchSchedule, schedule_from_tune
from repro.core.load_balance import EpochPlan, plan_epoch
from repro.core.privacy import PlacementManifest, Shard, place
from repro.core.topology import ClusterSpec, Fleet, ProcessMap
from repro.core.tuner import BenchmarkFn, DriftMonitor, tune
from repro.models.api import Model
from repro.storage import (
    DataConfig, DeviceFleet, FleetBatcher, FleetManifest, StorageSpec,
    make_fleet_batcher, manifest_sources,
)
from repro.distributed.sharding import use_rules
from repro.launch.mesh import ClusterContext, make_single_mesh
from repro.optim.optimizers import Optimizer
from repro.optim.schedules import goyal_schedule
from repro.train.steps import (
    abstract_train_state, build_sharding_plan, make_bucketed_apply_step,
    make_bucketed_grad_step, make_train_step, plan_buckets,
)

PyTree = Any

# stage dependency graph: invalidating a stage clears it plus everything
# that derives from it.  Note "shard"/"compile" depend only on the tune
# schedule (shapes + mesh + lr anchor) — a plan/place override must not
# throw away the sharding plan or the jitted step.
_STAGES = ("tune", "plan", "place", "dataset", "shard", "compile")
_DOWNSTREAM = {
    "tune": ("plan", "place", "dataset", "shard", "compile"),
    "plan": ("place", "dataset"),
    "place": ("dataset",),
    "dataset": (),
    "shard": ("compile",),
    "compile": (),
}


@dataclasses.dataclass
class SessionConfig:
    """Run-level knobs (training length, LR rule, checkpointing, drift).

    Mutable by design (unlike the stage artifacts): callers tweak e.g.
    ``total_steps`` or ``retune_margin`` between runs of the same session.
    """

    total_steps: int = 100
    base_lr: float = 1e-3
    base_batch: int = 256
    warmup_steps: int = 20
    aux_weight: float = 0.01
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    retune_margin: float = 0.2       # DriftMonitor threshold = tuner 1/E
    retune_patience: int = 10
    log_every: int = 10
    seed: int = 0


class Session:
    """Staged pipeline: tune -> plan -> place -> shard -> compile -> run."""

    def __init__(
        self,
        *,
        model: Model,
        optimizer: Optimizer,
        fleet: Union[Fleet, FleetSpec],
        data: DataConfig,
        shards: Sequence[Shard],
        config: Optional[SessionConfig] = None,
        benchmark: Optional[BenchmarkFn] = None,
        callbacks: Optional[CallbackRegistry] = None,
        storage: Optional[StorageSpec] = None,
    ):
        self.model = model
        self.optimizer = optimizer
        spec_storage = fleet.storage if isinstance(fleet, FleetSpec) else None
        # fleet-wide logical-axis rule overrides (FleetSpec.with_sharding)
        self.sharding_overrides: Dict[str, Any] = (
            dict(fleet.sharding) if isinstance(fleet, FleetSpec) else {}
        )
        self.fleet: Fleet = fleet.build() if isinstance(fleet, FleetSpec) else fleet
        self.data = data
        self._shards: List[Shard] = list(shards)
        self.config = config or SessionConfig()
        self.benchmark = benchmark
        self.callbacks = callbacks or CallbackRegistry()
        # the storage data plane: explicit arg > FleetSpec.storage > default
        self.storage: StorageSpec = storage or spec_storage or StorageSpec()
        # cluster mode: the spec travels on the FleetSpec; the live process
        # identity (ClusterContext) is attached by the WorkerRuntime after
        # the jax.distributed handshake.  No context attached = the
        # repro.compat single-process fallback: same stages, one process.
        self.cluster_spec: Optional[ClusterSpec] = (
            fleet.cluster if isinstance(fleet, FleetSpec) else None
        )
        self._cluster: Optional[ClusterContext] = None
        self._local_plan: Optional[ShardingPlan] = None
        # the device fleet persists across stage rebuilds — custody state
        # (quarantine tombstones, re-homed public shards) must survive
        # re-plans exactly like live membership does
        self._device_fleet: Optional[DeviceFleet] = None
        self._artifacts: Dict[str, Any] = {}
        self._compile_count = 0
        # WorkerClass templates survive a fully-dead class leaving the fleet,
        # so a replacement node can still rejoin under the same class name
        self._class_templates: Dict[str, Any] = {
            c.name: c for c in self.fleet.classes
        }
        # canonical live membership: survives stage rebuilds (tune(force=True)
        # must not resurrect dead workers from bare class counts)
        self._group_workers: Optional[Tuple[str, ...]] = None
        # per-class high-water mark of worker indices ever handed out, so a
        # joiner can never be relabeled as a dead worker
        self._next_index: Dict[str, int] = {}

    def _note_labels(self, workers: Sequence[str]) -> None:
        for w in workers:
            cls, idx = w.rsplit("/", 1)
            self._next_index[cls] = max(
                self._next_index.get(cls, 0), int(idx) + 1
            )

    # -- cluster mode ------------------------------------------------------

    @property
    def cluster(self) -> Optional[ClusterContext]:
        return self._cluster

    def attach_cluster(self, ctx: ClusterContext) -> None:
        """Bind this session to its worker-process identity (see
        :class:`~repro.launch.mesh.ClusterContext`).  Must happen before the
        first stage builds — custody and mesh resolution key off it."""
        if self._artifacts or self._device_fleet is not None:
            raise RuntimeError(
                "attach_cluster() must run before any stage is built"
            )
        if self.storage.backend not in ("meshfeed",):
            raise ValueError(
                f"cluster execution needs a mesh-delivery storage backend, "
                f"not {self.storage.backend!r} (use "
                f"FleetSpec.with_cluster / with_storage('meshfeed'))"
            )
        self._cluster = ctx

    def _is_cluster(self) -> bool:
        return self._cluster is not None and self._cluster.n_processes > 1

    def process_map(self) -> Optional[ProcessMap]:
        """dp-group -> process custody (None outside cluster mode)."""
        if not self._is_cluster():
            return None
        tp = self.tune()
        pmap = ProcessMap(tp.group_workers, self._cluster.n_processes)
        if pmap.n_groups % pmap.n_processes != 0:
            raise ValueError(
                f"{pmap.n_groups} dp-groups do not split evenly over "
                f"{pmap.n_processes} processes — the mesh's equal row slabs "
                f"would straddle process custody; size the fleet so "
                f"groups % processes == 0"
            )
        return pmap

    def _exec_plan(self) -> ShardingPlan:
        """The plan the STEP runs on: the local (hostsync) compute plan in
        a cluster whose backend cannot span processes, the global plan
        everywhere else.  State (init, restore, adoption) follows it."""
        plan = self.shard()
        if self._is_cluster() and self._cluster.mode == "hostsync":
            return self._local_plan
        return plan

    # -- introspection -----------------------------------------------------

    @property
    def shards(self) -> Tuple[Shard, ...]:
        """Live shard set (shrinks when an owner dies — privacy constraint)."""
        return tuple(self._shards)

    @property
    def compile_count(self) -> int:
        """How many times a CompiledStep was built (the no-recompile probe)."""
        return self._compile_count

    @property
    def devices(self) -> DeviceFleet:
        """The live storage device fleet (provisioned on first access).
        In cluster mode only THIS process's dp-groups get real devices —
        every other worker is a remote custody record."""
        if self._device_fleet is None:
            tp = self.tune()
            pmap = self.process_map()
            self._device_fleet = DeviceFleet.provision(
                tp.group_workers, self._shards, self.data, spec=self.storage,
                process_map=pmap,
                process_id=self._cluster.process_id if pmap else 0,
            )
        return self._device_fleet

    def cached(self, stage: str) -> bool:
        return stage in self._artifacts

    def override(self, stage: str, artifact: Any) -> None:
        """Install a caller-supplied artifact for ``stage``; downstream stages
        are invalidated and will rebuild against it on next access."""
        if stage not in _STAGES:
            raise KeyError(f"unknown stage {stage!r}; stages are {_STAGES}")
        self._invalidate(stage)
        self._artifacts[stage] = artifact
        if stage == "tune":
            # an externally supplied TunePlan defines the live membership
            self._group_workers = tuple(artifact.group_workers)
            self._note_labels(artifact.group_workers)

    def _invalidate(self, from_stage: str) -> None:
        self._artifacts.pop(from_stage, None)
        for s in _DOWNSTREAM[from_stage]:
            self._artifacts.pop(s, None)

    # -- stage 1: Algorithm 1 ---------------------------------------------

    def tune(self, *, force: bool = False) -> TunePlan:
        prev = self._artifacts.get("tune")
        prev_compiled = self._artifacts.get("compile")
        prev_shard = self._artifacts.get("shard")
        if force:
            self._invalidate("tune")
        if "tune" not in self._artifacts:
            result = tune(self.fleet, self.benchmark)
            if self._group_workers is None:
                # first tune: physical workers are enumerated from class counts
                class_counts = {c.name: c.count for c in self.fleet.classes}
                schedule, workers = schedule_from_tune(
                    result.batches, class_counts
                )
                self._group_workers = tuple(workers)
            else:
                # rebuild (e.g. force=True after elastic events): keep the
                # live membership, map per-class batches onto it
                workers = self._group_workers
                new_batches = tuple(
                    result.batches[w.rsplit("/", 1)[0]] for w in workers
                )
                if prev is not None and prev.group_workers == workers:
                    # preserve the pinned capacity (and round_to): a re-tune
                    # that fits under it keeps the compiled shapes
                    schedule = prev.schedule.with_batches(new_batches)
                else:
                    schedule = BatchSchedule(new_batches)
            self._note_labels(workers)
            self._artifacts["tune"] = TunePlan(
                result=result, schedule=schedule, group_workers=tuple(workers)
            )
            if (
                prev_shard is not None
                and prev_shard.global_rows == schedule.global_rows
            ):
                # same rows => same mesh => the resolved plan survives
                self._artifacts["shard"] = prev_shard
            if (
                prev_compiled is not None
                and prev_compiled.global_rows == schedule.global_rows
            ):
                self._artifacts["compile"] = prev_compiled
        return self._artifacts["tune"]

    # -- stage 2: Eq. 1 epoch balancing -----------------------------------

    def plan(self, *, force: bool = False) -> EpochPlan:
        if force:
            self._invalidate("plan")
        if "plan" not in self._artifacts:
            tp = self.tune()
            batches = dict(zip(tp.group_workers, tp.schedule.group_batches))
            private_sizes = {w: 0 for w in tp.group_workers}
            n_public = 0
            for s in self._shards:
                if s.private:
                    private_sizes[s.owner] = (
                        private_sizes.get(s.owner, 0) + s.n_samples
                    )
                else:
                    n_public += s.n_samples
            self._artifacts["plan"] = plan_epoch(batches, private_sizes, n_public)
        return self._artifacts["plan"]

    # -- stage 3: privacy placement ---------------------------------------

    def place(self, *, force: bool = False) -> FleetManifest:
        """Privacy placement, fleet-aware: the core manifest wrapped with
        per-device custody records (which device holds which shards, under
        which backend)."""
        if force:
            self._invalidate("place")
        if "place" not in self._artifacts:
            epoch = self.plan()
            targets = {sh.worker: sh.total for sh in epoch.shares}
            core = place(list(self._shards), targets)
            self._artifacts["place"] = self.devices.manifest(core)
        return self._artifacts["place"]

    # -- stage 3b: data pipeline (internal, derived from plan + place) -----

    @property
    def dataset(self) -> FleetBatcher:
        if "dataset" not in self._artifacts:
            tp = self.tune()
            self._artifacts["dataset"] = make_fleet_batcher(
                self.data, tp.schedule, list(tp.group_workers),
                self.place(), self.devices,
            )
        return self._artifacts["dataset"]

    # -- stage 4: the sharding plan ---------------------------------------

    def shard(self, *, force: bool = False) -> ShardingPlan:
        """Resolve the logical-axis rule table against the live mesh ONCE.

        The plan is the placement contract every downstream consumer reads:
        ``compile()`` (explicit in/out_shardings), sharded init, the
        meshfeed data plane, and checkpoint restore.  It is keyed by the
        schedule's ``global_rows``: a cached plan for a different row count
        (an elastic resize changed the mesh) is invalidated and re-derived,
        together with the compiled step.
        """
        if force:
            self._invalidate("shard")
        tp = self.tune()
        cached = self._artifacts.get("shard")
        if cached is not None and cached.global_rows != tp.schedule.global_rows:
            self._invalidate("shard")      # elastic mesh resize: re-derive
        if "shard" not in self._artifacts:
            rows = tp.schedule.global_rows
            if self._is_cluster():
                # the CLUSTER mesh: every process's devices, process-major,
                # resolved identically in every process (the shared
                # contract each worker feeds its addressable slice of)
                mesh = self._cluster.global_mesh(rows)
            else:
                mesh = self.devices.feed_mesh(rows)
            if mesh is None:
                # host-delivery backends: same code path on a 1x1 mesh
                mesh = make_single_mesh()
            self._artifacts["shard"] = build_sharding_plan(
                self.model, self.optimizer,
                mesh=mesh,
                global_rows=rows,
                seq_len=self.data.seq_len,
                extra_rules=self.sharding_overrides or None,
            )
            self._local_plan = None
        plan = self._artifacts["shard"]
        if (
            self._is_cluster()
            and self._cluster.mode == "hostsync"
            and self._local_plan is None
        ):
            # the hostsync COMPUTE plan: this process's row slab on its own
            # devices, chunked exactly like its share of the global mesh so
            # the local view reuses the global feed's buffers
            pmap = self.process_map()
            start, stop = pmap.row_span(
                self._cluster.process_id, tp.schedule.max_local
            )
            self._local_plan = build_sharding_plan(
                self.model, self.optimizer,
                mesh=self._cluster.local_mesh(
                    stop - start,
                    data_axis=plan.data_axis // self._cluster.n_processes,
                ),
                global_rows=stop - start,
                seq_len=self.data.seq_len,
                extra_rules=self.sharding_overrides or None,
            )
        # (re-)hand the plan to the data plane: meshfeed lands every batch
        # with the plan's exact NamedShardings; idempotent for other backends
        self.devices.adopt_plan(
            plan,
            self._local_plan
            if self._is_cluster() and self._cluster.mode == "hostsync"
            else None,
        )
        return plan

    # -- stage 5: the jitted SPMD step ------------------------------------

    def _config_key(self) -> Tuple:
        """The SessionConfig values baked into the compiled step."""
        cfg = self.config
        return (cfg.base_lr, cfg.base_batch, cfg.warmup_steps,
                cfg.total_steps, cfg.aux_weight)

    def compile(self, *, force: bool = False) -> CompiledStep:
        if force:
            self._invalidate("compile")
        cached = self._artifacts.get("compile")
        if cached is not None and cached.config_key != self._config_key():
            # config edits between runs must take effect (the step bakes in
            # the lr schedule); drift re-tunes deliberately do NOT count —
            # valid_rows stays anchored at build time, as in the seed
            self._invalidate("compile")
        if "compile" not in self._artifacts:
            tp = self.tune()
            plan = self.shard()
            sched = goyal_schedule(
                self.config.base_lr,
                tp.schedule.valid_rows,
                base_batch=self.config.base_batch,
                warmup_steps=self.config.warmup_steps,
                total_steps=self.config.total_steps,
            )
            if self._is_cluster() and self._cluster.mode == "hostsync":
                step_fn, in_sh, out_sh = self._compile_hostsync(sched)
            else:
                step = make_train_step(
                    self.model, self.optimizer, sched,
                    aux_weight=self.config.aux_weight,
                )
                mesh = plan.mesh

                def step_in_mesh(params, opt_state, batch):
                    # trace under the plan's mesh AND rule table so the
                    # model's logical-axis activation constraints resolve
                    # against the same (possibly overridden) rules that
                    # produced the argument shardings — not the defaults
                    with use_rules(plan.rules), compat_set_mesh(mesh):
                        return step(params, opt_state, batch)

                in_sh = (plan.params, plan.opt, plan.batch)
                # metrics are scalars: plan.replicated is a pytree-prefix
                # for the whole metrics dict
                out_sh = (plan.params, plan.opt, plan.replicated)
                step_fn = jax.jit(
                    step_in_mesh,
                    in_shardings=in_sh,
                    out_shardings=out_sh,
                    donate_argnums=(0, 1),
                )
            self._compile_count += 1
            self._artifacts["compile"] = CompiledStep(
                step_fn=step_fn,
                global_rows=tp.schedule.global_rows,
                seq_len=self.data.seq_len,
                valid_rows=tp.schedule.valid_rows,
                build_id=self._compile_count,
                config_key=self._config_key(),
                in_shardings=in_sh,
                out_shardings=out_sh,
            )
        return self._artifacts["compile"]

    def _transport_spec(self):
        """The TransportSpec in force: the attached context's (set by the
        worker CLI) wins; the FleetSpec's ClusterSpec is the fallback."""
        from repro.core.topology import TransportSpec

        if self._cluster is not None and self._cluster.transport_spec is not None:
            return self._cluster.transport_spec
        if self.fleet.cluster is not None:
            return self.fleet.cluster.transport
        return TransportSpec()

    def _compile_hostsync(self, sched):
        """The cluster step for backends that cannot run cross-process XLA
        programs: a jitted partial-gradient half over this process's local
        plan emitting per-bucket flat f32 vectors, a
        :class:`~repro.launch.transport.GradReducer` round (compression /
        overlap / star-or-ring per the :class:`TransportSpec`), and a
        jitted apply half that unflattens inside the step — one ``step_fn``
        with the standard signature.  Numerically the single-program step
        (see :func:`make_partial_grad_step`); counts as ONE compile (the
        no-recompile probe spans both halves).
        """
        from repro.launch.transport import GradReducer, StarTransport

        lp = self._local_plan
        ctx = self._cluster
        tspec = self._transport_spec()
        params_abs, _ = self.model.init_params(abstract=True)
        groups = plan_buckets(params_abs, tspec.buckets)
        grad_step = make_bucketed_grad_step(
            self.model, groups, aux_weight=self.config.aux_weight
        )
        apply_step = make_bucketed_apply_step(
            self.optimizer, sched, params_abs, groups,
            aux_weight=self.config.aux_weight,
        )

        def grad_in_mesh(params, batch):
            with use_rules(lp.rules), compat_set_mesh(lp.mesh):
                return grad_step(params, batch)

        def apply_in_mesh(params, opt_state, bucket_vecs, sums):
            with use_rules(lp.rules), compat_set_mesh(lp.mesh):
                return apply_step(params, opt_state, bucket_vecs, sums)

        vec_sh = tuple(lp.replicated for _ in groups)
        jit_grad = jax.jit(
            grad_in_mesh,
            in_shardings=(lp.params, lp.batch),
            out_shardings=(vec_sh, lp.replicated),
        )
        # explicit in_shardings matter: the reduced buckets come back as
        # numpy arrays, and jit without placement hints pays a slow
        # host-layout probe on every call (measured ~60ms vs ~4ms/step)
        jit_apply = jax.jit(
            apply_in_mesh,
            in_shardings=(lp.params, lp.opt, vec_sh, lp.replicated),
            out_shardings=(lp.params, lp.opt, lp.replicated),
            donate_argnums=(0, 1),
        )
        reducer = None
        if ctx.sync is not None:
            # cached on the context so error-feedback residuals (and the
            # ring's sockets) survive recompiles
            reducer = ctx.grad_reducer
            if reducer is None:
                wire = ctx.transport or StarTransport(ctx.sync)
                reducer = GradReducer(
                    wire, tspec, ctx.process_id, ctx.n_processes
                )
                ctx.grad_reducer = reducer
        counter = iter(range(1 << 62))

        def step_fn(params, opt_state, batch):
            vecs, sums = jit_grad(params, batch)
            if reducer is not None:
                host_vecs = [np.asarray(jax.device_get(v)) for v in vecs]
                host_sums = jax.tree_util.tree_map(
                    lambda x: np.asarray(jax.device_get(x)), sums
                )
                # deterministic pid-ordered reduction: every process gets
                # identical totals, applies the identical update, and the
                # replicas stay bit-synchronized without a broadcast
                red_vecs, sums = reducer.reduce(
                    f"step/{next(counter)}", host_vecs, host_sums
                )
                vecs = tuple(red_vecs)
            return jit_apply(params, opt_state, vecs, sums)

        in_sh = (lp.params, lp.opt, lp.batch)
        out_sh = (lp.params, lp.opt, lp.replicated)
        return step_fn, in_sh, out_sh

    # -- sharded state construction / adoption ----------------------------

    def init_state(
        self,
        plan: Optional[ShardingPlan] = None,
        *,
        key: Optional[jax.Array] = None,
        init_opt: bool = True,
    ) -> Tuple[PyTree, Any]:
        """Initialize (params, opt_state) DIRECTLY as mesh shards.

        Both inits are jitted with the plan's trees as ``out_shardings``, so
        every leaf materializes on its own mesh slice — a fully replicated
        host-side param tree never exists at any point.  The only bytes that
        ever cross host->device are the PRNG seed (pass ``key`` to move even
        that out; ``benchmarks/bench_step.py`` proves the zero-transfer
        property under ``jax.transfer_guard("disallow")``).
        """
        plan = plan or self._exec_plan()
        model = self.model

        def init_fn(key):
            params, _ = model.init_params(key=key)
            return params

        if key is None:
            key = jax.random.PRNGKey(self.config.seed)
        params = jax.jit(init_fn, out_shardings=plan.params)(key)
        if not init_opt:      # caller brings its own opt_state (continuation)
            return params, None
        opt_state = jax.jit(
            self.optimizer.init, out_shardings=plan.opt
        )(params)
        return params, opt_state

    def _adopt_state(self, tree: PyTree, shardings: PyTree) -> PyTree:
        """Re-home caller-supplied state onto the live plan (a no-op when it
        already matches — e.g. continuing a run on an unchanged mesh)."""
        return jax.device_put(tree, shardings)

    # -- stage 5: training ------------------------------------------------

    def run(
        self,
        params: Optional[PyTree] = None,
        *,
        opt_state: Optional[PyTree] = None,
        steps: Optional[int] = None,
    ) -> TrainReport:
        """Train.  Pass a prior report's ``params`` AND ``opt_state`` to
        continue after an elastic event — the optimizer's moments and the
        lr-schedule step counter live in ``opt_state``, so omitting it
        restarts warmup from step 0."""
        cfg = self.config
        steps = steps or cfg.total_steps

        compiled = self.compile()
        plan = self._exec_plan()
        ckpt = None
        if cfg.checkpoint_dir:
            if self._is_cluster():
                # coordinated save: single writer per shard, barrier at the
                # coordinator, primary publishes — same call sites below
                ckpt = ClusterCheckpointManager(
                    cfg.checkpoint_dir, keep=cfg.keep_checkpoints,
                    process_index=self._cluster.process_id,
                    num_processes=self._cluster.n_processes,
                    sync=self._cluster.sync,
                )
            else:
                ckpt = CheckpointManager(
                    cfg.checkpoint_dir, keep=cfg.keep_checkpoints
                )
        start_step = 0
        if ckpt is not None and ckpt.latest_step() is not None:
            # restart-after-failure: resume the newest valid checkpoint,
            # each leaf placed STRAIGHT onto the plan's NamedSharding — the
            # elastic path (save at dp=8, restore at dp=4) never stages a
            # fully replicated tree on any device
            params_abs, _, opt_abs = abstract_train_state(
                self.model, self.optimizer
            )
            state, meta = ckpt.restore(
                {"params": params_abs, "opt": opt_abs},
                shardings={"params": plan.params, "opt": plan.opt},
            )
            params, opt_state = state["params"], state["opt"]
            start_step = int(meta.get("step", ckpt.latest_step()))
            # resume the SAMPLING state too: without the cursors a restart
            # replays already-seen batches (and a restore-on-fewer-processes
            # run would diverge from the uninterrupted one)
            self.dataset.set_cursors(meta.get("cursors") or {})
        else:
            # no checkpoint: fresh state is BORN sharded (jitted init with
            # the plan as out_shardings); caller-supplied state (continuing
            # across an elastic event) is re-homed onto the live plan — a
            # no-op when the mesh did not change
            if params is None:
                params, fresh_opt = self.init_state(
                    plan, init_opt=opt_state is None
                )
                opt_state = opt_state if opt_state is not None else fresh_opt
            else:
                params = self._adopt_state(params, plan.params)
            if opt_state is None:
                opt_state = jax.jit(
                    self.optimizer.init, out_shardings=plan.opt
                )(params)
            else:
                opt_state = self._adopt_state(opt_state, plan.opt)

        dataset = self.dataset
        monitor = DriftMonitor(
            margin=cfg.retune_margin, patience=cfg.retune_patience
        )
        history: List[Dict[str, float]] = []
        t0 = time.perf_counter()

        for i in range(start_step, steps):
            # batches come THROUGH the device fleet: each dp-group's rows are
            # assembled in its storage device, and the meshfeed backend lands
            # them pre-sharded on the mesh
            batch = dataset.next_device_batch()
            ts = time.perf_counter()
            params, opt_state, metrics = compiled.step_fn(params, opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step_time"] = time.perf_counter() - ts
            history.append(metrics)
            self.callbacks.emit_step(i, metrics)

            # straggler watch: feed per-class analytic times perturbed by the
            # observed wall time (single-host stand-in for per-worker probes)
            tp = self.tune()
            class_times = {
                c.name: self.fleet.by_name(c.name).step_time(
                    tp.result.batches[c.name]
                )
                for c in self.fleet.classes
                if c.name in tp.result.batches
            }
            if monitor.update(class_times):
                self.apply(DriftDetected(source="monitor"))
                compiled = self.compile()   # same object unless shapes grew
                dataset = self.dataset

            if ckpt is not None and (i + 1) % cfg.checkpoint_every == 0:
                ckpt.save(
                    i + 1, {"params": params, "opt": opt_state},
                    metadata={
                        "step": i + 1,
                        "schedule": list(self.tune().schedule.group_batches),
                        "cursors": dataset.cursors(),
                    },
                    async_=cfg.async_checkpoint,
                )
                self.callbacks.emit_checkpoint(i + 1, cfg.checkpoint_dir)
        if ckpt is not None:
            ckpt.wait()
        return TrainReport(
            params=params,
            opt_state=opt_state,
            history=tuple(history),
            steps_run=len(history),
            start_step=start_step,
            compile_count=self._compile_count,
            wall_time=time.perf_counter() - t0,
        )

    # -- the ONE elastic replanning path ----------------------------------

    def apply(self, event: FleetEvent) -> ReplanResult:
        """Route any elastic fleet event through one replanning code path.

        The pinned row ``capacity`` always survives the event, so shapes only
        change when the group COUNT changes (node loss/join) — never on a
        drift re-tune.
        """
        old = self.tune()
        dropped: Tuple[str, ...] = ()

        if isinstance(event, DriftDetected):
            # membership never changes on drift: re-tune per-CLASS batches
            # and map them onto the CURRENT group workers (which may already
            # reflect earlier losses/joins)
            result = tune(self.fleet, self.benchmark)
            new_batches = tuple(
                result.batches[w.rsplit("/", 1)[0]] for w in old.group_workers
            )
            # capacity-pinned: same shapes => the compiled step survives
            schedule = old.schedule.with_batches(new_batches)
            new = TunePlan(result=result, schedule=schedule,
                           group_workers=old.group_workers)

        elif isinstance(event, WorkerLost):
            dead = set(event.workers)
            missing = dead - set(old.group_workers)
            if missing:
                raise KeyError(f"unknown workers {sorted(missing)}")
            keep = [
                (w, b) for w, b in zip(old.group_workers,
                                       old.schedule.group_batches)
                if w not in dead
            ]
            if not keep:
                raise ValueError("cannot lose every worker in the fleet")
            # shrink the fleet's class counts so later tunes/joins see the
            # true membership (a fully-dead class leaves the fleet)
            lost_per_class: Dict[str, int] = {}
            for w in dead:
                cls = w.rsplit("/", 1)[0]
                lost_per_class[cls] = lost_per_class.get(cls, 0) + 1
            self.fleet = Fleet(classes=tuple(
                dataclasses.replace(c, count=c.count - lost_per_class.get(c.name, 0))
                for c in self.fleet.classes
                if c.count - lost_per_class.get(c.name, 0) > 0
            ))
            # paper's remedy, routed through the fleet custody API: dead
            # workers' private shards are quarantined (nobody else may read
            # them — tombstoned on every surviving device), their public
            # custody re-homes to survivors; plan_epoch rebalances the share
            dropped = self.devices.quarantine_workers(sorted(dead))
            self._shards = [
                s for s in self._shards
                if not (s.private and s.owner in dead)
            ]
            # pin capacity to the pre-event max_local: fewer groups, but the
            # per-group row count is stable (no avoidable max_local shrink)
            schedule = BatchSchedule(
                tuple(b for _, b in keep),
                round_to=old.schedule.round_to,
                capacity=old.schedule.max_local,
            )
            new = TunePlan(result=old.result, schedule=schedule,
                           group_workers=tuple(w for w, _ in keep))

        elif isinstance(event, WorkerJoined):
            if any(c.name == event.class_name for c in self.fleet.classes):
                self.fleet = Fleet(classes=tuple(
                    dataclasses.replace(c, count=c.count + event.count)
                    if c.name == event.class_name else c
                    for c in self.fleet.classes
                ))
            elif event.class_name in self._class_templates:
                # the class fully died earlier; revive it from its template
                self.fleet = Fleet(classes=self.fleet.classes + (
                    dataclasses.replace(
                        self._class_templates[event.class_name],
                        count=event.count,
                    ),
                ))
            else:
                raise KeyError(event.class_name)
            result = tune(self.fleet, self.benchmark)
            # survivors keep their labels (private shards stay pinned to the
            # right physical owners); joiners draw fresh never-used indices
            # from the high-water mark, so a dead worker's label (e.g. the
            # highest index) is never recycled for a new machine
            start = self._next_index.get(event.class_name, 0)
            self._next_index[event.class_name] = start + event.count
            joiners = tuple(
                f"{event.class_name}/{start + i}" for i in range(event.count)
            )
            workers = old.group_workers + joiners
            # provision fresh storage devices for the joiners (they hold the
            # public pool; no private shards exist for a new worker yet)
            for w in joiners:
                self.devices.provision_worker(w)
            schedule = BatchSchedule(
                tuple(result.batches[w.rsplit("/", 1)[0]] for w in workers),
                round_to=old.schedule.round_to,
                capacity=old.schedule.max_local,   # never shrinks; growth
            )                                      # beyond it recompiles
            new = TunePlan(result=result, schedule=schedule,
                           group_workers=workers)

        else:
            raise TypeError(f"unknown fleet event {event!r}")

        # ---- shared tail: install the new TunePlan, re-plan, re-place ----
        compiled = self._artifacts.get("compile")
        keep_compiled = (
            compiled is not None
            and compiled.global_rows == new.schedule.global_rows
        )
        shard_plan = self._artifacts.get("shard")
        keep_shard = (
            shard_plan is not None
            and shard_plan.global_rows == new.schedule.global_rows
        )
        dataset = self._artifacts.get("dataset")
        keep_dataset = (
            dataset is not None and new.group_workers == old.group_workers
        )
        self.override("tune", new)          # invalidates plan/place/dataset
        if keep_shard:
            # same rows => same mesh: the resolved sharding plan survives
            # the event exactly like the compiled step does
            self._artifacts["shard"] = shard_plan
        if keep_compiled:
            self._artifacts["compile"] = compiled
        self.plan()
        self.place()
        if keep_dataset:
            # same membership (drift re-tune): rewire the live iterator to
            # the re-planned schedule AND placement so plan()/place() keep
            # describing what training samples, while per-worker epoch
            # cursors survive (no replay of already-seen data)
            dataset.rewire(
                new.schedule,
                manifest_sources(self.place(), list(new.group_workers)),
            )
            self._artifacts["dataset"] = dataset
        else:
            _ = self.dataset
        result_obj = ReplanResult(
            event=event, tune_plan=new,
            # only a real invalidation counts: with no step compiled yet,
            # nothing was thrown away
            recompiled=compiled is not None and not keep_compiled,
            dropped_shards=dropped,
        )
        if isinstance(event, DriftDetected):
            self.callbacks.emit_retune(event, new)
        else:
            self.callbacks.emit_fleet_change(event, result_obj)
        return result_obj
