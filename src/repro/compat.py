"""jax version compatibility: one import site for APIs that moved.

The repo targets the modern mesh API (``jax.sharding.AxisType``,
``jax.set_mesh``, ``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.get_abstract_mesh``), but must also run on jax 0.4.x where
none of those exist.  Every call site imports the equivalents from here
instead of feature-testing jax inline:

  * :func:`make_mesh` — builds an Auto-axis mesh on both API generations.
  * :func:`set_mesh` — context manager activating a mesh; on 0.4.x the
    ``Mesh`` object itself is the context manager.
  * :func:`get_abstract_mesh` — the mesh active at trace time, or ``None``;
    on 0.4.x this is the thread-local *physical* mesh, which is strictly
    richer (it also carries devices), so callers treat both uniformly.
  * :func:`constraint_sharding` — wraps a PartitionSpec for
    ``with_sharding_constraint``: bare spec under an abstract mesh,
    ``NamedSharding`` when the mesh is physical (0.4.x requirement outside
    a mesh context).

Multi-process (cluster) execution goes through the same funnel:

  * :func:`distributed_initialize` — the ``jax.distributed.initialize``
    handshake with a single-process fallback: when the runtime has no
    ``jax.distributed`` (or the coordinator is unreachable) the caller gets
    ``False`` back and runs the exact same code path on one process.
  * :func:`process_index` / :func:`process_count` — safe on every jax
    generation, before or after distributed init.
  * :func:`multiprocess_compute_supported` — whether jit computations may
    SPAN processes on this backend.  CPU jaxlib can hold a global mesh,
    build per-host addressable shards, and assemble global arrays — but not
    execute a cross-process XLA program ("Multiprocess computations aren't
    implemented on the CPU backend").  The cluster runtime
    (:mod:`repro.launch.cluster`) keys its execution strategy off this:
    global-SPMD where supported, host-synchronized partial gradients
    (the paper's host-aggregation topology) where not.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

try:  # jax >= 0.6
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x: meshes are implicitly Auto
    AxisType = None

HAS_AXIS_TYPES = AxisType is not None


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with Auto axis types on any jax generation."""
    if HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def set_mesh(mesh: Mesh):
    """Context manager that makes ``mesh`` the ambient mesh.

    jax >= 0.6 exposes ``jax.set_mesh``; on 0.4.x entering the ``Mesh``
    object itself installs it as the thread-local physical mesh, which is
    what ``get_abstract_mesh`` below reads back.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def get_abstract_mesh():
    """The ambient mesh visible at trace time, or ``None`` when outside one.

    Returns the AbstractMesh on jax >= 0.6 and the thread-local physical
    ``Mesh`` on 0.4.x.  Both expose ``axis_names`` and ``shape``.
    """
    try:
        m = jax.sharding.get_abstract_mesh()  # type: ignore[attr-defined]
        return None if m is None or m.empty else m
    except AttributeError:
        pass
    try:
        from jax.interpreters import pxla

        m = pxla.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def axis_size(axis_name: str) -> int:
    """``jax.lax.axis_size`` (>= 0.5); falls back to the bound axis frame.

    On 0.4.x ``jax.core.axis_frame`` returns the size int directly.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)  # type: ignore[attr-defined]
    return frame if isinstance(frame, int) else frame.size


def process_index() -> int:
    """This process's id in the distributed job (0 when single-process)."""
    try:
        return int(jax.process_index())
    except Exception:
        return 0


def process_count() -> int:
    """How many processes share the global device view (1 single-process)."""
    try:
        return int(jax.process_count())
    except Exception:
        return 1


def distributed_initialize(
    coordinator_address: str, num_processes: int, process_id: int,
) -> bool:
    """``jax.distributed.initialize`` with a single-process fallback.

    Returns True when the handshake succeeded and the runtime now holds the
    GLOBAL device view (``jax.devices()`` spans all processes,
    ``jax.local_devices()`` is this host's slice).  Returns False when the
    runtime cannot do distributed init at all (no ``jax.distributed``) —
    callers then run the identical code on the single-process view.
    Idempotent: a second call on an initialized runtime is a no-op True.
    """
    if num_processes <= 1:
        return False
    dist = getattr(jax, "distributed", None)
    if dist is None or not hasattr(dist, "initialize"):
        return False
    # NB: do NOT probe jax.process_count() here — it initializes the
    # backend, after which jax.distributed refuses the handshake
    state = getattr(dist, "global_state", None)
    if state is not None and getattr(state, "client", None) is not None:
        return True          # already initialized (e.g. by the launcher)
    dist.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
    )
    return True


def multiprocess_compute_supported() -> bool:
    """Can a single jit computation span processes on this backend?

    CPU jaxlib supports the distributed *service* (handshake, global device
    view, cross-process array metadata) but refuses to execute multiprocess
    XLA programs.  TPU/GPU backends execute them natively.
    """
    try:
        return jax.default_backend() != "cpu"
    except Exception:
        return False


def constraint_sharding(
    mesh, spec: PartitionSpec
) -> Union[PartitionSpec, NamedSharding]:
    """What to hand ``with_sharding_constraint`` for ``spec`` under ``mesh``.

    A physical mesh (0.4.x path) needs an explicit ``NamedSharding``; an
    abstract mesh (>= 0.6) resolves the bare spec itself.
    """
    if isinstance(mesh, Mesh):
        return NamedSharding(mesh, spec)
    return spec
