"""jax version compatibility: one import site for APIs that moved.

The repo targets the modern mesh API (``jax.sharding.AxisType``,
``jax.set_mesh``, ``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.get_abstract_mesh``), but must also run on jax 0.4.x where
none of those exist.  Every call site imports the equivalents from here
instead of feature-testing jax inline:

  * :func:`make_mesh` — builds an Auto-axis mesh on both API generations.
  * :func:`set_mesh` — context manager activating a mesh; on 0.4.x the
    ``Mesh`` object itself is the context manager.
  * :func:`get_abstract_mesh` — the mesh active at trace time, or ``None``;
    on 0.4.x this is the thread-local *physical* mesh, which is strictly
    richer (it also carries devices), so callers treat both uniformly.
  * :func:`constraint_sharding` — wraps a PartitionSpec for
    ``with_sharding_constraint``: bare spec under an abstract mesh,
    ``NamedSharding`` when the mesh is physical (0.4.x requirement outside
    a mesh context).
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

try:  # jax >= 0.6
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x: meshes are implicitly Auto
    AxisType = None

HAS_AXIS_TYPES = AxisType is not None


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with Auto axis types on any jax generation."""
    if HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def set_mesh(mesh: Mesh):
    """Context manager that makes ``mesh`` the ambient mesh.

    jax >= 0.6 exposes ``jax.set_mesh``; on 0.4.x entering the ``Mesh``
    object itself installs it as the thread-local physical mesh, which is
    what ``get_abstract_mesh`` below reads back.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def get_abstract_mesh():
    """The ambient mesh visible at trace time, or ``None`` when outside one.

    Returns the AbstractMesh on jax >= 0.6 and the thread-local physical
    ``Mesh`` on 0.4.x.  Both expose ``axis_names`` and ``shape``.
    """
    try:
        m = jax.sharding.get_abstract_mesh()  # type: ignore[attr-defined]
        return None if m is None or m.empty else m
    except AttributeError:
        pass
    try:
        from jax.interpreters import pxla

        m = pxla.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def axis_size(axis_name: str) -> int:
    """``jax.lax.axis_size`` (>= 0.5); falls back to the bound axis frame.

    On 0.4.x ``jax.core.axis_frame`` returns the size int directly.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)  # type: ignore[attr-defined]
    return frame if isinstance(frame, int) else frame.size


def constraint_sharding(
    mesh, spec: PartitionSpec
) -> Union[PartitionSpec, NamedSharding]:
    """What to hand ``with_sharding_constraint`` for ``spec`` under ``mesh``.

    A physical mesh (0.4.x path) needs an explicit ``NamedSharding``; an
    abstract mesh (>= 0.6) resolves the bare spec itself.
    """
    if isinstance(mesh, Mesh):
        return NamedSharding(mesh, spec)
    return spec
