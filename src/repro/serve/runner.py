"""Jitted model-step factories shared by :class:`ServeEngine` and
:class:`~repro.api.serving.ServeSession`.

Two compiled entry points per model family:

  * ``decode`` — one batched token step over the engine's slot batch, with
    in-jit sampling and an ``active`` mask: inactive / mid-prefill slots pass
    their cache state through untouched, so one fixed-shape program serves
    every mix of decoding, prefilling, and empty slots (no recompiles as
    requests come and go).
  * ``extend`` — a ``jax.lax.scan`` of the single-token decode step over a
    token chunk: the compiled chunked-prefill primitive (one host round-trip
    per chunk instead of one per token) that also replaces the old
    ``ServeSession._prefill_recurrent`` Python loop.

Both donate the cache argument (``donate_argnums``), so stepping never copies
the KV/state buffers — the decode loop is update-in-place end to end.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.serve.sampling import make_sample_fn

PyTree = Any


def mask_tree(new: PyTree, old: PyTree, active: jax.Array) -> PyTree:
    """Per-slot select: active rows take ``new``, the rest keep ``old``.

    Every cache leaf in every family carries the batch (slot) dimension at
    axis 1 — (layers, batch, ...) — which this relies on.
    """

    def f(n, o):
        shape = [1] * n.ndim
        shape[1] = active.shape[0]
        return jnp.where(active.reshape(shape), n, o)

    return jax.tree_util.tree_map(f, new, old)


class StepRunner:
    """Holds the jitted decode/extend programs for one (model, params) pair."""

    def __init__(self, model: Model, *, k_cap: int = 64):
        self.model = model
        self._sample = make_sample_fn(k_cap)
        self.sample1 = jax.jit(self._sample)
        self.decode = jax.jit(self._decode_fn, donate_argnums=(2,))
        self.extend = jax.jit(self._extend_fn, donate_argnums=(2,))

    # decode(params, tok (S,1), cache, pos (S,), active (S,) bool,
    #        keys (S,2) u32, temp (S,) f32, topk (S,) i32)
    #   -> (next_tok (S,), new_cache)
    def _decode_fn(self, params, tok, cache, pos, active, keys, temp, topk):
        logits, new_cache = self.model.decode_step(params, tok, cache, pos)
        nxt = self._sample(logits[:, -1], keys, temp, topk)
        nxt = jnp.where(active, nxt, 0)
        return nxt, mask_tree(new_cache, cache, active)

    # extend(params, tokens (B, C), cache, start (B,))
    #   -> (last_logits (B, V), new_cache)
    def _extend_fn(self, params, tokens, cache, start):
        ts = jnp.arange(tokens.shape[1])

        def body(carry, xs):
            cache = carry
            tok_t, t = xs                                 # (B,), ()
            logits, cache = self.model.decode_step(
                params, tok_t[:, None], cache, start + t
            )
            return cache, logits[:, -1]

        cache, logits_seq = jax.lax.scan(body, cache, (tokens.T, ts))
        return logits_seq[-1], cache
