"""Ref-counted block allocator with hash-based prefix caching.

The allocator manages *logical* block ids in ``[0, num_blocks)``; what a block
physically holds is the adapter's business (:mod:`repro.serve.adapters`): a
page of KV rows for attention families, a recurrent-state snapshot for
rwkv6/rglru.  The STANNIS discipline — compute where the data lives instead of
moving it — shows up here as *don't recompute what is already resident*: a
prefix that hashes to a live block is reused byte-for-byte instead of being
prefilled again.

Lifecycle of a block:

    free ──allocate()──► live (ref=1) ──decref() to 0──┬─► cached  (hashed:
      ▲                      ▲                          │   evictable LRU, but
      │                      └──lookup(hash) re-refs────┘   still a hit target)
      └──────────── evicted when allocate() finds no free block ◄┘

``lookup`` resurrects cached blocks (a prefix-cache hit on a finished
request's blocks), so the pool behaves like an LRU cache of the most recent
prefixes under allocation pressure.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence, Tuple


def hash_block(prev_hash: int, tokens: Sequence[int]) -> int:
    """Chained content hash: identifies the FULL prefix ending at this block."""
    return hash((prev_hash, tuple(int(t) for t in tokens)))


def hash_chain(tokens: Sequence[int], block_size: int) -> List[int]:
    """One chained hash per *full* block of ``tokens`` (the trailing partial
    block is not hashable — it can't be shared)."""
    out: List[int] = []
    h = 0
    for i in range(len(tokens) // block_size):
        h = hash_block(h, tokens[i * block_size:(i + 1) * block_size])
        out.append(h)
    return out


@dataclasses.dataclass
class CacheStats:
    queries: int = 0          # prefix-cache probes (per block)
    hit_blocks: int = 0       # probes that found a resident block
    allocated: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hit_blocks / self.queries if self.queries else 0.0


class BlockAllocator:
    """Fixed pool of ``num_blocks`` ref-counted blocks + hash → block map."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError("num_blocks and block_size must be positive")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: deque = deque(range(num_blocks))
        self._cached: "OrderedDict[int, int]" = OrderedDict()  # block_id -> hash (LRU)
        self._ref: Dict[int, int] = {}                         # block_id -> refcount
        self._hash_of: Dict[int, int] = {}                     # block_id -> hash
        self._table: Dict[int, int] = {}                       # hash -> block_id
        self.stats = CacheStats()

    # -- introspection --------------------------------------------------------

    @property
    def num_free(self) -> int:
        """Blocks allocatable right now (never-used + evictable cached)."""
        return len(self._free) + len(self._cached)

    @property
    def num_live(self) -> int:
        return len(self._ref)

    def refcount(self, block_id: int) -> int:
        return self._ref.get(block_id, 0)

    # -- prefix cache ---------------------------------------------------------

    def lookup(self, h: int) -> Optional[int]:
        """Hash probe.  A hit returns the block id with its refcount BUMPED
        (the caller now holds a reference and must ``decref`` eventually)."""
        self.stats.queries += 1
        bid = self._table.get(h)
        if bid is None:
            return None
        self.stats.hit_blocks += 1
        if bid in self._cached:            # resurrect an evictable block
            del self._cached[bid]
            self._ref[bid] = 1
        else:
            self._ref[bid] += 1
        return bid

    def contains(self, h: int) -> bool:
        return h in self._table

    # -- alloc / free ---------------------------------------------------------

    def allocate(self, h: Optional[int] = None) -> Optional[int]:
        """Take a block (ref=1), optionally registering it under hash ``h``.
        Returns None when every block is referenced (pool exhausted)."""
        if self._free:
            bid = self._free.popleft()
        elif self._cached:
            bid, old_h = self._cached.popitem(last=False)   # evict LRU
            del self._table[old_h]
            del self._hash_of[bid]
            self.stats.evictions += 1
        else:
            return None
        self._ref[bid] = 1
        if h is not None:
            if h in self._table:
                raise ValueError(f"hash {h} already registered")
            self._table[h] = bid
            self._hash_of[bid] = h
        self.stats.allocated += 1
        return bid

    def incref(self, block_id: int) -> None:
        if block_id not in self._ref:
            raise ValueError(f"block {block_id} is not live")
        self._ref[block_id] += 1

    def decref(self, block_id: int) -> None:
        """Release one reference.  At zero, a hashed block becomes *cached*
        (still a lookup target, evictable LRU); an anonymous one goes free."""
        n = self._ref.get(block_id)
        if n is None:
            raise ValueError(f"block {block_id} is not live")
        if n > 1:
            self._ref[block_id] = n - 1
            return
        del self._ref[block_id]
        h = self._hash_of.get(block_id)
        if h is None:
            self._free.append(block_id)
        else:
            self._cached[block_id] = h

    free = decref
