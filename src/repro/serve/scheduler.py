"""Step-level continuous-batching scheduler (pure bookkeeping, no jax).

Each engine step the scheduler hands out a :class:`StepSchedule` under a hard
``token_budget``:

  * every RUNNING (decoding) request gets exactly 1 token — decode is
    prioritized so in-flight requests keep streaming and eventually free
    their slot (no starvation via decode);
  * remaining budget goes to chunked prefill, FCFS: partially-prefilled
    requests continue, then WAITING requests are admitted into free slots —
    a new request starts prefilling *while* older requests keep decoding
    (continuous batching), and a long prompt is consumed in
    ``prefill_chunk``-token chunks instead of stalling the decode batch.

Invariants (property-tested in ``tests/test_serve_engine.py``):

  * scheduled tokens per step never exceed ``token_budget``;
  * admission is strictly FCFS (a later request never enters a slot while an
    earlier one is still waiting);
  * a slot is owned by at most one request at a time;
  * every request finishes in bounded steps (no starvation).
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple


class RequestStatus(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    RUNNING = "running"     # decoding, 1 token per step
    FINISHED = "finished"


@dataclasses.dataclass
class RequestMeta:
    """Scheduler-visible request state (model state lives in the adapters)."""
    request_id: int
    prompt_len: int
    max_new_tokens: int
    status: RequestStatus = RequestStatus.WAITING
    slot: Optional[int] = None
    prefill_pos: int = 0        # prompt tokens already in the cache
    generated: int = 0          # tokens sampled so far


@dataclasses.dataclass(frozen=True)
class PrefillWork:
    request_id: int
    slot: int
    start: int                  # prompt positions [start, end) this step
    end: int
    last: bool                  # True when end == prompt_len (sample 1st token)


@dataclasses.dataclass(frozen=True)
class StepSchedule:
    admitted: Tuple[int, ...]           # request ids entering a slot this step
    prefill: Tuple[PrefillWork, ...]
    decode: Tuple[int, ...]             # request ids decoding 1 token
    total_tokens: int


class Scheduler:
    def __init__(self, *, max_slots: int, token_budget: int, prefill_chunk: int):
        if token_budget < prefill_chunk:
            raise ValueError("token_budget must cover at least one prefill chunk")
        if max_slots < 1 or prefill_chunk < 1:
            raise ValueError("max_slots and prefill_chunk must be >= 1")
        self.max_slots = max_slots
        self.token_budget = token_budget
        self.prefill_chunk = prefill_chunk
        self.waiting: Deque[int] = deque()
        self.requests: Dict[int, RequestMeta] = {}
        self._active_order: List[int] = []      # admission order of in-slot reqs
        self._free_slots: List[int] = list(range(max_slots))

    # -- request lifecycle ----------------------------------------------------

    def add(self, meta: RequestMeta) -> None:
        if meta.request_id in self.requests:
            raise ValueError(f"duplicate request id {meta.request_id}")
        self.requests[meta.request_id] = meta
        self.waiting.append(meta.request_id)

    def set_prefill_pos(self, request_id: int, pos: int) -> None:
        """Engine reports prefix-cache reuse: prompt positions [0, pos) are
        already resident, prefill resumes at ``pos``."""
        r = self.requests[request_id]
        if not 0 <= pos < r.prompt_len:
            raise ValueError(f"prefill pos {pos} out of range for {r.prompt_len}")
        r.prefill_pos = pos

    def finish(self, request_id: int) -> None:
        r = self.requests[request_id]
        r.status = RequestStatus.FINISHED
        if r.slot is not None:
            self._free_slots.append(r.slot)
            self._active_order.remove(request_id)
            r.slot = None

    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self._active_order)

    @property
    def num_active(self) -> int:
        return len(self._active_order)

    # -- the step decision ----------------------------------------------------

    def admit(self) -> List[int]:
        """Move waiting requests into free slots, strictly FCFS."""
        admitted: List[int] = []
        while self.waiting and self._free_slots:
            rid = self.waiting.popleft()
            r = self.requests[rid]
            r.slot = self._free_slots.pop(0)
            r.status = RequestStatus.PREFILL
            self._active_order.append(rid)
            admitted.append(rid)
        return admitted

    def schedule(self) -> StepSchedule:
        """One step's worth of work.  Call AFTER :meth:`admit` (the engine
        admits first so prefix-cache hits can move the prefill cursor)."""
        budget = self.token_budget
        decode: List[int] = []
        prefill: List[PrefillWork] = []

        # decode first: 1 token per running request (slots bound this by
        # max_slots, and token_budget >= prefill_chunk >= 1 keeps them live)
        for rid in self._active_order:
            r = self.requests[rid]
            if r.status is RequestStatus.RUNNING and budget > 0:
                decode.append(rid)
                budget -= 1

        # then chunked prefill, oldest-admitted first
        for rid in self._active_order:
            r = self.requests[rid]
            if r.status is not RequestStatus.PREFILL or budget <= 0:
                continue
            n = min(self.prefill_chunk, r.prompt_len - r.prefill_pos, budget)
            if n <= 0:
                continue
            start, end = r.prefill_pos, r.prefill_pos + n
            prefill.append(PrefillWork(
                request_id=rid, slot=r.slot, start=start, end=end,
                last=(end == r.prompt_len),
            ))
            budget -= n

        total = len(decode) + sum(w.end - w.start for w in prefill)
        assert total <= self.token_budget
        return StepSchedule(
            admitted=(), prefill=tuple(prefill), decode=tuple(decode),
            total_tokens=total,
        )

    # -- engine feedback ------------------------------------------------------

    def note_prefilled(self, work: PrefillWork) -> None:
        r = self.requests[work.request_id]
        r.prefill_pos = work.end
        if work.last:
            # the last prompt position's logits produced the first token
            r.status = RequestStatus.RUNNING
            r.generated = 1

    def note_decoded(self, request_id: int) -> None:
        self.requests[request_id].generated += 1

    def is_done(self, request_id: int) -> bool:
        r = self.requests[request_id]
        return r.generated >= r.max_new_tokens
