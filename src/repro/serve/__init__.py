"""`repro.serve` — the continuous-batching serving engine.

The inference-side counterpart of :class:`repro.api.Session`:

    from repro.serve import ServeEngine, EngineConfig, SamplingParams

    engine = ServeEngine(model=model, params=params, config=EngineConfig())
    rid = engine.submit(prompt_ids, max_new_tokens=16)
    while engine.has_work():
        for ev in engine.step():          # streams per-request, in order
            ...

Subsystem layout: :mod:`~repro.serve.scheduler` (step-level admission /
chunked prefill under a token budget), :mod:`~repro.serve.block_cache`
(ref-counted blocks + hash-chain prefix cache), :mod:`~repro.serve.adapters`
(per-family cache layouts), :mod:`~repro.serve.runner` (the jitted
decode/extend programs), :mod:`~repro.serve.sampling` (per-request PRNG
streams), :mod:`~repro.serve.loadgen` (synthetic-user benchmark harness).
"""
from repro.serve.adapters import (
    PagedKVAdapter, RecurrentStateAdapter, make_adapter,
)
from repro.serve.block_cache import BlockAllocator, CacheStats, hash_chain
from repro.serve.engine import (
    EngineConfig, GenOutput, ServeEngine, StreamEvent,
)
from repro.serve.loadgen import LoadReport, run_load
from repro.serve.runner import StepRunner
from repro.serve.sampling import GREEDY, SamplingParams
from repro.serve.scheduler import (
    PrefillWork, RequestMeta, RequestStatus, Scheduler, StepSchedule,
)

__all__ = [
    "BlockAllocator",
    "CacheStats",
    "EngineConfig",
    "GenOutput",
    "GREEDY",
    "LoadReport",
    "PagedKVAdapter",
    "PrefillWork",
    "RecurrentStateAdapter",
    "RequestMeta",
    "RequestStatus",
    "SamplingParams",
    "ServeEngine",
    "Scheduler",
    "StepRunner",
    "StepSchedule",
    "StreamEvent",
    "hash_chain",
    "make_adapter",
    "run_load",
]
