"""`ServeEngine`: continuous-batching serving with streaming outputs.

The inference-side counterpart of the staged training ``Session``: requests
enter a queue, a step-level scheduler admits them into the in-flight decode
batch (chunked prefill interleaves with decode instead of stalling it), and a
per-family cache adapter keeps their context resident — paged ref-counted KV
blocks for attention families, O(1)-state slots with snapshot prefix caching
for recurrent ones.

    engine = ServeEngine(model=model, params=params)
    rid = engine.submit([1, 2, 3], max_new_tokens=8)
    while engine.has_work():
        for ev in engine.step():
            print(ev.request_id, ev.token, ev.done)   # streams in token order

Everything compiled is fixed-shape: one decode program over the whole slot
batch (inactive slots masked, cache donated) plus one extend program per
prefill-chunk length — admission and completion never trigger recompiles.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serve.adapters import make_adapter, slot_slice, slot_write
from repro.serve.runner import StepRunner
from repro.serve.sampling import GREEDY, SamplingParams, request_key, token_key
from repro.serve.scheduler import RequestMeta, Scheduler

PyTree = Any


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 8          # concurrent decode batch size
    max_len: int = 64           # per-slot context rows (attention families)
    block_size: int = 8         # prefix-cache block granularity (tokens)
    num_blocks: int = 128       # pool pages / state snapshots
    prefill_chunk: int = 16     # prompt tokens per prefill step
    token_budget: int = 32      # scheduled tokens per engine step
    k_cap: int = 64             # static top-k bound for the sampler
    eos_token: Optional[int] = None

    def __post_init__(self):
        if self.prefill_chunk % self.block_size:
            raise ValueError(
                "prefill_chunk must be a multiple of block_size so chunk "
                "boundaries align with prefix-cache blocks"
            )


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    request_id: int
    token: int
    index: int                  # 0-based position in the generated stream
    done: bool
    finish_reason: Optional[str] = None    # "length" | "stop"


@dataclasses.dataclass
class GenOutput:
    request_id: int
    prompt_len: int
    tokens: List[int]
    finish_reason: str = ""
    submit_time: float = 0.0
    first_token_time: float = 0.0
    finish_time: float = 0.0

    @property
    def latency(self) -> float:
        return self.finish_time - self.submit_time

    @property
    def ttft(self) -> float:
        return self.first_token_time - self.submit_time


@dataclasses.dataclass
class _Record:
    prompt: tuple
    max_new_tokens: int
    sampling: SamplingParams
    root_key: jax.Array
    out: GenOutput


class ServeEngine:
    def __init__(self, *, model: Model, params: PyTree,
                 config: EngineConfig = EngineConfig()):
        self.model = model
        self.params = params
        self.config = config
        self.adapter = make_adapter(
            model, n_slots=config.max_slots, max_len=config.max_len,
            num_blocks=config.num_blocks, block_size=config.block_size,
        )
        self.runner = StepRunner(model, k_cap=config.k_cap)
        self.scheduler = Scheduler(
            max_slots=config.max_slots, token_budget=config.token_budget,
            prefill_chunk=config.prefill_chunk,
        )
        self._records: Dict[int, _Record] = {}
        self._next_id = 0
        S = config.max_slots
        # per-slot decode-side state (host mirrors of the jit inputs)
        self._slot_tok = np.zeros((S,), np.int32)
        self._slot_pos = np.zeros((S,), np.int32)
        self._slot_temp = np.zeros((S,), np.float32)
        self._slot_topk = np.zeros((S,), np.int32)
        self.steps = 0
        self.tokens_decoded = 0

    # -- submission -----------------------------------------------------------

    def submit(self, prompt: Sequence[int], *, max_new_tokens: int = 16,
               sampling: SamplingParams = GREEDY) -> int:
        prompt = tuple(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if not self.adapter.fits(len(prompt), max_new_tokens):
            raise ValueError(
                f"prompt_len={len(prompt)} + max_new_tokens={max_new_tokens} "
                f"exceeds max_len={self.config.max_len}"
            )
        rid = self._next_id
        self._next_id += 1
        self._records[rid] = _Record(
            prompt=prompt, max_new_tokens=max_new_tokens, sampling=sampling,
            root_key=request_key(sampling, rid),
            out=GenOutput(request_id=rid, prompt_len=len(prompt), tokens=[],
                          submit_time=time.time()),
        )
        self.scheduler.add(RequestMeta(
            request_id=rid, prompt_len=len(prompt),
            max_new_tokens=max_new_tokens,
        ))
        return rid

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def output(self, request_id: int) -> GenOutput:
        return self._records[request_id].out

    @property
    def prefix_cache_stats(self):
        return self.adapter.allocator.stats

    # -- the engine step ------------------------------------------------------

    def step(self) -> List[StreamEvent]:
        events: List[StreamEvent] = []

        for rid in self.scheduler.admit():
            rec = self._records[rid]
            meta = self.scheduler.requests[rid]
            cached = self.adapter.admit(meta.slot, rec.prompt)
            if cached:
                self.scheduler.set_prefill_pos(rid, cached)

        sched = self.scheduler.schedule()

        for w in sched.prefill:
            events.extend(self._run_prefill_chunk(w))

        if sched.decode:
            events.extend(self._run_decode(sched.decode))

        self.steps += 1
        return events

    def _run_prefill_chunk(self, w) -> List[StreamEvent]:
        rec = self._records[w.request_id]
        chunk = jnp.asarray([rec.prompt[w.start:w.end]], jnp.int32)   # (1, C)
        sub = slot_slice(self.adapter.cache, w.slot)
        start = jnp.asarray([w.start], jnp.int32)
        logits, sub = self.runner.extend(self.params, chunk, sub, start)
        self.adapter.cache = slot_write(self.adapter.cache, w.slot, sub)
        self.adapter.snapshot(w.slot, rec.prompt, w.end)
        self.scheduler.note_prefilled(w)
        if not w.last:
            return []

        # prompt complete: publish prefix blocks, sample the first token
        self.adapter.publish(w.slot, rec.prompt)
        sp = rec.sampling
        tok = self.runner.sample1(
            logits,
            token_key(rec.root_key, 0)[None],
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
        )
        t = int(tok[0])
        rec.out.first_token_time = time.time()
        self._slot_tok[w.slot] = t
        self._slot_pos[w.slot] = len(rec.prompt)
        self._slot_temp[w.slot] = sp.temperature
        self._slot_topk[w.slot] = sp.top_k
        return [self._emit(w.request_id, t)]

    def _run_decode(self, decode_ids) -> List[StreamEvent]:
        S = self.config.max_slots
        active = np.zeros((S,), bool)
        keys = np.zeros((S, 2), np.uint32)
        slot_of = {}
        for rid in decode_ids:
            meta = self.scheduler.requests[rid]
            rec = self._records[rid]
            active[meta.slot] = True
            slot_of[rid] = meta.slot
            keys[meta.slot] = np.asarray(
                token_key(rec.root_key, meta.generated)
            )
        tok_out, self.adapter.cache = self.runner.decode(
            self.params,
            jnp.asarray(self._slot_tok)[:, None],
            self.adapter.cache,
            jnp.asarray(self._slot_pos),
            jnp.asarray(active),
            jnp.asarray(keys),
            jnp.asarray(self._slot_temp),
            jnp.asarray(self._slot_topk),
        )
        tok_np = np.asarray(tok_out)
        events = []
        for rid in decode_ids:
            slot = slot_of[rid]
            t = int(tok_np[slot])
            self.scheduler.note_decoded(rid)
            self._slot_tok[slot] = t
            self._slot_pos[slot] += 1
            self.tokens_decoded += 1
            events.append(self._emit(rid, t))
        return events

    def _emit(self, rid: int, token: int) -> StreamEvent:
        rec = self._records[rid]
        rec.out.tokens.append(token)
        idx = len(rec.out.tokens) - 1
        done_len = self.scheduler.is_done(rid)
        done_eos = (self.config.eos_token is not None
                    and token == self.config.eos_token)
        if done_len or done_eos:
            meta = self.scheduler.requests[rid]
            self.adapter.release(meta.slot)
            self.scheduler.finish(rid)
            rec.out.finish_reason = "length" if done_len else "stop"
            rec.out.finish_time = time.time()
            return StreamEvent(rid, token, idx, True, rec.out.finish_reason)
        return StreamEvent(rid, token, idx, False)

    # -- convenience ----------------------------------------------------------

    def run_to_completion(self) -> List[StreamEvent]:
        events: List[StreamEvent] = []
        while self.has_work():
            events.extend(self.step())
        return events

    def generate_batch(
        self, prompts: Sequence[Sequence[int]], *, max_new_tokens: int = 16,
        sampling: SamplingParams = GREEDY,
    ) -> List[GenOutput]:
        rids = [self.submit(p, max_new_tokens=max_new_tokens, sampling=sampling)
                for p in prompts]
        self.run_to_completion()
        return [self.output(r) for r in rids]
