"""Per-request sampling: greedy by default, temperature / top-k opt-in.

Every request carries its own PRNG stream (``SamplingParams.seed``), folded
with the token index — two requests with the same seed draw identical chains
regardless of how they are batched or interleaved by the scheduler, which is
what makes sampled serving reproducible under continuous batching.

``make_sample_fn`` builds a jit-friendly batched sampler: all inputs are
arrays, so one compiled function serves every mix of greedy / sampled rows.
Greedy rows (temperature 0) are exact argmax — the deterministic-parity mode
used by the engine-vs-ServeSession tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0    # 0 => greedy argmax
    top_k: int = 0              # 0 => no restriction
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0")


GREEDY = SamplingParams()


def request_key(params: SamplingParams, request_id: int) -> jax.Array:
    """Root key for one request's sampling stream."""
    return jax.random.fold_in(jax.random.PRNGKey(params.seed), request_id)


def token_key(root: jax.Array, token_index: int) -> jax.Array:
    return jax.random.fold_in(root, token_index)


def make_sample_fn(k_cap: int = 64) -> Callable:
    """Returns ``sample(logits, keys, temperature, top_k) -> tokens``.

    logits (B, V) f32; keys (B, 2) uint32 (one PRNG key per row);
    temperature (B,) f32; top_k (B,) int32 (0 = unrestricted).

    ``k_cap`` statically bounds top-k: per-row k is clipped to
    ``min(k_cap, V)``.  Rows with temperature 0 take the argmax and never
    touch their key.
    """

    def sample(logits, keys, temperature, top_k):
        B, V = logits.shape
        lf = logits.astype(jnp.float32)
        greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)

        cap = min(k_cap, V)
        # top-k threshold per row: the k-th largest logit; k=0 disables
        topv = jax.lax.top_k(lf, cap)[0]                     # (B, cap)
        kk = jnp.clip(top_k, 1, cap)
        thresh = jnp.take_along_axis(topv, (kk - 1)[:, None], axis=-1)  # (B,1)
        restricted = jnp.where((top_k > 0)[:, None] & (lf < thresh), -jnp.inf, lf)

        temp = jnp.maximum(temperature, 1e-6)[:, None]
        sampled = jax.vmap(
            lambda key, row: jax.random.categorical(key, row)
        )(keys, restricted / temp).astype(jnp.int32)

        return jnp.where(temperature <= 0.0, greedy, sampled)

    return sample
