"""Synthetic-user load generator + latency accounting for :class:`ServeEngine`.

Closed-loop load: ``n_requests`` synthetic users all submit up-front (so the
queue depth — the number of concurrently outstanding requests — equals
``n_requests``) and the engine drains them through its slot batch.  Per-request
latency is submit→finish wall clock, which under a deep queue is dominated by
queueing: exactly the regime the p99 numbers in ``BENCH_serve.json`` are
meant to expose.

``shared_prefix_len`` > 0 gives every prompt a common prefix (a system
prompt), exercising the prefix cache under load.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.serve.engine import ServeEngine


def percentile(xs: List[float], p: float) -> float:
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs), p))


@dataclasses.dataclass
class LoadReport:
    arch: str
    family: str
    n_requests: int
    concurrency: int            # outstanding requests at peak (closed loop: all)
    prompt_len: int
    max_new_tokens: int
    wall_s: float
    requests_per_s: float
    decode_tok_s: float
    latency_p50_ms: float
    latency_p99_ms: float
    ttft_p50_ms: float
    ttft_p99_ms: float
    engine_steps: int
    prefix_hit_rate: float

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def run_load(
    engine: ServeEngine,
    *,
    n_requests: int,
    prompt_len: int = 16,
    max_new_tokens: int = 8,
    shared_prefix_len: int = 0,
    vocab: Optional[int] = None,
    seed: int = 0,
) -> LoadReport:
    vocab = vocab or engine.model.cfg.vocab
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, size=(shared_prefix_len,)).tolist()
    prompts = [
        prefix + rng.integers(
            0, vocab, size=(prompt_len - shared_prefix_len,)
        ).tolist()
        for _ in range(n_requests)
    ]

    t0 = time.time()
    rids = [engine.submit(p, max_new_tokens=max_new_tokens) for p in prompts]
    while engine.has_work():
        engine.step()
    wall = time.time() - t0

    outs = [engine.output(r) for r in rids]
    lat = [o.latency for o in outs]
    ttft = [o.ttft for o in outs]
    total_tokens = sum(len(o.tokens) for o in outs)
    stats = engine.prefix_cache_stats
    return LoadReport(
        arch=engine.model.cfg.name,
        family=engine.model.cfg.family,
        n_requests=n_requests,
        concurrency=n_requests,
        prompt_len=prompt_len,
        max_new_tokens=max_new_tokens,
        wall_s=round(wall, 3),
        requests_per_s=round(n_requests / wall, 2),
        decode_tok_s=round(total_tokens / wall, 1),
        latency_p50_ms=round(percentile(lat, 50) * 1e3, 1),
        latency_p99_ms=round(percentile(lat, 99) * 1e3, 1),
        ttft_p50_ms=round(percentile(ttft, 50) * 1e3, 1),
        ttft_p99_ms=round(percentile(ttft, 99) * 1e3, 1),
        engine_steps=engine.steps,
        prefix_hit_rate=round(stats.hit_rate, 3),
    )
