"""Per-family cache adapters: how a request's context lives in engine memory.

Two layouts behind one interface (``admit`` / ``snapshot`` / ``publish`` /
``release``):

  * :class:`PagedKVAdapter` (attention families: dense, moe) — the decode
    working set is a slot batch of contiguous KV rows, backed by a ref-counted
    page pool.  On admit, hash-chain prefix blocks that are resident in the
    pool are *gathered* into the slot (no recompute); on prompt completion the
    slot's full blocks are published back to the pool for future hits.  The
    Pallas paged kernel (``kernels.decode_attention.paged_decode_attention``)
    is the TPU-native hot path that reads the pool directly through a block
    table, eliminating the admission gather; the CPU engine uses the gathered
    working set, which is bit-identical.
  * :class:`RecurrentStateAdapter` (rwkv6, rglru) — state is O(1) per
    request, so a "block" is a *state snapshot at a block-aligned prompt
    position*.  Prefix caching stores the recurrent state every
    ``block_size`` tokens during prefill; an admit resumes from the deepest
    snapshot whose hash chain matches.  Continuous batching is free: one
    state slot per request, nothing grows with context length.

Reused blocks are the very arrays computed the first time, so a prefix-cache
hit is bit-identical to a cold prefill (tested).

Both adapters are leaf-generic over the model's cache pytree, so the int8
KV layouts (4-leaf ``{k, k_scale, v, v_scale}``, with scale columns as
ordinary ``(..., 1)`` f32 leaves) page, snapshot, and publish exactly like
native caches — no per-dtype paths here.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.serve.block_cache import BlockAllocator, hash_chain

PyTree = Any


def slot_slice(tree: PyTree, slot: int) -> PyTree:
    """Single-slot view (batch axis is 1 on every cache leaf)."""
    return jax.tree_util.tree_map(lambda a: a[:, slot:slot + 1], tree)


def slot_write(tree: PyTree, slot: int, sub: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda a, s: a.at[:, slot:slot + 1].set(s), tree, sub
    )


class PagedKVAdapter:
    """Slot-contiguous KV working set + ref-counted page pool (attention)."""

    recurrent = False

    def __init__(self, model: Model, *, n_slots: int, max_len: int,
                 num_blocks: int, block_size: int):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = model.init_cache(n_slots, max_len)
        self.allocator = BlockAllocator(num_blocks, block_size)
        # pool leaf: (layers, num_blocks, block_size, *kv_dims)
        self.pool = jax.tree_util.tree_map(
            lambda a: jnp.zeros(
                (a.shape[0], num_blocks, block_size) + a.shape[3:], a.dtype
            ),
            self.cache,
        )
        self._held: Dict[int, List[int]] = {}

    def fits(self, prompt_len: int, max_new_tokens: int) -> bool:
        return prompt_len + max_new_tokens <= self.max_len

    def admit(self, slot: int, prompt: Sequence[int]) -> int:
        """Reuse resident prefix blocks; returns tokens already in the slot."""
        bs = self.allocator.block_size
        chain = hash_chain(prompt, bs)
        n_max = (len(prompt) - 1) // bs   # >= 1 token must remain to prefill
        hits: List[int] = []
        for d in range(min(len(chain), n_max)):
            bid = self.allocator.lookup(chain[d])
            if bid is None:
                break
            hits.append(bid)
        if hits:
            idx = jnp.asarray(hits, jnp.int32)
            n = len(hits) * bs

            def gather(c, p):
                pages = p[:, idx]                        # (L, n_hit, bs, ...)
                rows = pages.reshape((p.shape[0], n) + p.shape[3:])
                return c.at[:, slot, :n].set(rows)

            self.cache = jax.tree_util.tree_map(gather, self.cache, self.pool)
        self._held[slot] = hits
        return len(hits) * bs

    def snapshot(self, slot: int, prompt: Sequence[int], pos: int) -> None:
        """No mid-prefill publishing for KV pages (rows land at completion)."""

    def publish(self, slot: int, prompt: Sequence[int]) -> None:
        """Copy the slot's full prompt blocks into the pool (best-effort)."""
        bs = self.allocator.block_size
        chain = hash_chain(prompt, bs)
        held = self._held.setdefault(slot, [])
        for d in range(len(held), len(chain)):
            bid = self.allocator.lookup(chain[d])
            if bid is None:
                bid = self.allocator.allocate(chain[d])
                if bid is None:            # pool exhausted: stop publishing
                    break

                def put(p, c):
                    rows = c[:, slot, d * bs:(d + 1) * bs]
                    return p.at[:, bid].set(rows)

                self.pool = jax.tree_util.tree_map(put, self.pool, self.cache)
            held.append(bid)

    def release(self, slot: int) -> None:
        for bid in self._held.pop(slot, []):
            self.allocator.decref(bid)


class RecurrentStateAdapter:
    """O(1)-state slots + block-aligned state-snapshot prefix cache."""

    recurrent = True

    def __init__(self, model: Model, *, n_slots: int, max_len: int,
                 num_blocks: int, block_size: int):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = model.init_cache(n_slots, max_len)
        self.allocator = BlockAllocator(num_blocks, block_size)
        self._states: Dict[int, PyTree] = {}     # block_id -> (.., 1, ..) state
        self._held: Dict[int, List[int]] = {}

    def fits(self, prompt_len: int, max_new_tokens: int) -> bool:
        return True                              # state never grows

    def admit(self, slot: int, prompt: Sequence[int]) -> int:
        """Resume from the deepest matching state snapshot, if any."""
        bs = self.allocator.block_size
        chain = hash_chain(prompt, bs)
        n_max = (len(prompt) - 1) // bs
        for d in range(min(len(chain), n_max), 0, -1):
            bid = self.allocator.lookup(chain[d - 1])
            if bid is not None:
                self.cache = slot_write(self.cache, slot, self._states[bid])
                self._held[slot] = [bid]
                return d * bs
        self._held[slot] = []
        return 0

    def snapshot(self, slot: int, prompt: Sequence[int], pos: int) -> None:
        """Publish the slot state after ``pos`` prompt tokens (block-aligned)."""
        bs = self.allocator.block_size
        if pos <= 0 or pos % bs or pos >= len(prompt):
            return
        h = hash_chain(prompt[:pos], bs)[-1]
        if self.allocator.contains(h):
            return
        bid = self.allocator.allocate(h)
        if bid is None:
            return
        self._states[bid] = slot_slice(self.cache, slot)
        self._held.setdefault(slot, []).append(bid)

    def publish(self, slot: int, prompt: Sequence[int]) -> None:
        """Snapshots happen during prefill; nothing to flush at completion."""

    def release(self, slot: int) -> None:
        for bid in self._held.pop(slot, []):
            self.allocator.decref(bid)


def make_adapter(model: Model, *, n_slots: int, max_len: int,
                 num_blocks: int, block_size: int):
    family = model.cfg.family
    if family in ("rwkv6", "rglru"):
        cls = RecurrentStateAdapter
    elif family in ("dense", "moe"):
        cls = PagedKVAdapter
    else:
        raise NotImplementedError(
            f"ServeEngine does not support family {family!r} yet "
            "(encdec/vlm decode needs side inputs; use ServeSession)"
        )
    return cls(model, n_slots=n_slots, max_len=max_len,
               num_blocks=num_blocks, block_size=block_size)
