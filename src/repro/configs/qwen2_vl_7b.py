"""qwen2-vl-7b [vlm]: 28L, d_model=3584, 28H (GQA kv=4), d_ff=18944, vocab=152064 —
M-RoPE, dynamic resolution [arXiv:2409.12191].  Vision patch frontend is a STUB:
``input_specs`` supplies precomputed (B, n_patches, 3584) patch embeddings.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    modality="vision",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
    mlp="swiglu",
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),   # freq pairs: temporal / height / width (sum=64=D/2)
    n_vision_patches=1024,         # stub patch-grid prefix (32x32)
    fsdp=True,
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab=256, mrope_sections=(4, 2, 2), n_vision_patches=4,
    fsdp=False, dtype=jnp.float32,
)
