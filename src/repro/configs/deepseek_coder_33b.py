"""deepseek-coder-33b [dense]: 62L, d_model=7168, 56H (GQA kv=8), d_ff=19200,
vocab=32256 — llama-arch [arXiv:2401.14196].
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab=32256,
    mlp="swiglu",
    rope_theta=100000.0,    # deepseek-coder long-context base
    fsdp=True,              # ZeRO-3-style weight sharding over "data"
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab=256, fsdp=False, dtype=jnp.float32,
)
