"""qwen3-moe-30b-a3b [moe]: 48L, d_model=2048, 32H (GQA kv=4), per-expert d_ff=768,
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,               # per-expert hidden
    vocab=151936,
    n_experts=128,
    experts_per_token=8,
    capacity_factor=1.25,
    mlp="swiglu",
    rope_theta=1000000.0,
    fsdp=True,
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab=256, n_experts=8, experts_per_token=2,
    fsdp=False, dtype=jnp.float32,
)
