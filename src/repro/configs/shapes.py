"""Assigned input shapes and ShapeDtypeStruct stand-ins for every model input.

The four LM shapes (seq_len x global_batch):
  train_4k     4,096 x 256   -> lowers train_step
  prefill_32k  32,768 x 32   -> lowers prefill (serve)
  decode_32k   32,768 x 128  -> lowers serve_step (1 token, KV cache of seq_len)
  long_500k    524,288 x 1   -> lowers serve_step; sub-quadratic archs only

``input_specs`` returns weak-type-correct ShapeDtypeStructs (no allocation),
shardable by the rules in :mod:`repro.distributed.sharding`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.api import get_model
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    long_context: bool = False


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1, long_context=True),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason).  long_500k needs sub-quadratic attention."""
    if shape.long_context and cfg.family not in ("rglru", "rwkv6"):
        return False, "full quadratic attention at 512k is not deployable; skipped"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step function selected by ``shape.kind``.

    train   -> {tokens, labels, loss_mask [, frames | patch_embeds]}
    prefill -> {tokens [, frames | patch_embeds]}
    decode  -> {token, pos, cache}
    """
    B, S = shape.global_batch, shape.seq_len
    model = get_model(cfg)

    if shape.kind == "train":
        batch: Dict[str, Any] = {
            "labels": SDS((B, S), jnp.int32),
            "loss_mask": SDS((B, S), jnp.float32),
        }
        if cfg.family == "encdec":
            batch["tokens"] = SDS((B, S), jnp.int32)
            batch["frames"] = SDS((B, cfg.n_frames, cfg.d_model), cfg.dtype)
        elif cfg.family == "vlm":
            n_vis = min(cfg.n_vision_patches, S // 4)
            batch["tokens"] = SDS((B, S - n_vis), jnp.int32)
            batch["patch_embeds"] = SDS((B, n_vis, cfg.d_model), cfg.dtype)
        else:
            batch["tokens"] = SDS((B, S), jnp.int32)
        return batch

    if shape.kind == "prefill":
        batch = {"tokens": SDS((B, S), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = SDS((B, cfg.n_frames, cfg.d_model), cfg.dtype)
        elif cfg.family == "vlm":
            n_vis = min(cfg.n_vision_patches, S // 4)
            batch["tokens"] = SDS((B, S - n_vis), jnp.int32)
            batch["patch_embeds"] = SDS((B, n_vis, cfg.d_model), cfg.dtype)
        return batch

    if shape.kind == "decode":
        cache = model.abstract_cache(B, S)
        return {
            "token": SDS((B, 1), jnp.int32),
            "pos": SDS((B,), jnp.int32),
            "cache": cache,
        }

    raise ValueError(shape.kind)
