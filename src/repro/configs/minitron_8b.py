"""minitron-8b [dense]: 32L, d_model=4096, 32H (GQA kv=8), d_ff=16384,
vocab=256000 — pruned nemotron, squared-ReLU MLP [arXiv:2407.14679].
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=256000,
    mlp="relu2",            # nemotron squared-ReLU
    fsdp=True,
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab=256, fsdp=False, dtype=jnp.float32,
)
