"""rwkv6-7b [ssm]: 32L, d_model=4096 (attention-free), d_ff=14336, vocab=65536 —
Finch, data-dependent decay [arXiv:2404.05892].  O(1)-state decode: runs long_500k.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="rwkv6",
    n_layers=32,
    d_model=4096,
    n_heads=64,             # = d_model / rwkv_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    rwkv_head_dim=64,
    decay_lora=64,
    norm="layernorm",
    fsdp=True,
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=192, vocab=256,
    rwkv_head_dim=16, decay_lora=8, fsdp=False, dtype=jnp.float32,
)
