"""Assigned architecture configs (exact published dims) + reduced smoke variants.

``get_config(name)`` -> full ModelConfig;  ``smoke_config(name)`` -> tiny same-family
config for CPU tests;  ``ARCHS`` lists all ten assigned ids.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCHS: List[str] = [
    "whisper-medium",
    "recurrentgemma-2b",
    "deepseek-coder-33b",
    "minitron-8b",
    "deepseek-7b",
    "qwen1.5-4b",
    "qwen2-vl-7b",
    "dbrx-132b",
    "qwen3-moe-30b-a3b",
    "rwkv6-7b",
]

_MODULES: Dict[str, str] = {
    "whisper-medium": "repro.configs.whisper_medium",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "minitron-8b": "repro.configs.minitron_8b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "qwen1.5-4b": "repro.configs.qwen15_4b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
}


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return importlib.import_module(_MODULES[name])


def get_config(name: str) -> ModelConfig:
    return _mod(name).CONFIG


def smoke_config(name: str) -> ModelConfig:
    return _mod(name).SMOKE
