"""deepseek-7b [dense]: 30L, d_model=4096, 32H (kv=32, MHA), d_ff=11008,
vocab=102400 — llama-arch [arXiv:2401.02954].
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab=102400,
    mlp="swiglu",
    fsdp=True,
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=192, vocab=256, fsdp=False, dtype=jnp.float32,
)
