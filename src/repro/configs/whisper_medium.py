"""whisper-medium [audio]: enc-dec, 24L, d_model=1024, 16H (kv=16), d_ff=4096,
vocab=51865 [arXiv:2212.04356].  Conv audio frontend is a STUB — ``input_specs``
feeds precomputed (B, 1500, 1024) frame embeddings.

vocab is padded 51865 -> 51872 (multiple of 32; /16 TP-shardable) per standard TPU
practice; labels stay < 51865.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    modality="audio",
    n_layers=24,
    n_enc_layers=24,
    n_dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51872,            # 51865 padded to /32
    n_frames=1500,
    norm="layernorm",
    mlp="gelu",
    qkv_bias=True,
    tie_embeddings=True,    # whisper ties decoder embedding and output head
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, n_enc_layers=2, n_dec_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=160, n_frames=12, dtype=jnp.float32,
)
