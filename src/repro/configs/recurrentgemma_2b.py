"""recurrentgemma-2b [hybrid]: 26L, d_model=2560, 10H (kv=1), d_ff=7680,
vocab=256000 — RG-LRU + local attention in a 1:2 pattern (R, R, A)
[arXiv:2402.19427].  Sub-quadratic: runs the long_500k shape.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="rglru",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,              # 3x multiplier, GeGLU
    vocab=256000,
    block_pattern=("R", "R", "A"),
    window=2048,            # local attention window
    lru_width=2560,
    conv_width=4,
    mlp="geglu",
    tie_embeddings=True,    # Gemma convention
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=3, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
    d_ff=192, vocab=256, window=8, lru_width=64, dtype=jnp.float32,
)
