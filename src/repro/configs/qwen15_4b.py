"""qwen1.5-4b [dense]: 40L, d_model=2560, 20H (kv=20), d_ff=6912, vocab=151936 —
QKV bias [hf:Qwen/Qwen1.5-*].
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    mlp="swiglu",
    rope_theta=1000000.0,
    fsdp=True,
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=192, vocab=256, fsdp=False, dtype=jnp.float32,
)
