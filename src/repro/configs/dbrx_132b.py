"""dbrx-132b [moe]: 40L, d_model=6144, 48H (GQA kv=8), d_ff=10752, vocab=100352,
MoE 16 experts top-4, fine-grained [hf:databricks/dbrx-base].
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab=100352,
    n_experts=16,
    experts_per_token=4,
    capacity_factor=1.25,
    mlp="swiglu",
    norm="layernorm",
    rope_theta=500000.0,
    fsdp=True,
    dtype=jnp.bfloat16,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=256, n_experts=4, experts_per_token=2,
    fsdp=False, dtype=jnp.float32,
)
