"""Multi-process cluster execution: per-worker device fleets, one global mesh.

STANNIS's rack is a *cluster*: every computational storage device trains
against the data it physically holds, and the host only ever sees
aggregates.  This module is the process-level realization of that topology:

  * :class:`ClusterCoordinator` — launches N worker PROCESSES (real
    ``subprocess`` children, each with its own jax runtime and
    ``XLA_FLAGS``-pinned device fleet), serves the gradient/barrier
    :class:`SyncServer`, and collects per-process result records.
  * :class:`WorkerRuntime` — what each worker process runs: the
    ``jax.distributed.initialize``-style handshake
    (:func:`repro.compat.distributed_initialize`), a
    :class:`~repro.launch.mesh.ClusterContext` attached to a standard
    :class:`~repro.api.Session`, a membership heartbeat, and the training
    loop with the per-host data plane: THIS process provisions only its own
    dp-groups' storage devices and ``device_put``s only its **addressable**
    slice of the plan's ``NamedSharding``s
    (:meth:`~repro.storage.meshfeed.MeshFeeder.feed_addressable`), with the
    no-cross-host-batch-bytes invariant receipted every step.

Execution strategy is ``ClusterContext.mode``:

  * ``spmd`` — the backend executes cross-process XLA programs (TPU/GPU):
    the one jitted global-mesh step consumes the globally-assembled arrays.
  * ``hostsync`` — CPU jaxlib cannot run multiprocess computations, so each
    process jits the PARTIAL gradient step over its local row slab and the
    coordinator sums contributions (deterministic order) before every
    process applies the identical update — the paper's host-aggregation,
    numerically the single-program step (dense models exactly; see
    :func:`repro.train.steps.make_partial_grad_step`).

The single-process fallback is the degenerate N=1 launch: same factory,
same session, no handshake — ``repro.compat`` keeps the code path one.

CLI (the worker entry the coordinator spawns, also usable by hand):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
    PYTHONPATH=src python -m repro.launch.cluster --worker \\
        --process-id 0 --num-processes 2 \\
        --coordinator 127.0.0.1:7801 --sync 127.0.0.1:7802 \\
        --membership-dir /tmp/members \\
        --factory repro.launch.cluster:demo_session_factory \\
        --factory-kwargs '{"steps": 6}'
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import os
import socket
import subprocess
import sys
import tempfile
import threading
import time
from multiprocessing import AuthenticationError, connection
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.topology import ClusterSpec, ProcessMap, TransportSpec
from repro.launch.transport import (  # noqa: F401  (SyncPeerLost re-exported)
    SyncPeerLost, build_wire_transport,
)

_AUTHKEY = b"repro-cluster-sync"


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _tree_add(a, b):
    import jax
    import numpy as np

    return jax.tree_util.tree_map(
        lambda x, y: np.asarray(x) + np.asarray(y), a, b
    )


# ---------------------------------------------------------------------------
# Coordinator-side sync service + worker-side client
# ---------------------------------------------------------------------------


class SyncServer:
    """The coordinator's reduction/barrier service.

    One TCP listener; every worker connects once and issues blocking
    rounds: ``allreduce`` (tree-sum of numpy pytrees, accumulated in
    process-id order so every participant receives the bit-identical
    total — replicas stay synchronized without a broadcast),
    ``allgather`` (every participant receives the pid-ordered list of all
    payloads — the compressed transport decodes and sums client-side), and
    ``barrier``.  A participant dying mid-round poisons the round: the
    survivors get :class:`SyncPeerLost` instead of a silent hang.
    """

    def __init__(self, n_processes: int, port: Optional[int] = None):
        self.n = int(n_processes)
        self.port = port or _free_port()
        # backlog must cover every worker dialing at once: the default (1)
        # drops simultaneous SYNs and the kernel's retransmission backoff
        # can stall a client past the rendezvous window on a loaded host
        self._listener = connection.Listener(
            ("127.0.0.1", self.port), authkey=_AUTHKEY,
            backlog=max(16, self.n + 4),
        )
        self._lock = threading.Condition()
        self._rounds: Dict[Tuple[str, str], Dict[str, Any]] = {}
        self._dead: set = set()
        self._stop = False
        self._threads: List[threading.Thread] = []
        self._accepter = threading.Thread(target=self._accept, daemon=True)
        self._accepter.start()

    @property
    def address(self) -> str:
        return f"127.0.0.1:{self.port}"

    def _accept(self):
        while not self._stop:
            try:
                conn = self._listener.accept()
            except (OSError, EOFError):
                return
            t = threading.Thread(
                target=self._serve_one, args=(conn,), daemon=True
            )
            t.start()
            # reap finished handlers so long runs don't accumulate them
            self._threads = [x for x in self._threads if x.is_alive()]
            self._threads.append(t)

    def _serve_one(self, conn):
        pid = None
        try:
            hello = conn.recv()
            pid = int(hello["pid"])
            conn.send({"ok": True, "n": self.n})
            while True:
                msg = conn.recv()
                op, tag = msg["op"], msg["tag"]
                if op in ("allreduce", "allgather", "barrier"):
                    result = self._join_round(
                        op, tag, pid, msg.get("payload")
                    )
                    conn.send(result)
                elif op == "put":
                    with self._lock:
                        self._rounds[("kv", tag)] = {"value": msg["payload"]}
                        self._lock.notify_all()
                    conn.send({"ok": True})
                elif op == "get":
                    # retire on read: kv is single-consumer rendezvous
                    # state, and keeping every tag alive leaks memory
                    with self._lock:
                        slot = self._rounds.pop(("kv", tag), None)
                    conn.send({"ok": True, "value":
                               None if slot is None else slot["value"]})
                else:
                    conn.send({"error": f"unknown op {op!r}"})
        except (EOFError, OSError, ConnectionError):
            pass
        finally:
            if pid is not None:
                self.mark_dead(pid)
            try:
                conn.close()
            except OSError:
                pass

    def mark_dead(self, pid: int):
        """Poison every pending round that still waits on ``pid``."""
        with self._lock:
            self._dead.add(pid)
            for key, round_ in self._rounds.items():
                if key[0] == "kv" or round_.get("done"):
                    continue
                round_["error"] = f"process {pid} lost mid-round {key}"
                round_["done"] = True
            self._lock.notify_all()

    def _join_round(self, op: str, tag: str, pid: int, payload):
        key = (op, tag)
        parts = None
        with self._lock:
            round_ = self._rounds.setdefault(key, {"got": {}, "done": False})
            round_["got"][pid] = payload
            if self._dead and not round_["done"]:
                # a reduction over PARTIAL membership is silently wrong
                # training, never a degraded mode: any round touched after
                # a death fails loudly (mid-round ones are poisoned by
                # mark_dead; this covers rounds STARTED after it)
                round_["error"] = (
                    f"process(es) {sorted(self._dead)} lost; "
                    f"round {key} cannot complete"
                )
                round_["done"] = True
                self._lock.notify_all()
            complete = (
                not round_["done"]
                and not round_.get("summing")
                and set(range(self.n)) <= set(round_["got"])
            )
            if complete:
                if op == "allreduce":
                    # the tree-sum happens OUTSIDE the lock (below): on
                    # large grad payloads it would otherwise serialize
                    # every other connection's round for its duration
                    round_["summing"] = True
                    parts = [round_["got"][p] for p in sorted(round_["got"])]
                else:
                    if op == "allgather":
                        round_["result"] = [
                            round_["got"][p] for p in sorted(round_["got"])
                        ]
                    round_["done"] = True
                    self._lock.notify_all()
        if parts is not None:
            total = parts[0]
            for part in parts[1:]:  # pid order — bit-identical everywhere
                total = _tree_add(total, part)
            with self._lock:
                round_["result"] = total
                round_["done"] = True
                self._lock.notify_all()
        with self._lock:
            while not round_["done"]:
                self._lock.wait(timeout=0.5)
            resp = (
                {"error": round_["error"]} if round_.get("error")
                else {"ok": True, "result": round_.get("result")}
            )
            # last reader retires the round (grad payloads are large)
            round_["readers"] = round_.get("readers", 0) + 1
            if round_["readers"] >= len(round_["got"]):
                self._rounds.pop(key, None)
            return resp

    def close(self):
        self._stop = True
        try:
            self._listener.close()
        except OSError:
            pass


class SyncClient:
    """Worker-side handle to the coordinator's :class:`SyncServer`.

    ``timeout`` bounds every round-trip: a coordinator that dies mid-round
    (or a round stalled on a hung peer) raises :class:`SyncPeerLost`
    instead of blocking the worker forever on a bare ``recv()``.
    """

    def __init__(self, address: str, process_id: int, *,
                 timeout: float = 120.0):
        host, port = address.rsplit(":", 1)
        self.process_id = int(process_id)
        self.timeout = float(timeout)
        self._conn = self._dial(host, int(port))
        self._lock = threading.Lock()
        self._conn.send({"pid": self.process_id})
        if not self._conn.poll(self.timeout):
            raise SyncPeerLost(
                f"coordinator never answered the handshake "
                f"within {self.timeout}s"
            )
        hello = self._conn.recv()
        if not hello.get("ok"):
            raise RuntimeError(f"sync handshake failed: {hello}")
        self.n_processes = int(hello["n"])

    def _dial(self, host: str, port: int):
        # Workers all dial at startup; on an oversubscribed host a connect
        # (or its auth challenge) can be refused or reset while the
        # coordinator's accept loop is starved, so retry under the timeout
        # instead of failing on the first attempt.
        deadline = time.monotonic() + self.timeout
        while True:
            try:
                return connection.Client((host, port), authkey=_AUTHKEY)
            except (ConnectionError, OSError, AuthenticationError) as exc:
                if time.monotonic() > deadline:
                    raise SyncPeerLost(
                        f"could not reach coordinator at {host}:{port} "
                        f"within {self.timeout}s: {exc}"
                    ) from exc
                time.sleep(0.2)

    def _request(self, op: str, tag: str, payload=None):
        with self._lock:
            try:
                self._conn.send({"op": op, "tag": tag, "payload": payload})
                if not self._conn.poll(self.timeout):
                    raise SyncPeerLost(
                        f"coordinator silent for {self.timeout}s "
                        f"(op={op!r}, tag={tag!r})"
                    )
                resp = self._conn.recv()
            except (EOFError, ConnectionError, OSError) as exc:
                raise SyncPeerLost(
                    f"coordinator connection lost (op={op!r}, "
                    f"tag={tag!r}): {exc}"
                ) from exc
        if "error" in resp:
            raise SyncPeerLost(resp["error"])
        return resp.get("result") if op != "get" else resp.get("value")

    def allreduce(self, tag: str, tree):
        """Sum ``tree`` (numpy pytree) across all live processes."""
        return self._request("allreduce", tag, tree)

    def allgather(self, tag: str, payload) -> list:
        """Collect every process's payload, ordered by process id."""
        return self._request("allgather", tag, payload)

    def barrier(self, tag: str) -> None:
        self._request("barrier", tag)

    def put(self, tag: str, value) -> None:
        self._request("put", tag, value)

    def get(self, tag: str):
        return self._request("get", tag)

    def close(self):
        try:
            self._conn.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# Worker runtime (runs INSIDE each worker process)
# ---------------------------------------------------------------------------


def _resolve_factory(spec: str) -> Callable:
    """``"module.path:function"`` -> the session factory callable."""
    mod, _, fn = spec.partition(":")
    if not fn:
        raise ValueError(
            f"factory must be 'module:function', got {spec!r}"
        )
    return getattr(importlib.import_module(mod), fn)


def _steady_steps_per_s(history, warmup: int = 2) -> float:
    """steps/s over post-warmup steps (per-step wall times from history)."""
    times = [h["step_time"] for h in history if "step_time" in h]
    if not times:
        return 0.0
    if len(times) > warmup + 1:
        times = times[warmup:]
    total = sum(times)
    return round(len(times) / total, 3) if total > 0 else 0.0


def _params_digest(params) -> str:
    """sha256 over the param leaves' bytes, leaf order = tree order."""
    import hashlib

    import jax
    import numpy as np

    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(params):
        h.update(np.ascontiguousarray(jax.device_get(leaf)).tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class WorkerRuntime:
    """One worker process's lifecycle: handshake -> session -> train.

    Drives a completely standard :class:`~repro.api.Session` — the ONLY
    cluster-specific acts are attaching the
    :class:`~repro.launch.mesh.ClusterContext` and beating the membership
    heartbeat.  Everything else (local-only custody, addressable feeding,
    hostsync compile, coordinated checkpoints) follows from the session's
    cluster mode.
    """

    process_id: int
    num_processes: int
    coordinator: str                   # jax.distributed coordinator address
    sync_address: Optional[str]        # SyncServer address (None if N == 1)
    membership_dir: Optional[str]
    factory: str
    factory_kwargs: Dict[str, Any]
    heartbeat_interval: float = 0.25
    transport: Optional[Dict[str, Any]] = None   # TransportSpec kwargs
    compile_cache_dir: Optional[str] = None

    def run(self, resume_steps: int = 2) -> Dict[str, Any]:
        from repro.compat import distributed_initialize
        from repro.launch.mesh import ClusterContext

        distributed = False
        if self.num_processes > 1:
            distributed = distributed_initialize(
                self.coordinator, self.num_processes, self.process_id
            )
            if not distributed:
                raise RuntimeError(
                    "this runtime cannot initialize jax.distributed; launch "
                    "with processes=1 (the repro.compat fallback) instead"
                )
        import jax

        if self.compile_cache_dir:
            # shared persistent XLA cache: re-launches of the same shapes
            # (CI smokes, bench sweeps, respawned workers) skip the compile
            try:
                jax.config.update(
                    "jax_compilation_cache_dir", self.compile_cache_dir
                )
            except Exception:
                pass

        tspec = TransportSpec(**(self.transport or {}))
        sync = (
            SyncClient(self.sync_address, self.process_id,
                       timeout=tspec.timeout)
            if self.sync_address and self.num_processes > 1 else None
        )
        wire = build_wire_transport(
            tspec, sync, self.process_id, self.num_processes
        )
        session = _resolve_factory(self.factory)(**self.factory_kwargs)
        ctx = ClusterContext.detect(
            self.process_id, self.num_processes, sync=sync,
            member=f"proc-{self.process_id}",
            transport=wire, transport_spec=tspec,
        )
        if self.num_processes > 1:
            session.attach_cluster(ctx)

        tp = session.tune()
        pmap = session.process_map()
        local_workers = (
            pmap.local_workers(self.process_id) if pmap
            else tp.group_workers
        )
        beat = None
        if self.membership_dir:
            from repro.api.membership import HeartbeatWriter

            beat = HeartbeatWriter(
                self.membership_dir, ctx.member or f"proc-{self.process_id}",
                local_workers, interval=self.heartbeat_interval,
            ).start()

        try:
            record = self._train(session, ctx, pmap, jax,
                                 resume_steps=resume_steps)
        finally:
            if beat is not None:
                beat.stop()
            if ctx.grad_reducer is not None:
                ctx.grad_reducer.close()      # also closes the wire
            elif wire is not None:
                wire.close()
            if sync is not None:
                sync.close()
        return record

    def _train(self, session, ctx, pmap, jax, *, resume_steps: int):
        from repro.api.events import DriftDetected

        manifest = session.place()
        plan = session.shard()
        report = session.run()

        # -- the addressable-slice invariant, receipted on the LAST feed --
        receipt = session.devices.last_receipt
        local_ids = sorted(d.id for d in jax.local_devices())
        addressable_only = (
            receipt is not None
            and set(receipt.devices) <= set(local_ids)
        )

        # -- drift re-tune must keep the compiled step (capacity pinned) --
        compiles_before = session.compile_count
        drift = session.apply(DriftDetected())
        session.compile()
        no_recompile = (
            not drift.recompiled
            and session.compile_count == compiles_before
        )

        # -- continue after the re-tune (resumes the coordinated
        #    checkpoint when one is configured: every process restores the
        #    identical state onto its plan) --
        resumed_losses: List[float] = []
        final_report = report
        if resume_steps > 0:
            report2 = session.run(
                report.params, opt_state=report.opt_state,
                steps=session.config.total_steps + resume_steps,
            )
            resumed_losses = [h["loss"] for h in report2.history]
            final_report = report2

        chunked_ok = None
        if ctx.sync is not None and ctx.mode == "hostsync":
            chunked_ok = self._check_chunked_save(session, ctx, jax)

        reducer = ctx.grad_reducer
        return {
            "process": self.process_id,
            "n_processes": self.num_processes,
            "mode": ctx.mode if session.cluster else "single",
            "global_devices": int(len(jax.devices())),
            "local_devices": len(local_ids),
            "losses": [h["loss"] for h in report.history],
            "resumed_losses": resumed_losses,
            # steady-state rate: first-call jit compiles dominate short
            # runs, so skip the warmup steps when enough history exists
            # (same convention as benchmarks/bench_step.py)
            "steps_per_s": _steady_steps_per_s(report.history),
            "steps_per_s_wall": (
                round(report.steps_run / report.wall_time, 3)
                if report.wall_time > 0 else 0.0
            ),
            # bit-identity probe: replicas must end every run with the
            # EXACT same parameters (compared across records by the rigs)
            "param_digest": _params_digest(final_report.params),
            "transport": None if reducer is None else {
                "topology": getattr(reducer.wire, "topology", "star"),
                "spec": dataclasses.asdict(reducer.spec),
                **reducer.stats.snapshot(),
            },
            "compile_count": session.compile_count,
            "drift_no_recompile": bool(no_recompile),
            "local_workers": list(
                pmap.local_workers(self.process_id) if pmap
                else session.tune().group_workers
            ),
            "remote_workers": [
                d.worker for d in manifest.devices if d.backend == "remote"
            ],
            "manifest_local": [
                d.worker for d in manifest.local_devices()
            ],
            "addressable_only": bool(addressable_only),
            "receipt": None if receipt is None else {
                "rows_local": receipt.rows_local,
                "rows_global": receipt.rows_global,
                "bytes_put": receipt.bytes_put,
                "n_puts": receipt.n_puts,
                "devices": list(receipt.devices),
                "local_fraction": receipt.local_fraction,
            },
            "data_axis": plan.data_axis,
            "global_rows": plan.global_rows,
            "chunked_save_ok": chunked_ok,
        }

    def _check_chunked_save(self, session, ctx, jax) -> bool:
        """Exercise single-writer-per-shard save on a REAL cross-process
        array: each process writes only its addressable pieces of a
        global-mesh array; the merged checkpoint restores the full thing.
        """
        import numpy as np

        from repro.checkpoint.manager import (
            finalize_process_save, restore, save_process,
        )

        plan = session.shard()
        sh = plan.batch["tokens"]
        rows = plan.global_rows
        gshape = (rows, 2)
        full = np.arange(rows * 2, dtype=np.int32).reshape(gshape)
        idx_map = sh.addressable_devices_indices_map(gshape)
        pieces = [
            jax.device_put(full[idx], dev) for dev, idx in idx_map.items()
        ]
        arr = jax.make_array_from_single_device_arrays(gshape, sh, pieces)
        directory = os.path.join(
            tempfile.gettempdir(),
            f"repro-chunked-{os.getppid()}-{rows}",
        )
        save_process(
            directory, 1, {"x": arr},
            process_index=ctx.process_id,
            num_processes=ctx.n_processes,
        )
        ctx.sync.barrier("chunked-stamp")
        if ctx.is_primary:
            finalize_process_save(
                directory, 1, num_processes=ctx.n_processes
            )
        ctx.sync.barrier("chunked-publish")
        got, _ = restore(directory, {"x": full})
        ok = bool(np.array_equal(np.asarray(got["x"]), full))
        ctx.sync.barrier("chunked-check")
        return ok


# ---------------------------------------------------------------------------
# Coordinator (runs in the launcher process)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClusterResult:
    """What a cluster run produced: one record per worker process."""

    records: List[Dict[str, Any]]
    returncodes: List[int]
    run_dir: str

    @property
    def ok(self) -> bool:
        return (
            bool(self.records)
            and all(rc == 0 for rc in self.returncodes)
            and len(self.records) == len(self.returncodes)
        )

    def record(self, process: int) -> Dict[str, Any]:
        for r in self.records:
            if r["process"] == process:
                return r
        raise KeyError(process)


class ClusterCoordinator:
    """Launch + supervise N worker processes feeding one global mesh.

    The coordinator owns the sync service, the membership directory the
    workers beat into, and the worker subprocesses themselves.  It does NOT
    hold a jax runtime of its own — model state lives only in the workers
    (the paper's host never sees gradients, only their sum passing
    through).
    """

    def __init__(
        self,
        spec: ClusterSpec,
        factory: str,
        factory_kwargs: Optional[Dict[str, Any]] = None,
        *,
        run_dir: Optional[str] = None,
    ):
        self.spec = spec
        self.factory = factory
        self.factory_kwargs = dict(factory_kwargs or {})
        self.run_dir = run_dir or tempfile.mkdtemp(prefix="repro-cluster-")
        self.membership_dir = (
            spec.membership_dir or os.path.join(self.run_dir, "members")
        )
        self.compile_cache_dir = spec.compile_cache_dir or os.path.join(
            tempfile.gettempdir(), "repro-xla-cache"
        )
        self.coordinator_port = spec.coordinator_port or _free_port()
        self._server: Optional[SyncServer] = None
        self._procs: List[subprocess.Popen] = []

    @property
    def processes(self) -> List[subprocess.Popen]:
        return list(self._procs)

    def launch(self, *, resume_steps: int = 2) -> None:
        n = self.spec.processes
        self._server = SyncServer(n, self.spec.sync_port or None)
        os.makedirs(self.membership_dir, exist_ok=True)
        src_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__)
        )))
        for pid in range(n):
            env = dict(os.environ)
            if self.spec.local_devices:
                env["XLA_FLAGS"] = (
                    f"--xla_force_host_platform_device_count="
                    f"{self.spec.local_devices}"
                )
            env["PYTHONPATH"] = os.pathsep.join(
                [src_root] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH")
                              else [])
            )
            out = open(os.path.join(self.run_dir, f"log.p{pid}.txt"), "w")
            cmd = [
                sys.executable, "-m", "repro.launch.cluster", "--worker",
                "--process-id", str(pid),
                "--num-processes", str(n),
                "--coordinator", f"127.0.0.1:{self.coordinator_port}",
                "--sync", self._server.address,
                "--membership-dir", self.membership_dir,
                "--factory", self.factory,
                "--factory-kwargs", json.dumps(self.factory_kwargs),
                "--result", os.path.join(self.run_dir, f"result.p{pid}.json"),
                "--resume-steps", str(resume_steps),
                "--heartbeat-interval", str(self.spec.heartbeat_interval),
                "--transport", json.dumps(self.spec.transport.to_dict()),
                "--compile-cache-dir", self.compile_cache_dir,
            ]
            self._procs.append(subprocess.Popen(
                cmd, env=env, stdout=out, stderr=subprocess.STDOUT,
                cwd=self.run_dir,
            ))

    def kill_worker(self, process_id: int, sig: int = 9) -> None:
        """Elastic-failure injection: hard-kill one worker process."""
        import signal as _signal

        proc = self._procs[process_id]
        proc.send_signal(sig if sig else _signal.SIGKILL)
        if self._server is not None:
            self._server.mark_dead(process_id)

    def wait(self, timeout: float = 600.0) -> ClusterResult:
        deadline = time.time() + timeout
        codes = []
        for proc in self._procs:
            left = max(1.0, deadline - time.time())
            try:
                codes.append(proc.wait(timeout=left))
            except subprocess.TimeoutExpired:
                proc.kill()
                codes.append(-9)
        records = []
        for pid in range(self.spec.processes):
            path = os.path.join(self.run_dir, f"result.p{pid}.json")
            if os.path.isfile(path):
                with open(path) as f:
                    records.append(json.load(f))
        self.close()
        return ClusterResult(
            records=records, returncodes=codes, run_dir=self.run_dir
        )

    def tail_logs(self, lines: int = 30) -> str:
        out = []
        for pid in range(self.spec.processes):
            path = os.path.join(self.run_dir, f"log.p{pid}.txt")
            if os.path.isfile(path):
                with open(path) as f:
                    body = f.read().splitlines()[-lines:]
                out.append(f"--- worker {pid} ---\n" + "\n".join(body))
        return "\n".join(out)

    def close(self) -> None:
        for proc in self._procs:
            if proc.poll() is None:
                proc.kill()
        if self._server is not None:
            self._server.close()
            self._server = None


def run_cluster(
    spec: ClusterSpec,
    factory: str,
    factory_kwargs: Optional[Dict[str, Any]] = None,
    *,
    run_dir: Optional[str] = None,
    resume_steps: int = 2,
    timeout: float = 600.0,
) -> ClusterResult:
    """Launch a cluster, wait for it, return the per-process records."""
    coord = ClusterCoordinator(
        spec, factory, factory_kwargs, run_dir=run_dir
    )
    coord.launch(resume_steps=resume_steps)
    try:
        return coord.wait(timeout=timeout)
    finally:
        coord.close()


# ---------------------------------------------------------------------------
# The stock session factory (smoke rigs, CI, tests)
# ---------------------------------------------------------------------------


def demo_session_factory(
    *,
    processes: int = 2,
    n_csds: int = 3,
    steps: int = 6,
    seq_len: int = 16,
    arch: str = "deepseek-7b",
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: Optional[int] = None,
    seed: int = 0,
):
    """The standard cluster smoke session: ``FleetSpec.demo(n_csds)`` (1 +
    n_csds dp-groups — keep ``(1 + n_csds) % processes == 0``), meshfeed
    storage, cluster mode.  Importable by name from every worker process.
    """
    from repro.api import FleetSpec, Session, SessionConfig
    from repro.configs import smoke_config
    from repro.models.api import get_model
    from repro.optim import adamw
    from repro.storage import DataConfig

    cfg = smoke_config(arch)
    spec = FleetSpec.demo(n_csds=n_csds).with_cluster(processes=processes)
    return Session(
        model=get_model(cfg),
        optimizer=adamw(),
        fleet=spec,
        data=DataConfig(vocab=cfg.vocab, seq_len=seq_len, seed=seed),
        shards=spec.shards(private_per_worker={"csd": 64}, public=4096),
        config=SessionConfig(
            total_steps=steps,
            checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every or max(1, steps // 2),
            async_checkpoint=False,
            seed=seed,
        ),
    )


# ---------------------------------------------------------------------------
# Worker CLI entry (what the coordinator spawns)
# ---------------------------------------------------------------------------


def _worker_main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(prog="repro.launch.cluster")
    ap.add_argument("--worker", action="store_true", required=True)
    ap.add_argument("--process-id", type=int, required=True)
    ap.add_argument("--num-processes", type=int, required=True)
    ap.add_argument("--coordinator", required=True)
    ap.add_argument("--sync", default=None)
    ap.add_argument("--membership-dir", default=None)
    ap.add_argument("--factory", required=True)
    ap.add_argument("--factory-kwargs", default="{}")
    ap.add_argument("--result", default=None)
    ap.add_argument("--resume-steps", type=int, default=2)
    ap.add_argument("--heartbeat-interval", type=float, default=0.25)
    ap.add_argument("--transport", default="{}")
    ap.add_argument("--compile-cache-dir", default=None)
    args = ap.parse_args(argv)

    runtime = WorkerRuntime(
        process_id=args.process_id,
        num_processes=args.num_processes,
        coordinator=args.coordinator,
        sync_address=args.sync,
        membership_dir=args.membership_dir,
        factory=args.factory,
        factory_kwargs=json.loads(args.factory_kwargs),
        heartbeat_interval=args.heartbeat_interval,
        transport=json.loads(args.transport),
        compile_cache_dir=args.compile_cache_dir,
    )
    record = runtime.run(resume_steps=args.resume_steps)
    body = json.dumps(record, indent=1)
    if args.result:
        with open(args.result + ".tmp", "w") as f:
            f.write(body)
        os.replace(args.result + ".tmp", args.result)
    print(body)
    return 0


if __name__ == "__main__":
    sys.exit(_worker_main())
