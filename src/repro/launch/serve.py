"""Serving driver: one-shot generate, engine streaming, or load generation.

Three modes over the same model + params:

  * ``oneshot``  — :class:`repro.api.ServeSession.generate` (prefill + decode
    loop, the parity oracle)
  * ``engine``   — :class:`repro.serve.ServeEngine` with streaming events
    printed as they arrive (continuous batching visible on the console)
  * ``loadgen``  — :func:`repro.serve.run_load` closed-loop synthetic users;
    prints the req/s + latency-percentile report

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --tokens 16
  PYTHONPATH=src python -m repro.launch.serve --mode engine --requests 8
  PYTHONPATH=src python -m repro.launch.serve --mode loadgen --requests 64
"""
from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.api import ServeSession
from repro.configs import ARCHS, get_config, smoke_config
from repro.models.api import get_model
from repro.serve import EngineConfig, SamplingParams, run_load


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=ARCHS)
    ap.add_argument("--mode", default="oneshot",
                    choices=("oneshot", "engine", "loadgen"))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # engine / loadgen
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="loadgen: common prompt prefix length (prefix cache)")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    cfg = get_config(args.arch) if args.full_config else smoke_config(args.arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params, _ = model.init_params(key=key)
    serve = ServeSession(model=model, params=params)
    sampling = SamplingParams(
        temperature=args.temperature, top_k=args.top_k, seed=args.seed,
    )

    if args.mode == "oneshot":
        B, P = args.batch, args.prompt_len
        prompt = jax.random.randint(key, (B, P), 0, cfg.vocab)
        out = serve.generate(prompt, max_new_tokens=args.tokens,
                             sampling=sampling)
        print(f"arch={cfg.name} batch={B} prompt={P} decoded={args.tokens}")
        print(f"decode throughput: {out.decode_tok_s:.1f} tok/s "
              f"({out.ms_per_step:.1f} ms/step)")
        print("sample token ids:", out.tokens[0].tolist())
        return 0

    max_len = args.max_len or (args.prompt_len + args.tokens + 8)
    engine = serve.engine(EngineConfig(max_slots=args.slots, max_len=max_len))

    if args.mode == "engine":
        import numpy as np
        rng = np.random.default_rng(args.seed)
        for _ in range(args.requests):
            prompt = rng.integers(0, cfg.vocab, size=(args.prompt_len,))
            engine.submit(prompt.tolist(), max_new_tokens=args.tokens,
                          sampling=sampling)
        while engine.has_work():
            for ev in engine.step():
                tag = f" [{ev.finish_reason}]" if ev.done else ""
                print(f"req={ev.request_id} #{ev.index} tok={ev.token}{tag}")
        stats = engine.prefix_cache_stats
        print(f"steps={engine.steps} decoded={engine.tokens_decoded} "
              f"prefix_hit_rate={stats.hit_rate:.3f}")
        return 0

    report = run_load(
        engine, n_requests=args.requests, prompt_len=args.prompt_len,
        max_new_tokens=args.tokens, shared_prefix_len=args.shared_prefix,
        seed=args.seed,
    )
    print(json.dumps(report.to_json(), indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
