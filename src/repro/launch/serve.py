"""Batched serving driver: prefill + decode loop with a KV cache (CPU demo).

Thin argparse front-end over :class:`repro.api.ServeSession`, which owns the
family-aware prefill/decode control flow.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --tokens 16
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.api import ServeSession
from repro.configs import ARCHS, get_config, smoke_config
from repro.models.api import get_model


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full_config else smoke_config(args.arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params, _ = model.init_params(key=key)

    B, P = args.batch, args.prompt_len
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab)

    serve = ServeSession(model=model, params=params)
    out = serve.generate(prompt, max_new_tokens=args.tokens)

    print(f"arch={cfg.name} batch={B} prompt={P} decoded={args.tokens}")
    print(f"decode throughput: {out.decode_tok_s:.1f} tok/s "
          f"({out.ms_per_step:.1f} ms/step)")
    print("sample token ids:", out.tokens[0].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
