"""Batched serving driver: prefill + decode loop with a KV cache (CPU demo).

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --tokens 16
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, smoke_config
from repro.models.api import get_model
from repro.train.steps import make_serve_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full_config else smoke_config(args.arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params, _ = model.init_params(key=key)

    B, P = args.batch, args.prompt_len
    cache_len = P + args.tokens + 1
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab)

    serve = jax.jit(make_serve_step(model))

    if cfg.family in ("rglru", "rwkv6"):
        # recurrent archs: feed the prompt token by token (O(1) state)
        cache = model.init_cache(B, cache_len)
        tok = prompt[:, 0:1]
        for t in range(P):
            pos = jnp.full((B,), t, jnp.int32)
            nxt, logits, cache = serve(params, prompt[:, t:t + 1], cache, pos)
        tok, pos0 = nxt, P
    else:
        prefill = jax.jit(lambda p, t: model.prefill(p, t, cache_len))
        logits, cache = prefill(params, prompt)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        pos0 = P

    out_tokens = [tok]
    t0 = time.time()
    for t in range(args.tokens):
        pos = jnp.full((B,), pos0 + t, jnp.int32)
        tok, logits, cache = serve(params, tok, cache, pos)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    seqs = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={P} decoded={args.tokens}")
    print(f"decode throughput: {args.tokens * B / dt:.1f} tok/s "
          f"({dt / args.tokens * 1e3:.1f} ms/step)")
    print("sample token ids:", seqs[0].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
