"""Cluster gradient transport: compression, overlap pipelining, ring topology.

``hostsync`` cluster mode reduces per-host partial gradients every step.  The
original path shipped full-f32 pytrees through the coordinator star; this
module is the production transport behind
:class:`repro.core.topology.TransportSpec`:

  * :class:`GradCodec` — per-bucket encode/decode with int8 per-chunk
    quantization (:mod:`repro.kernels.quantize`) or top-k sparsification,
    plus per-host error-feedback residuals.
  * :class:`StarTransport` / :class:`RingTransport` — the wire: either the
    coordinator's :class:`~repro.launch.cluster.SyncServer` (star) or a
    peer-to-peer allgather ring where workers listen on their own sockets
    and the coordinator is only used once, for rendezvous.
  * :class:`GradReducer` — the per-step driver: encode bucket *i*, hand it
    to a background thread (double-buffered) that gathers every peer's
    payload and decode-sums them in process-id order while bucket *i+1*
    encodes.

**The determinism invariant**: in every topology x compression combination,
the reduced value is the f32 sum, in process-id order, of the *decoded*
per-worker payloads.  Each worker encodes its own contribution exactly once
and every worker decodes the identical bytes, so all replicas apply the
bit-identical update — compression changes *what* is summed, never who
computes the sum.  (star+none short-circuits through the server-side
pid-ordered tree-sum, which is the same sequence of f32 adds.)
"""
from __future__ import annotations

import queue
import threading
import time
from multiprocessing import connection
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.topology import TransportSpec

_AUTHKEY = b"repro-cluster-sync"


class SyncPeerLost(RuntimeError):
    """A peer process died mid-round; the cluster step cannot complete."""


def _tree_add(a, b):
    import jax

    return jax.tree_util.tree_map(
        lambda x, y: np.asarray(x) + np.asarray(y), a, b
    )


# ---------------------------------------------------------------------------
# Codec
# ---------------------------------------------------------------------------


class GradCodec:
    """Encode/decode one worker's per-bucket gradient contribution.

    Lossy modes keep a per-bucket *error-feedback* residual: the difference
    between what this worker wanted to send and what its payload decodes to
    is added into the next step's contribution, so quantization bias and
    dropped top-k mass re-enter instead of accumulating as drift.  The
    residual is reset when a bucket changes size (elastic replan).
    """

    def __init__(self, spec: TransportSpec):
        self.spec = spec
        self._residual: Dict[int, np.ndarray] = {}

    def encode(self, bucket: int, vec) -> Dict[str, Any]:
        vec = np.asarray(vec, dtype=np.float32).reshape(-1)
        mode = self.spec.compression
        if mode == "none":
            return {"k": "raw", "v": vec}
        res = self._residual.get(bucket)
        if res is None or res.shape != vec.shape:
            res = np.zeros_like(vec)
        y = vec + res
        payload = (
            self._encode_int8(y) if mode == "int8" else self._encode_topk(y)
        )
        self._residual[bucket] = y - self.decode(payload)
        return payload

    def _encode_int8(self, y: np.ndarray) -> Dict[str, Any]:
        from repro.kernels.quantize import quantize_flat

        q, scale = quantize_flat(y, chunk=self.spec.chunk)
        return {
            "k": "int8", "q": np.asarray(q),
            "s": np.asarray(scale, dtype=np.float32), "n": int(y.shape[0]),
        }

    def _encode_topk(self, y: np.ndarray) -> Dict[str, Any]:
        n = int(y.shape[0])
        k = max(1, int(n * self.spec.topk_ratio))
        idx = np.argpartition(np.abs(y), n - k)[n - k:]
        idx.sort()  # deterministic order (argpartition's tail is unordered)
        return {
            "k": "topk", "i": idx.astype(np.uint32),
            "v": y[idx].astype(np.float32), "n": n,
        }

    def decode(self, payload: Dict[str, Any]) -> np.ndarray:
        kind = payload["k"]
        if kind == "raw":
            return np.asarray(payload["v"], dtype=np.float32)
        if kind == "int8":
            from repro.kernels.quantize import dequantize_flat

            return dequantize_flat(payload["q"], payload["s"], payload["n"])
        if kind == "topk":
            out = np.zeros(payload["n"], dtype=np.float32)
            out[np.asarray(payload["i"], dtype=np.int64)] = payload["v"]
            return out
        raise ValueError(f"unknown payload kind {kind!r}")

    @staticmethod
    def nbytes(payload: Dict[str, Any]) -> int:
        return sum(
            v.nbytes for v in payload.values() if isinstance(v, np.ndarray)
        )


# ---------------------------------------------------------------------------
# Wire layers
# ---------------------------------------------------------------------------


class StarTransport:
    """Coordinator-routed wire (the fallback topology).

    ``allgather`` collects every worker's blob pid-ordered through the
    :class:`~repro.launch.cluster.SyncServer`; ``allreduce_tree`` is the
    wire-cheaper server-side tree-sum used by the uncompressed path.
    """

    topology = "star"

    def __init__(self, sync):
        self.sync = sync

    def allgather(self, tag: str, blob) -> List[Any]:
        return self.sync.allgather(tag, blob)

    def allreduce_tree(self, tag: str, tree):
        return self.sync.allreduce(tag, tree)

    def close(self) -> None:
        pass


class RingTransport:
    """Peer-to-peer allgather ring; the coordinator is rendezvous only.

    Every worker owns a listener socket and publishes its address through
    the coordinator kv store once at startup; worker *p* connects to
    ``(p+1) % n`` and accepts from ``(p-1) % n``.  An allgather is ``n-1``
    lockstep hops: forward the previous hop's blob right while receiving a
    new one from the left.  Sends run on a dedicated thread — with blobs
    larger than the socket buffer a synchronous send would deadlock the
    ring (everyone blocked sending, nobody receiving).
    """

    topology = "ring"

    def __init__(
        self,
        sync,
        process_id: int,
        n_processes: int,
        *,
        timeout: float = 120.0,
    ):
        self.pid = int(process_id)
        self.n = int(n_processes)
        self.timeout = float(timeout)
        self._send_err: Optional[BaseException] = None
        self._listener = connection.Listener(
            ("127.0.0.1", 0), authkey=_AUTHKEY
        )
        sync.put(f"ring/addr/{self.pid}", list(self._listener.address))
        # accept must already be in flight when we dial: Client() blocks in
        # the auth handshake until the peer accept()s, so connect-then-accept
        # would deadlock the whole ring (everyone dialing, nobody answering)
        accept_box: Dict[str, Any] = {}
        accept_thread = self._start_accept(accept_box)
        right_addr = self._await_kv(sync, f"ring/addr/{(self.pid + 1) % self.n}")
        self._right = connection.Client(tuple(right_addr), authkey=_AUTHKEY)
        self._left = self._join_accept(accept_thread, accept_box)
        self._sendq: "queue.Queue" = queue.Queue()
        self._sender = threading.Thread(
            target=self._send_loop, daemon=True, name=f"ring-send-p{self.pid}"
        )
        self._sender.start()
        sync.barrier("ring/up")

    def _await_kv(self, sync, tag: str):
        deadline = time.monotonic() + self.timeout
        while True:
            value = sync.get(tag)
            if value is not None:
                return value
            if time.monotonic() > deadline:
                raise SyncPeerLost(
                    f"ring rendezvous: {tag} never published "
                    f"within {self.timeout}s"
                )
            time.sleep(0.02)

    def _start_accept(self, box: Dict[str, Any]) -> threading.Thread:
        def accept():
            try:
                box["conn"] = self._listener.accept()
            except BaseException as exc:  # surfaces as the timeout below
                box["err"] = exc

        t = threading.Thread(target=accept, daemon=True)
        t.start()
        return t

    def _join_accept(self, t: threading.Thread, box: Dict[str, Any]):
        t.join(self.timeout)
        if "conn" not in box:
            raise SyncPeerLost(
                f"ring: left neighbour of process {self.pid} did not "
                f"connect within {self.timeout}s ({box.get('err')})"
            )
        return box["conn"]

    def _send_loop(self):
        while True:
            item = self._sendq.get()
            if item is None:
                return
            try:
                self._right.send(item)
            except BaseException as exc:
                self._send_err = exc
                return

    def _post(self, item) -> None:
        if self._send_err is not None:
            raise SyncPeerLost(f"ring: send to right neighbour failed: "
                               f"{self._send_err}")
        self._sendq.put(item)

    def _recv(self):
        if self._send_err is not None:
            raise SyncPeerLost(f"ring: send to right neighbour failed: "
                               f"{self._send_err}")
        try:
            if not self._left.poll(self.timeout):
                raise SyncPeerLost(
                    f"ring: nothing from left neighbour of process "
                    f"{self.pid} within {self.timeout}s"
                )
            return self._left.recv()
        except (EOFError, OSError, ConnectionError) as exc:
            raise SyncPeerLost(f"ring: left neighbour hung up: {exc}") from exc

    def allgather(self, tag: str, blob) -> List[Any]:
        """All workers call this with the same ``tag`` in the same order."""
        out: List[Any] = [None] * self.n
        out[self.pid] = blob
        self._post((tag, self.pid, blob))
        for hop in range(self.n - 1):
            got_tag, origin, body = self._recv()
            if got_tag != tag:
                raise SyncPeerLost(
                    f"ring protocol skew: received round {got_tag!r} "
                    f"while gathering {tag!r}"
                )
            out[origin] = body
            if hop < self.n - 2:
                self._post((got_tag, origin, body))
        return out

    def close(self) -> None:
        # flush queued forwards before tearing down: neighbours may still
        # be mid-hop on data sitting in our send queue
        self._sendq.put(None)
        self._sender.join(timeout=5.0)
        for c in (self._right, self._left):
            try:
                c.close()
            except OSError:
                pass
        try:
            self._listener.close()
        except OSError:
            pass


def build_wire_transport(
    spec: TransportSpec, sync, process_id: int, n_processes: int
):
    """The wire layer named by ``spec.topology`` (None when single-process)."""
    if sync is None or n_processes <= 1:
        return None
    if spec.topology == "ring":
        return RingTransport(
            sync, process_id, n_processes, timeout=spec.timeout
        )
    return StarTransport(sync)


# ---------------------------------------------------------------------------
# Reducer (the per-step driver)
# ---------------------------------------------------------------------------


class TransportStats:
    """Per-worker wire accounting, reported in the cluster result record."""

    def __init__(self):
        self.steps = 0
        self.raw_bytes = 0
        self.wire_bytes = 0
        self.encode_s = 0.0
        self.wire_s = 0.0
        self.decode_s = 0.0
        self.reduce_s = 0.0

    def snapshot(self) -> Dict[str, Any]:
        steps = max(1, self.steps)
        return {
            "steps": self.steps,
            "raw_bytes_per_step": self.raw_bytes // steps,
            "wire_bytes_per_step": self.wire_bytes // steps,
            "compression_ratio": round(
                self.raw_bytes / max(1, self.wire_bytes), 2
            ),
            "encode_s_per_step": round(self.encode_s / steps, 5),
            "wire_s_per_step": round(self.wire_s / steps, 5),
            "decode_s_per_step": round(self.decode_s / steps, 5),
            "reduce_s_per_step": round(self.reduce_s / steps, 5),
        }


class _Future:
    __slots__ = ("_ev", "_val", "_exc")

    def __init__(self):
        self._ev = threading.Event()
        self._val = None
        self._exc: Optional[BaseException] = None

    def set(self, value) -> None:
        self._val = value
        self._ev.set()

    def set_exc(self, exc: BaseException) -> None:
        self._exc = exc
        self._ev.set()

    def result(self, timeout: float):
        if not self._ev.wait(timeout):
            raise SyncPeerLost(
                f"gradient reduction stalled for {timeout}s"
            )
        if self._exc is not None:
            raise self._exc
        return self._val


class GradReducer:
    """Reduce per-bucket flat gradient vectors across all workers.

    ``reduce(tag, buckets, sums)`` returns the pid-ordered f32 sum of every
    worker's decoded contribution per bucket, plus the tree-summed ``sums``
    (loss numerators/denominators, riding with bucket 0).  With
    ``spec.overlap`` the gather+decode of bucket *i* runs on a background
    thread while bucket *i+1* encodes on the caller's thread (queue bounded
    at 2 — double buffering, bounded memory).
    """

    def __init__(
        self,
        wire,
        spec: TransportSpec,
        process_id: int,
        n_processes: int,
    ):
        self.wire = wire
        self.spec = spec
        self.pid = int(process_id)
        self.n = int(n_processes)
        self.codec = GradCodec(spec)
        self.stats = TransportStats()
        self._q: Optional["queue.Queue"] = None
        if spec.overlap:
            self._q = queue.Queue(maxsize=2)
            self._worker = threading.Thread(
                target=self._drain, daemon=True,
                name=f"grad-reduce-p{self.pid}",
            )
            self._worker.start()

    # uncompressed star rounds can use the server-side tree-sum: one blob
    # up, the pid-ordered total back — same f32 add sequence, half the
    # client traffic of an allgather through the same socket
    def _server_side(self) -> bool:
        return (
            self.spec.compression == "none"
            and hasattr(self.wire, "allreduce_tree")
        )

    def _drain(self):
        while True:
            job = self._q.get()
            if job is None:
                return
            self._run_job(*job)

    def _run_job(self, tag, bucket, payload, extra, fut):
        try:
            t0 = time.perf_counter()
            if self._server_side():
                vec, sums = self.wire.allreduce_tree(
                    tag, (payload["v"], extra)
                )
                self.stats.wire_s += time.perf_counter() - t0
                fut.set((np.asarray(vec, dtype=np.float32), sums))
                return
            gathered = self.wire.allgather(tag, (payload, extra))
            self.stats.wire_s += time.perf_counter() - t0
            t0 = time.perf_counter()
            total: Optional[np.ndarray] = None
            sums_total = None
            for peer_payload, peer_extra in gathered:  # pid order
                decoded = self.codec.decode(peer_payload)
                total = decoded if total is None else total + decoded
                if peer_extra is not None:
                    sums_total = (
                        peer_extra if sums_total is None
                        else _tree_add(sums_total, peer_extra)
                    )
            self.stats.decode_s += time.perf_counter() - t0
            fut.set((total, sums_total))
        except BaseException as exc:
            fut.set_exc(exc)

    def reduce(
        self, tag: str, buckets: Sequence, sums
    ) -> Tuple[List[np.ndarray], Any]:
        t_start = time.perf_counter()
        futures: List[_Future] = []
        for b, vec in enumerate(buckets):
            t0 = time.perf_counter()
            payload = self.codec.encode(b, vec)
            self.stats.encode_s += time.perf_counter() - t0
            self.stats.raw_bytes += np.asarray(vec).nbytes
            self.stats.wire_bytes += self.codec.nbytes(payload)
            fut = _Future()
            job = (f"{tag}/b{b}", b, payload, sums if b == 0 else None, fut)
            if self._q is not None:
                self._q.put(job)
            else:
                self._run_job(*job)
            futures.append(fut)
        outs = [f.result(self.spec.timeout + 5.0) for f in futures]
        self.stats.steps += 1
        self.stats.reduce_s += time.perf_counter() - t_start
        return [o[0] for o in outs], outs[0][1]

    def close(self) -> None:
        if self._q is not None:
            self._q.put(None)
            self._worker.join(timeout=5.0)
        if self.wire is not None:
            self.wire.close()
