"""Production mesh construction.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax use.

Production target: TPU v5e pods, 16x16 = 256 chips per pod.
  single pod: ("data", "model") = (16, 16)
  multi-pod:  ("pod", "data", "model") = (2, 16, 16) = 512 chips
Stannis dp-groups live along ("pod", "data"); tensor/expert parallel along
"model".

``make_host_mesh`` is the CPU-device mesh the storage layer's
:class:`~repro.storage.meshfeed.MeshFeedDevice` backend feeds per-dp-group
batches onto (smoke tests force N host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.compat import make_mesh, multiprocess_compute_supported


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(
    *, data: int = 1, model: int = 1, axis_names: Tuple[str, ...] = ("data", "model")
) -> Mesh:
    """Small mesh over however many (CPU) devices exist — smoke tests."""
    n = len(jax.devices())
    if data < 1 or model < 1:
        raise ValueError(
            f"mesh axes must be positive, got data={data}, model={model}"
        )
    if data * model > n:
        raise ValueError(
            f"host mesh ({data} x {model}) needs {data * model} devices "
            f"but only {n} are available; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={data * model} "
            f"or shrink the mesh"
        )
    return make_mesh((data, model), axis_names)


def make_single_mesh(
    axis_names: Tuple[str, ...] = ("data", "model")
) -> Mesh:
    """Degenerate 1x1 mesh over one device.

    Host-delivery storage backends (synthetic / flash) have no feed mesh of
    their own; ``Session.shard()`` resolves the rule table against this mesh
    so the SAME sharding-explicit compile path (explicit ``in_shardings``,
    jitted sharded init) runs on a laptop CPU and a pod alike.
    """
    return make_mesh((1,) * len(axis_names), axis_names)


# ---------------------------------------------------------------------------
# Cluster (multi-process) meshes
# ---------------------------------------------------------------------------


def cluster_data_axis(
    global_rows: int, n_devices: int, n_processes: int
) -> int:
    """Largest ``data`` axis that divides ``global_rows``, fits ``n_devices``,
    and is a multiple of ``n_processes`` — so the row chunks never straddle a
    process boundary (each process's rows land only on its own devices).
    Falls back to ``n_processes`` itself (one chunk per process)."""
    if global_rows <= 0:
        return n_processes
    for d in range(min(n_devices, global_rows), n_processes - 1, -1):
        if d % n_processes == 0 and global_rows % d == 0:
            return d
    return n_processes


def pick_cluster_devices(devices, data: int, model: int, n_processes: int):
    """An EQUAL share of ``data * model`` devices from every process.

    Taking the first ``data * model`` of the process-major order would be
    wrong whenever the data axis is smaller than the global device count:
    early processes would contribute extra devices and their addressable
    chunks would spill past their custody row slab.  Each process must
    contribute exactly ``data * model / n_processes`` devices (in id
    order) so chunk ownership and row custody coincide.
    """
    need = data * model
    if need % n_processes:
        raise ValueError(
            f"cluster mesh ({data} x {model}) does not split over "
            f"{n_processes} processes"
        )
    share = need // n_processes
    by_proc: dict = {}
    for d in sorted(devices, key=lambda d: (d.process_index, d.id)):
        by_proc.setdefault(d.process_index, []).append(d)
    if len(by_proc) != n_processes:
        raise ValueError(
            f"global device view spans {len(by_proc)} processes, "
            f"expected {n_processes}"
        )
    picked = []
    for p in sorted(by_proc):
        if len(by_proc[p]) < share:
            raise ValueError(
                f"process {p} has {len(by_proc[p])} devices but the mesh "
                f"needs {share} from each process"
            )
        picked.extend(by_proc[p][:share])
    return picked


def make_cluster_mesh(
    *,
    data: int,
    model: int = 1,
    n_processes: int = 1,
    axis_names: Tuple[str, ...] = ("data", "model"),
) -> Mesh:
    """The GLOBAL mesh of a multi-process cluster.

    Spans every process's devices (``jax.devices()``), process-major with
    an EQUAL device share per process (see :func:`pick_cluster_devices`),
    so the ``data`` axis's contiguous row chunks align with process
    ownership: process ``p``'s addressable devices cover exactly the row
    slab ``[p*R/P, (p+1)*R/P)``.  Built the same way in EVERY process —
    the mesh is the shared contract, each process only ever ``device_put``s
    to its addressable slice of it.
    """
    import numpy as np

    devs = pick_cluster_devices(jax.devices(), data, model, n_processes)
    grid = np.array(devs).reshape(data, model)
    return Mesh(grid, axis_names)


@dataclasses.dataclass
class ClusterContext:
    """This process's identity inside a multi-process cluster.

    Built by :class:`repro.launch.cluster.WorkerRuntime` after the
    ``jax.distributed`` handshake and attached to a ``Session``
    (:meth:`~repro.api.session.Session.attach_cluster`).  ``mode`` selects
    the execution strategy:

      * ``"spmd"``     — jit computations may span processes (TPU/GPU):
        the global-mesh step consumes globally-sharded arrays directly.
      * ``"hostsync"`` — the backend cannot execute cross-process programs
        (CPU jaxlib): each process computes partial gradients on a LOCAL
        mesh over its addressable devices and sums them through the
        coordinator (the paper's host-aggregation topology).  Numerically
        identical to the global step for dense models (the masked loss is a
        ratio of across-process sums).

    ``sync`` is the coordinator control-plane client (duck-typed:
    ``allreduce(tag, tree) -> tree`` and ``barrier(tag)``); ``None`` for a
    single-process compat fallback.  ``transport`` is the gradient wire
    (:func:`repro.launch.transport.build_wire_transport` — star or ring)
    configured by ``transport_spec``; the session's hostsync compile wraps
    it in a :class:`~repro.launch.transport.GradReducer` cached here as
    ``grad_reducer`` so error-feedback residuals survive recompiles.
    """

    process_id: int
    n_processes: int
    mode: str = "hostsync"                 # "spmd" | "hostsync"
    sync: Any = None
    member: Optional[str] = None           # membership id (heartbeat name)
    transport: Any = None                  # wire layer (star/ring), or None
    transport_spec: Any = None             # TransportSpec, or None
    grad_reducer: Any = None               # GradReducer cache (set at compile)

    def __post_init__(self):
        if self.mode not in ("spmd", "hostsync"):
            raise ValueError(f"unknown cluster mode {self.mode!r}")

    @classmethod
    def detect(cls, process_id: int, n_processes: int, sync=None,
               member: Optional[str] = None, transport=None,
               transport_spec=None) -> "ClusterContext":
        mode = "spmd" if multiprocess_compute_supported() else "hostsync"
        return cls(process_id=process_id, n_processes=n_processes,
                   mode=mode, sync=sync, member=member,
                   transport=transport, transport_spec=transport_spec)

    @property
    def is_primary(self) -> bool:
        return self.process_id == 0

    def global_mesh(self, global_rows: int) -> Mesh:
        d = cluster_data_axis(
            global_rows, len(jax.devices()), self.n_processes
        )
        return make_cluster_mesh(
            data=d, model=1, n_processes=self.n_processes
        )

    def local_mesh(self, local_rows: int, data_axis: Optional[int] = None) -> Mesh:
        """Mesh over THIS process's devices (the hostsync compute mesh).

        ``data_axis`` pins the chunk count — pass the per-process share of
        the global mesh's ``data`` axis so the local index map tiles rows
        with EXACTLY the pieces the global feed placed (the zero-extra-copy
        local view in :meth:`MeshFeeder.feed_addressable`)."""
        import numpy as np

        devs = sorted(jax.local_devices(), key=lambda d: d.id)
        d = data_axis
        if d is None:
            d = 1
            for cand in range(min(len(devs), max(1, local_rows)), 0, -1):
                if local_rows % cand == 0:
                    d = cand
                    break
        if d > len(devs) or (local_rows and local_rows % d):
            raise ValueError(
                f"local mesh data axis {d} invalid for {local_rows} rows "
                f"on {len(devs)} local devices"
            )
        grid = np.array(devs[:d]).reshape(d, 1)
        return Mesh(grid, ("data", "model"))


# Hardware constants (TPU v5e-class) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (effective)
HBM_BYTES = 16 * 1024 ** 3      # per chip
