"""Production mesh construction.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax use.

Production target: TPU v5e pods, 16x16 = 256 chips per pod.
  single pod: ("data", "model") = (16, 16)
  multi-pod:  ("pod", "data", "model") = (2, 16, 16) = 512 chips
Stannis dp-groups live along ("pod", "data"); tensor/expert parallel along
"model".
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_host_mesh(
    *, data: int = 1, model: int = 1, axis_names: Tuple[str, ...] = ("data", "model")
) -> Mesh:
    """Small mesh over however many (CPU) devices exist — smoke tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh(
        (data, model), axis_names, axis_types=(AxisType.Auto,) * 2
    )


# Hardware constants (TPU v5e-class) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (effective)
HBM_BYTES = 16 * 1024 ** 3      # per chip
