"""Production mesh construction.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax use.

Production target: TPU v5e pods, 16x16 = 256 chips per pod.
  single pod: ("data", "model") = (16, 16)
  multi-pod:  ("pod", "data", "model") = (2, 16, 16) = 512 chips
Stannis dp-groups live along ("pod", "data"); tensor/expert parallel along
"model".

``make_host_mesh`` is the CPU-device mesh the storage layer's
:class:`~repro.storage.meshfeed.MeshFeedDevice` backend feeds per-dp-group
batches onto (smoke tests force N host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(
    *, data: int = 1, model: int = 1, axis_names: Tuple[str, ...] = ("data", "model")
) -> Mesh:
    """Small mesh over however many (CPU) devices exist — smoke tests."""
    n = len(jax.devices())
    if data < 1 or model < 1:
        raise ValueError(
            f"mesh axes must be positive, got data={data}, model={model}"
        )
    if data * model > n:
        raise ValueError(
            f"host mesh ({data} x {model}) needs {data * model} devices "
            f"but only {n} are available; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={data * model} "
            f"or shrink the mesh"
        )
    return make_mesh((data, model), axis_names)


def make_single_mesh(
    axis_names: Tuple[str, ...] = ("data", "model")
) -> Mesh:
    """Degenerate 1x1 mesh over one device.

    Host-delivery storage backends (synthetic / flash) have no feed mesh of
    their own; ``Session.shard()`` resolves the rule table against this mesh
    so the SAME sharding-explicit compile path (explicit ``in_shardings``,
    jitted sharded init) runs on a laptop CPU and a pod alike.
    """
    return make_mesh((1,) * len(axis_names), axis_names)


# Hardware constants (TPU v5e-class) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (effective)
HBM_BYTES = 16 * 1024 ** 3      # per chip
