"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * 512 placeholder CPU devices host the production meshes
    (16, 16) = one pod and (2, 16, 16) = two pods.
  * Params/optimizer/caches are ShapeDtypeStructs — nothing is allocated.
  * For each cell we ``jit(step).lower(...).compile()`` and record
    memory_analysis (fits?), cost_analysis (FLOPs/bytes), and the collective
    bytes parsed from the HLO — the roofline inputs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells, single-pod
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod        # 2-pod mesh
  PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --out results.json
"""
from __future__ import annotations

import os

# MUST run before any jax import: jax locks the device count on first init.
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, ShapeSpec, applicable, input_specs
from repro.distributed.sharding import (
    arg_shardings_for_tree, make_rules, set_rules, specs_for_tree,
)
from repro.launch.mesh import make_production_mesh
from repro.models.api import get_model
from repro.optim import adamw
from repro.roofline.collectives import collective_bytes_from_hlo
from repro.train.steps import (
    abstract_opt_state, make_serve_step, make_train_step,
)

SDS = jax.ShapeDtypeStruct


def _batch_axes(batch: Dict[str, Any]) -> Dict[str, Any]:
    ax = {}
    for k, v in batch.items():
        if k in ("tokens", "labels", "loss_mask"):
            ax[k] = ("batch", "seq_data")      # batch over (pod, data)
        elif k in ("frames", "patch_embeds"):
            ax[k] = ("batch", None, "act_embed")
        elif k == "token":
            ax[k] = ("batch", None)
        elif k == "pos":
            ax[k] = ("batch",)
        else:
            raise KeyError(k)
    return ax


def _cycle_len(cfg) -> int:
    """Layers per repeating pattern cycle (cost-calibration unit)."""
    if cfg.family == "rglru":
        return len(cfg.block_pattern or ("R", "R", "A"))
    return 1


def _with_layers(cfg, n: int):
    """Full-dims config with ``n`` layers, UNROLLED (exact cost_analysis)."""
    kw = dict(n_layers=n, scan_layers=False)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=n, n_dec_layers=n)
    return cfg.with_(**kw)


def dryrun_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    rules_overrides: Optional[Dict[str, Any]] = None,
    verbose: bool = True,
    calibrate: bool = True,
    zero1: bool = False,
) -> Dict[str, Any]:
    """Lower + compile one cell; returns the roofline record.

    Two-phase costing: the FULL config (scan-over-layers) proves
    shardability + memory; because XLA's cost_analysis counts a scan body
    once, FLOPs/bytes/collectives come from a two-point calibration —
    unrolled 1-cycle and 2-cycle variants at full dims, extrapolated
    linearly to the real depth (exact: unrolled HLO cost is affine in depth).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "why": why}

    mesh = make_production_mesh(multi_pod=multi_pod)

    seq_shard = bool(shape.long_context)
    # zero1: params stay UN-sharded over data (no per-layer ZeRO-3 gathers);
    # only the optimizer state shards over data — GSPMD then emits a single
    # grads-reduce-scatter + params-all-gather around the update, once per
    # step instead of 2 gathers + 1 scatter per LAYER.
    rules = make_rules(fsdp=cfg.fsdp and not zero1, seq_shard=seq_shard,
                       extra=(rules_overrides or None))
    # token batch rows shard over every dp-ish axis; seq_data is the token/seq
    # dim of the *batch* (sharded only for SP long-context)
    rules.setdefault("seq_data", "data" if seq_shard else None)
    set_rules(rules)
    opt_rules = (
        make_rules(fsdp=True, seq_shard=seq_shard, extra=(rules_overrides or None))
        if zero1 else None
    )
    if opt_rules is not None:
        opt_rules.setdefault("seq_data", "data" if seq_shard else None)
        # opt state must not inherit a batch-over-model override
        opt_rules["batch"] = ("pod", "data")

    t0 = time.time()
    compiled = _lower_and_compile(cfg, shape, mesh, rules, opt_rules=opt_rules)
    elapsed = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes_from_hlo(compiled.as_text())
    n_dev = mesh.devices.size

    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    calibration = None
    if calibrate:
        # XLA cost_analysis counts scan bodies ONCE -> calibrate with
        # unrolled 1-cycle / 2-cycle variants at full dims and extrapolate.
        c = _cycle_len(cfg)
        layers = cfg.n_enc_layers if cfg.family == "encdec" else cfg.n_layers
        cyc = layers // c
        c1 = _lower_and_compile(_with_layers(cfg, c), shape, mesh, rules,
                                opt_rules=opt_rules)
        c2 = _lower_and_compile(_with_layers(cfg, 2 * c), shape, mesh, rules,
                                opt_rules=opt_rules)
        f1 = float(c1.cost_analysis().get("flops", 0.0))
        f2 = float(c2.cost_analysis().get("flops", 0.0))
        b1 = float(c1.cost_analysis().get("bytes accessed", 0.0))
        b2 = float(c2.cost_analysis().get("bytes accessed", 0.0))
        k1 = collective_bytes_from_hlo(c1.as_text())
        k2 = collective_bytes_from_hlo(c2.as_text())
        flops = f1 + (cyc - 1) * (f2 - f1)
        hbm = b1 + (cyc - 1) * (b2 - b1)
        kinds = set(k1) | set(k2)
        coll = {
            k: int(k1.get(k, 0) + (cyc - 1) * (k2.get(k, 0) - k1.get(k, 0)))
            for k in kinds
        }
        coll = {k: max(0, v) for k, v in coll.items()}
        calibration = {
            "cycle_layers": c, "cycles": cyc,
            "flops_1": f1, "flops_2": f2, "bytes_1": b1, "bytes_2": b2,
        }

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi_pod" if multi_pod else "single_pod",
        "status": "ok",
        "n_devices": int(n_dev),
        "compile_s": round(elapsed, 1),
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": coll,
        "memory": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        },
        "params": int(cfg.param_count()),
        "active_params": int(cfg.param_count(active_only=True)),
        "calibration": calibration,
    }
    if verbose:
        per_dev = (rec["memory"].get("argument_size_in_bytes", 0)
                   + rec["memory"].get("temp_size_in_bytes", 0)) / n_dev
        print(
            f"[{rec['mesh']}] {arch} x {shape_name}: OK "
            f"({elapsed:.0f}s compile, {rec['flops']:.3e} flops, "
            f"coll {sum(coll.values()):.3e} B, ~{per_dev/2**30:.2f} GiB/dev)"
        )
    return rec


def _lower_and_compile(cfg, shape, mesh, rules, opt_rules=None):
    """Lower + compile the step function for (cfg, shape) under (mesh, rules).

    ``opt_rules``: separate rule table for the optimizer state (ZeRO-1)."""
    model = get_model(cfg)
    params, p_axes = model.init_params(abstract=True)
    p_shardings = arg_shardings_for_tree(p_axes, params, rules, mesh)
    batch = input_specs(cfg, shape)

    from repro.compat import set_mesh

    with set_mesh(mesh):
        if shape.kind == "train":
            opt = adamw()
            step = make_train_step(model, opt, lambda s: jnp.float32(1e-3))
            from repro.optim.optimizers import OptState

            opt_state = abstract_opt_state(opt, params)
            m_shardings = (
                arg_shardings_for_tree(p_axes, params, opt_rules, mesh)
                if opt_rules is not None else p_shardings
            )
            o_shardings = OptState(
                step=NamedSharding(mesh, P()),
                mu=m_shardings,
                nu=m_shardings,
            )
            b_axes = _batch_axes(batch)
            b_shardings = arg_shardings_for_tree(b_axes, batch, rules, mesh)
            lowered = jax.jit(
                step,
                in_shardings=(p_shardings, o_shardings, b_shardings),
                donate_argnums=(0, 1),
            ).lower(params, opt_state, batch)
        elif shape.kind == "prefill":
            def prefill_step(params, batch):
                kwargs = {k: v for k, v in batch.items() if k != "tokens"}
                return model.prefill(params, batch["tokens"], shape.seq_len, **kwargs)

            b_axes = _batch_axes(batch)
            b_shardings = arg_shardings_for_tree(b_axes, batch, rules, mesh)
            lowered = jax.jit(
                prefill_step, in_shardings=(p_shardings, b_shardings)
            ).lower(params, batch)
        else:  # decode
            serve = make_serve_step(model)
            cache = batch["cache"]
            c_axes = model.cache_logical_axes()
            c_shardings = arg_shardings_for_tree(c_axes, cache, rules, mesh)
            tok_sh = arg_shardings_for_tree(
                {"token": ("batch", None), "pos": ("batch",)},
                {"token": batch["token"], "pos": batch["pos"]}, rules, mesh,
            )
            lowered = jax.jit(
                serve,
                in_shardings=(
                    p_shardings, tok_sh["token"], c_shardings, tok_sh["pos"]
                ),
                donate_argnums=(2,),
            ).lower(params, batch["token"], cache, batch["pos"])

        return lowered.compile()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON results here")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results, failures = [], 0
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    rec = dryrun_cell(arch, shape, multi_pod=multi)
                except Exception as e:
                    failures += 1
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "multi_pod" if multi else "single_pod",
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                    }
                    print(f"[{'multi' if multi else 'single'}] {arch} x {shape}: "
                          f"FAIL {type(e).__name__}: {str(e)[:200]}")
                    traceback.print_exc(limit=3)
                results.append(rec)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {len(results)} records to {args.out}")
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    print(f"dry-run: {n_ok} ok, {n_skip} skipped, {failures} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
