"""End-to-end training driver (CPU-runnable; production flags mirror the pods).

Runs the full Stannis pipeline on a reduced config: Algorithm-1 tune (analytic
or measured), Eq.-1 epoch plan, privacy placement, then real training steps
with checkpointing — the same code path the pods run, sized for this host.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \\
      --steps 20 --csds 4 --measured-tune
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, smoke_config
from repro.core.privacy import Shard
from repro.core.topology import Fleet, WorkerClass
from repro.core.tuner import measured_benchmark
from repro.data.pipeline import DataConfig
from repro.models.api import get_model
from repro.optim import adamw, sgd_momentum
from repro.train.trainer import Trainer, TrainerConfig


def make_demo_fleet(n_csds: int, host_tput: float = 80.0, csd_tput: float = 10.0) -> Fleet:
    """Paper-shaped fleet (1 host + N CSD-class workers), laptop-scaled."""
    host = WorkerClass(
        name="host", count=1, peak_throughput=host_tput, saturation_batch=8,
        max_batch=64, active_power=407.0, idle_power=100.0, link_bandwidth=8.0,
    )
    csd = WorkerClass(
        name="csd", count=n_csds, peak_throughput=csd_tput, saturation_batch=2,
        max_batch=8, active_power=7.0, idle_power=1.5, link_bandwidth=2.0,
    )
    return Fleet(classes=(host, csd))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=ARCHS)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--csds", type=int, default=2)
    ap.add_argument("--full-config", action="store_true",
                    help="use the published dims (default: reduced smoke dims)")
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--measured-tune", action="store_true",
                    help="tune with real step timings instead of the analytic model")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full_config else smoke_config(args.arch)
    model = get_model(cfg)
    fleet = make_demo_fleet(args.csds)

    shards = [
        Shard(f"private-csd/{i}", 256, True, f"csd/{i}") for i in range(args.csds)
    ] + [Shard("public", 65536, False)]

    benchmark = None
    if args.measured_tune:
        # time the real jitted step at each candidate batch; throughput ratios
        # between classes come from the analytic fleet (single-host stand-in)
        params, _ = model.init_params(key=jax.random.PRNGKey(args.seed))
        opt = adamw() if args.optimizer == "adamw" else sgd_momentum()
        from repro.train.steps import make_train_step

        step = jax.jit(make_train_step(model, opt, lambda s: 1e-3))

        def run_at(batch: int):
            toks = jnp.zeros((batch, args.seq), jnp.int32)
            b = {
                "tokens": toks, "labels": toks,
                "loss_mask": jnp.ones((batch, args.seq), jnp.float32),
            }
            st = opt.init(params)
            out = step(params, st, b)
            jax.block_until_ready(out[2]["loss"])

        bench_core = measured_benchmark({"host": run_at, "csd": run_at})

        def benchmark(name: str, batch: int) -> float:
            t = bench_core("host", batch)
            # model CSD-class slowness relative to the host measurement
            rel = fleet.by_name("host").peak_throughput / fleet.by_name(name).peak_throughput
            return t * rel

    trainer = Trainer(
        model=model,
        optimizer=adamw() if args.optimizer == "adamw" else sgd_momentum(),
        fleet=fleet,
        data_cfg=DataConfig(vocab=cfg.vocab, seq_len=args.seq, seed=args.seed),
        cfg=TrainerConfig(
            total_steps=args.steps,
            checkpoint_dir=args.checkpoint_dir,
            seed=args.seed,
        ),
        shards=shards,
        benchmark=benchmark,
    ).setup()

    print(f"arch={cfg.name} params={cfg.param_count():,}")
    print(f"tuned batches: {trainer.tune_result.batches} "
          f"(margin {trainer.tune_result.margin:.0%}, "
          f"ref={trainer.tune_result.reference_class})")
    print(f"schedule: groups={trainer.schedule.group_batches} "
          f"pad={trainer.schedule.pad_fraction:.1%}")
    print(f"epoch: {trainer.plan.steps_per_epoch} steps, "
          f"imbalance {trainer.plan.imbalance_steps()} steps")

    t0 = time.time()
    params, hist = trainer.train(
        on_metrics=lambda i, m: print(
            f"  step {i:4d} loss {m['loss']:.4f} lr {m['lr']:.2e} "
            f"gnorm {m['grad_norm']:.2f} ({m['step_time']*1e3:.0f} ms)"
        ) if i % 5 == 0 else None
    )
    dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.1f}s; "
          f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
