"""End-to-end training driver (CPU-runnable; production flags mirror the pods).

Runs the full Stannis pipeline through the staged Session API: Algorithm-1
tune (analytic or measured), Eq.-1 epoch plan, privacy placement, then real
training steps with checkpointing — the same code path the pods run, sized
for this host.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \\
      --steps 20 --csds 4 --measured-tune
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from repro.api import FleetSpec, Session, SessionConfig
from repro.configs import ARCHS, get_config, smoke_config
from repro.core.tuner import measured_benchmark
from repro.storage import DataConfig
from repro.models.api import get_model
from repro.optim import adamw, sgd_momentum


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=ARCHS)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--csds", type=int, default=2)
    ap.add_argument("--full-config", action="store_true",
                    help="use the published dims (default: reduced smoke dims)")
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--measured-tune", action="store_true",
                    help="tune with real step timings instead of the analytic model")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full_config else smoke_config(args.arch)
    model = get_model(cfg)
    spec = FleetSpec.demo(
        args.csds, host_tput=80.0, csd_tput=10.0,
        host_max_batch=64, csd_max_batch=8,
        host_idle=100.0, csd_idle=1.5,
    )
    fleet = spec.build()
    shards = spec.shards(private_per_worker={"csd": 256}, public=65536)

    benchmark = None
    if args.measured_tune:
        # time the real jitted step at each candidate batch; throughput ratios
        # between classes come from the analytic fleet (single-host stand-in)
        params, _ = model.init_params(key=jax.random.PRNGKey(args.seed))
        opt = adamw() if args.optimizer == "adamw" else sgd_momentum()
        from repro.train.steps import make_train_step

        step = jax.jit(make_train_step(model, opt, lambda s: 1e-3))

        def run_at(batch: int):
            toks = jnp.zeros((batch, args.seq), jnp.int32)
            b = {
                "tokens": toks, "labels": toks,
                "loss_mask": jnp.ones((batch, args.seq), jnp.float32),
            }
            st = opt.init(params)
            out = step(params, st, b)
            jax.block_until_ready(out[2]["loss"])

        bench_core = measured_benchmark({"host": run_at, "csd": run_at})

        def benchmark(name: str, batch: int) -> float:
            t = bench_core("host", batch)
            # model CSD-class slowness relative to the host measurement
            rel = fleet.by_name("host").peak_throughput / fleet.by_name(name).peak_throughput
            return t * rel

    session = Session(
        model=model,
        optimizer=adamw() if args.optimizer == "adamw" else sgd_momentum(),
        fleet=fleet,
        data=DataConfig(vocab=cfg.vocab, seq_len=args.seq, seed=args.seed),
        config=SessionConfig(
            total_steps=args.steps,
            checkpoint_dir=args.checkpoint_dir,
            seed=args.seed,
        ),
        shards=shards,
        benchmark=benchmark,
    )

    tune_plan = session.tune()
    epoch = session.plan()
    shard_plan = session.shard()
    print(f"arch={cfg.name} params={cfg.param_count():,}")
    print(f"tuned batches: {tune_plan.batches} "
          f"(margin {tune_plan.result.margin:.0%}, "
          f"ref={tune_plan.result.reference_class})")
    print(f"schedule: groups={tune_plan.schedule.group_batches} "
          f"pad={tune_plan.schedule.pad_fraction:.1%}")
    print(f"epoch: {epoch.steps_per_epoch} steps, "
          f"imbalance {epoch.imbalance_steps()} steps")
    print(f"sharding: {shard_plan.describe()} "
          f"batch={shard_plan.batch['tokens'].spec}")

    session.callbacks.on_step(
        lambda i, m: print(
            f"  step {i:4d} loss {m['loss']:.4f} lr {m['lr']:.2e} "
            f"gnorm {m['grad_norm']:.2f} ({m['step_time']*1e3:.0f} ms)"
        ) if i % 5 == 0 else None
    )
    report = session.run()
    hist = report.history
    print(f"{report.steps_run} steps in {report.wall_time:.1f}s; "
          f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
