"""End-to-end training driver (CPU-runnable; production flags mirror the pods).

Runs the full Stannis pipeline through the staged Session API: Algorithm-1
tune (analytic or measured), Eq.-1 epoch plan, privacy placement, then real
training steps with checkpointing — the same code path the pods run, sized
for this host.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \\
      --steps 20 --csds 4 --measured-tune

Cluster mode launches N worker PROCESSES feeding one global mesh (see
:mod:`repro.launch.cluster`); each provisions only its own dp-groups'
storage devices and feeds only its addressable mesh slice:

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \\
      --steps 20 --csds 3 --cluster-processes 2 --cluster-local-devices 4
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from repro.api import FleetSpec, Session, SessionConfig
from repro.configs import ARCHS, get_config, smoke_config
from repro.core.tuner import measured_benchmark
from repro.storage import DataConfig
from repro.models.api import get_model
from repro.optim import adamw, sgd_momentum


def train_session_factory(
    *,
    arch: str = "deepseek-7b",
    steps: int = 30,
    seq: int = 64,
    csds: int = 2,
    full_config: bool = False,
    optimizer: str = "adamw",
    checkpoint_dir=None,
    seed: int = 0,
    cluster_processes: int = 1,
) -> Session:
    """The driver's session, importable by name from cluster workers."""
    cfg = get_config(arch) if full_config else smoke_config(arch)
    spec = FleetSpec.demo(
        csds, host_tput=80.0, csd_tput=10.0,
        host_max_batch=64, csd_max_batch=8,
        host_idle=100.0, csd_idle=1.5,
    )
    if cluster_processes > 1:
        spec = spec.with_cluster(processes=cluster_processes)
    return Session(
        model=get_model(cfg),
        optimizer=adamw() if optimizer == "adamw" else sgd_momentum(),
        fleet=spec,
        data=DataConfig(vocab=cfg.vocab, seq_len=seq, seed=seed),
        config=SessionConfig(
            total_steps=steps,
            checkpoint_dir=checkpoint_dir,
            seed=seed,
        ),
        shards=spec.shards(private_per_worker={"csd": 256}, public=65536),
    )


def _run_cluster(args) -> int:
    from repro.core.topology import ClusterSpec
    from repro.launch.cluster import run_cluster

    result = run_cluster(
        ClusterSpec(
            processes=args.cluster_processes,
            local_devices=args.cluster_local_devices,
        ),
        "repro.launch.train:train_session_factory",
        {
            "arch": args.arch, "steps": args.steps, "seq": args.seq,
            "csds": args.csds, "full_config": args.full_config,
            "optimizer": args.optimizer,
            "checkpoint_dir": args.checkpoint_dir, "seed": args.seed,
            "cluster_processes": args.cluster_processes,
        },
    )
    for rec in result.records:
        print(
            f"[proc {rec['process']}/{rec['n_processes']} {rec['mode']}] "
            f"workers={rec['local_workers']} "
            f"devices={rec['receipt']['devices'] if rec['receipt'] else '-'} "
            f"local_rows={rec['receipt']['rows_local'] if rec['receipt'] else '-'}"
            f"/{rec['global_rows']} compiles={rec['compile_count']}"
        )
        if rec["losses"]:
            print(f"  loss {rec['losses'][0]:.4f} -> {rec['losses'][-1]:.4f} "
                  f"addressable_only={rec['addressable_only']}")
    if not result.ok:
        print(f"cluster failed: returncodes={result.returncodes} "
              f"(worker logs under {result.run_dir})", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=ARCHS)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--csds", type=int, default=2)
    ap.add_argument("--full-config", action="store_true",
                    help="use the published dims (default: reduced smoke dims)")
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--measured-tune", action="store_true",
                    help="tune with real step timings instead of the analytic model")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cluster-processes", type=int, default=1,
                    help="launch N worker processes feeding one global mesh")
    ap.add_argument("--cluster-local-devices", type=int, default=0,
                    help="force this many (fake CPU) devices per process")
    args = ap.parse_args(argv)

    if args.cluster_processes > 1:
        return _run_cluster(args)

    cfg = get_config(args.arch) if args.full_config else smoke_config(args.arch)
    model = get_model(cfg)
    spec = FleetSpec.demo(
        args.csds, host_tput=80.0, csd_tput=10.0,
        host_max_batch=64, csd_max_batch=8,
        host_idle=100.0, csd_idle=1.5,
    )
    fleet = spec.build()
    shards = spec.shards(private_per_worker={"csd": 256}, public=65536)

    benchmark = None
    if args.measured_tune:
        # time the real jitted step at each candidate batch; throughput ratios
        # between classes come from the analytic fleet (single-host stand-in)
        params, _ = model.init_params(key=jax.random.PRNGKey(args.seed))
        opt = adamw() if args.optimizer == "adamw" else sgd_momentum()
        from repro.train.steps import make_train_step

        step = jax.jit(make_train_step(model, opt, lambda s: 1e-3))

        def run_at(batch: int):
            toks = jnp.zeros((batch, args.seq), jnp.int32)
            b = {
                "tokens": toks, "labels": toks,
                "loss_mask": jnp.ones((batch, args.seq), jnp.float32),
            }
            st = opt.init(params)
            out = step(params, st, b)
            jax.block_until_ready(out[2]["loss"])

        bench_core = measured_benchmark({"host": run_at, "csd": run_at})

        def benchmark(name: str, batch: int) -> float:
            t = bench_core("host", batch)
            # model CSD-class slowness relative to the host measurement
            rel = fleet.by_name("host").peak_throughput / fleet.by_name(name).peak_throughput
            return t * rel

    session = Session(
        model=model,
        optimizer=adamw() if args.optimizer == "adamw" else sgd_momentum(),
        fleet=fleet,
        data=DataConfig(vocab=cfg.vocab, seq_len=args.seq, seed=args.seed),
        config=SessionConfig(
            total_steps=args.steps,
            checkpoint_dir=args.checkpoint_dir,
            seed=args.seed,
        ),
        shards=shards,
        benchmark=benchmark,
    )

    tune_plan = session.tune()
    epoch = session.plan()
    shard_plan = session.shard()
    print(f"arch={cfg.name} params={cfg.param_count():,}")
    print(f"tuned batches: {tune_plan.batches} "
          f"(margin {tune_plan.result.margin:.0%}, "
          f"ref={tune_plan.result.reference_class})")
    print(f"schedule: groups={tune_plan.schedule.group_batches} "
          f"pad={tune_plan.schedule.pad_fraction:.1%}")
    print(f"epoch: {epoch.steps_per_epoch} steps, "
          f"imbalance {epoch.imbalance_steps()} steps")
    print(f"sharding: {shard_plan.describe()} "
          f"batch={shard_plan.batch['tokens'].spec}")

    session.callbacks.on_step(
        lambda i, m: print(
            f"  step {i:4d} loss {m['loss']:.4f} lr {m['lr']:.2e} "
            f"gnorm {m['grad_norm']:.2f} ({m['step_time']*1e3:.0f} ms)"
        ) if i % 5 == 0 else None
    )
    report = session.run()
    hist = report.history
    print(f"{report.steps_run} steps in {report.wall_time:.1f}s; "
          f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
