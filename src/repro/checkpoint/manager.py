"""Sharded, atomic, async checkpointing with elastic re-shard on restore.

Design (1000+-node requirements):
  * Layout-independent: checkpoints store each leaf as a *logical* (unsharded)
    array + the pytree structure, so restore can re-shard onto ANY mesh — a
    restart after losing a pod re-shards to the survivors (elasticity test:
    save at dp=8, restore at dp=4/2).
  * Sharding-aware both ways: save assembles each leaf on HOST from its
    per-device shards (``addressable_shards``) — a sharded array is never
    re-gathered into one replicated device buffer just to write it; restore
    takes a ``shardings`` pytree (e.g. a live
    :class:`~repro.api.artifacts.ShardingPlan`'s trees) and ``device_put``s
    every leaf straight onto its target ``NamedSharding``.
  * Atomic: write to ``step_N.tmp/`` then ``rename`` — a crash mid-write never
    corrupts the latest valid checkpoint; restore picks the newest *valid* dir
    (manifest present + CRC match).
  * Integrity: every leaf file carries a CRC32 in the manifest.
  * Async: ``save_async`` snapshots device arrays to host (blocking only for
    the device->host copy) and writes in a background thread — training
    continues during serialization, the paper's "no stall" spirit applied to
    checkpoint I/O.
  * Keep-K rotation bounds disk usage.

Format: one ``.npy`` per leaf (key = '/'-joined path), ``manifest.json`` with
tree structure, dtypes, shapes, CRCs, and user metadata (step, schedule, rng).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def key_str(path) -> str:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(p.name)
            else:
                parts.append(str(p))
        return "/".join(parts)

    return [(key_str(path), leaf) for path, leaf in flat]


def _treedef_of(tree: PyTree):
    return jax.tree_util.tree_structure(tree)


def _host_leaf(leaf: Any) -> np.ndarray:
    """Snapshot one leaf to a host np array, gathering per-shard.

    For a mesh-sharded ``jax.Array`` the logical array is assembled on host
    from the single-device shards (one D2H copy per shard, each the shard's
    size) — the full array is never re-materialized in any one device's
    memory.  Replicated and single-device leaves copy their one shard.
    """
    if isinstance(leaf, jax.Array) and hasattr(leaf, "addressable_shards"):
        if not getattr(leaf, "is_fully_addressable", True):
            # multi-process meshes: this process cannot see the whole leaf;
            # a per-host partial write would CRC-stamp garbage as valid
            raise ValueError(
                "checkpoint save needs fully-addressable arrays; in a "
                "multi-process mesh gather (or save per-host) explicitly"
            )
        shards = list(leaf.addressable_shards)
        if len(shards) == 1 or leaf.sharding.is_fully_replicated:
            arr = np.asarray(shards[0].data)
            if arr.shape != tuple(leaf.shape):
                raise ValueError(
                    f"shard covers {arr.shape} of logical {tuple(leaf.shape)}"
                )
            return arr
        out = np.empty(leaf.shape, leaf.dtype)
        seen = set()
        covered = 0
        for s in shards:
            key = str(s.index)            # skip replica copies of a shard
            if key in seen:
                continue
            seen.add(key)
            data = np.asarray(s.data)
            out[s.index] = data
            covered += int(data.size)
        if covered != int(leaf.size):     # never save uninitialized memory
            raise ValueError(
                f"shards cover {covered} of {int(leaf.size)} elements"
            )
        return out
    return np.asarray(jax.device_get(leaf))


def save(
    directory: str,
    step: int,
    tree: PyTree,
    *,
    metadata: Optional[Dict[str, Any]] = None,
) -> str:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    # snapshot to host np arrays, assembled per-shard (see _host_leaf)
    leaves = _flatten_with_paths(tree)
    entries = {}
    for key, leaf in leaves:
        arr = _host_leaf(leaf)
        fn = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        entries[key] = {
            "file": fn,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF,
        }
    manifest = {
        "step": step,
        "entries": entries,
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def _is_valid(path: str, verify_crc: bool = False) -> bool:
    mf = os.path.join(path, _MANIFEST)
    if not os.path.isfile(mf):
        return False
    try:
        with open(mf) as f:
            manifest = json.load(f)
        for key, e in manifest["entries"].items():
            fp = os.path.join(path, e["file"])
            if not os.path.isfile(fp):
                return False
            if verify_crc:
                arr = np.load(fp)
                if (zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF) != e["crc32"]:
                    return False
        return True
    except Exception:
        return False


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if _is_valid(os.path.join(directory, name)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(
    directory: str,
    like: PyTree,
    *,
    step: Optional[int] = None,
    shardings: Optional[PyTree] = None,
    verify_crc: bool = True,
) -> Tuple[PyTree, Dict[str, Any]]:
    """Restore into the structure of ``like``; re-shard via ``shardings``.

    ``shardings`` (a pytree of NamedSharding matching ``like``) may describe a
    DIFFERENT mesh than the one that saved — elastic restore is just
    ``jax.device_put(host_leaf, new_sharding)``.
    Returns (tree, metadata).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    entries = manifest["entries"]

    keys_like = _flatten_with_paths(like)
    flat_shardings = (
        [s for _, s in _flatten_with_paths(shardings)] if shardings is not None
        else [None] * len(keys_like)
    )
    out_leaves = []
    for (key, ref), shd in zip(keys_like, flat_shardings):
        if key not in entries:
            raise KeyError(f"checkpoint {path} missing leaf {key!r}")
        e = entries[key]
        arr = np.load(os.path.join(path, e["file"]))
        if verify_crc:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
            if crc != e["crc32"]:
                raise IOError(f"CRC mismatch for {key} in {path}")
        want_shape = tuple(getattr(ref, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {want_shape}"
            )
        if shd is not None:
            out_leaves.append(jax.device_put(arr, shd))
        else:
            out_leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        _treedef_of(like), out_leaves
    )
    return tree, manifest.get("metadata", {})


@dataclasses.dataclass
class CheckpointManager:
    """Keep-K rotation + async background saves."""

    directory: str
    keep: int = 3
    _thread: Optional[threading.Thread] = None
    _error: Optional[BaseException] = None

    def save(self, step: int, tree: PyTree, metadata=None, *, async_: bool = False):
        if async_:
            # snapshot on the caller thread (per-shard device->host copies),
            # serialize + fsync + rotate on the background thread
            host = jax.tree_util.tree_map(_host_leaf, tree)
            self.wait()

            def work():
                try:
                    save(self.directory, step, host, metadata=metadata)
                    self._rotate()
                except BaseException as e:  # surfaced on next wait()
                    self._error = e

            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            save(self.directory, step, tree, metadata=metadata)
            self._rotate()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, like: PyTree, *, step=None, shardings=None):
        self.wait()
        return restore(self.directory, like, step=step, shardings=shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _rotate(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)
