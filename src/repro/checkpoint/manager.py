"""Sharded, atomic, async checkpointing with elastic re-shard on restore.

Design (1000+-node requirements):
  * Layout-independent: checkpoints store each leaf as a *logical* (unsharded)
    array + the pytree structure, so restore can re-shard onto ANY mesh — a
    restart after losing a pod re-shards to the survivors (elasticity test:
    save at dp=8, restore at dp=4/2).
  * Sharding-aware both ways: save assembles each leaf on HOST from its
    per-device shards (``addressable_shards``) — a sharded array is never
    re-gathered into one replicated device buffer just to write it; restore
    takes a ``shardings`` pytree (e.g. a live
    :class:`~repro.api.artifacts.ShardingPlan`'s trees) and ``device_put``s
    every leaf straight onto its target ``NamedSharding``.
  * Atomic: write to ``step_N.tmp/`` then ``rename`` — a crash mid-write never
    corrupts the latest valid checkpoint; restore picks the newest *valid* dir
    (manifest present + CRC match).
  * Integrity: every leaf file carries a CRC32 in the manifest.
  * Async: ``save_async`` snapshots device arrays to host (blocking only for
    the device->host copy) and writes in a background thread — training
    continues during serialization, the paper's "no stall" spirit applied to
    checkpoint I/O.
  * Keep-K rotation bounds disk usage.

Format: one ``.npy`` per leaf (key = '/'-joined path), ``manifest.json`` with
tree structure, dtypes, shapes, CRCs, and user metadata (step, schedule, rng).

Multi-process (cluster) checkpoints extend the same format with
single-writer-per-shard coordination: every process calls
:func:`save_process` — a leaf this process fully holds is written whole by
the PRIMARY process only; a leaf sharded across processes (not fully
addressable) is written as per-shard *chunk* files, each by the one process
whose addressable shard carries ``replica_id == 0`` — so every byte of the
checkpoint has exactly one writer and nothing is gathered across hosts.
Each process stamps a partial ``manifest.p<N>.json``; after a barrier the
primary calls :func:`finalize_process_save`, which merges the partials,
verifies every leaf is fully covered (a missing process can never
CRC-stamp a hole as valid), writes the standard ``manifest.json`` (chunked
entries carry a ``chunks`` list), and atomically publishes.  :func:`restore`
reads both layouts — so a checkpoint saved by N processes restores onto ANY
mesh shape, including a different process count (the elastic
save-at-2-processes / restore-at-1-process path).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def key_str(path) -> str:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(p.name)
            else:
                parts.append(str(p))
        return "/".join(parts)

    return [(key_str(path), leaf) for path, leaf in flat]


def _treedef_of(tree: PyTree):
    return jax.tree_util.tree_structure(tree)


def _host_leaf(leaf: Any) -> np.ndarray:
    """Snapshot one leaf to a host np array, gathering per-shard.

    For a mesh-sharded ``jax.Array`` the logical array is assembled on host
    from the single-device shards (one D2H copy per shard, each the shard's
    size) — the full array is never re-materialized in any one device's
    memory.  Replicated and single-device leaves copy their one shard.
    """
    if isinstance(leaf, jax.Array) and hasattr(leaf, "addressable_shards"):
        if not getattr(leaf, "is_fully_addressable", True):
            # multi-process meshes: this process cannot see the whole leaf;
            # a per-host partial write would CRC-stamp garbage as valid
            raise ValueError(
                "checkpoint save needs fully-addressable arrays; in a "
                "multi-process mesh gather (or save per-host) explicitly"
            )
        shards = list(leaf.addressable_shards)
        if len(shards) == 1 or leaf.sharding.is_fully_replicated:
            arr = np.asarray(shards[0].data)
            if arr.shape != tuple(leaf.shape):
                raise ValueError(
                    f"shard covers {arr.shape} of logical {tuple(leaf.shape)}"
                )
            return arr
        out = np.empty(leaf.shape, leaf.dtype)
        seen = set()
        covered = 0
        for s in shards:
            key = str(s.index)            # skip replica copies of a shard
            if key in seen:
                continue
            seen.add(key)
            data = np.asarray(s.data)
            out[s.index] = data
            covered += int(data.size)
        if covered != int(leaf.size):     # never save uninitialized memory
            raise ValueError(
                f"shards cover {covered} of {int(leaf.size)} elements"
            )
        return out
    return np.asarray(jax.device_get(leaf))


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _index_to_json(index, shape) -> List[List[int]]:
    """A shard's index (tuple of slices) as [[start, stop], ...] per dim."""
    out = []
    for d, sl in enumerate(index):
        start = 0 if sl.start is None else int(sl.start)
        stop = shape[d] if sl.stop is None else int(sl.stop)
        out.append([start, stop])
    for d in range(len(index), len(shape)):
        out.append([0, shape[d]])
    return out


def _owned_chunks(leaf: "jax.Array") -> List[Tuple[List[List[int]], np.ndarray]]:
    """The (index, host_array) pieces THIS process is the single writer of.

    One writer per shard: the addressable copies with ``replica_id == 0``.
    Replicated leaves therefore have exactly one writer fleet-wide; data
    shards are written by the process that computes them.
    """
    shape = tuple(leaf.shape)
    out = []
    seen = set()
    for s in leaf.addressable_shards:
        if getattr(s, "replica_id", 0) != 0:
            continue
        key = str(s.index)
        if key in seen:
            continue
        seen.add(key)
        out.append((_index_to_json(s.index, shape), np.asarray(s.data)))
    return out


def save_process(
    directory: str,
    step: int,
    tree: PyTree,
    *,
    process_index: int,
    num_processes: int,
    metadata: Optional[Dict[str, Any]] = None,
) -> str:
    """One process's share of a coordinated multi-process save.

    Writes into the SHARED ``step_N.tmp/`` staging dir (all processes see
    one filesystem — the paper's host-attached fabric): whole leaves from
    the primary, per-shard chunks from their single writers, plus this
    process's partial manifest.  Publish happens only in
    :func:`finalize_process_save` after every process has stamped its
    partial — callers barrier between the two.
    """
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step:010d}.tmp")
    os.makedirs(tmp, exist_ok=True)

    entries: Dict[str, Any] = {}
    for key, leaf in _flatten_with_paths(tree):
        fn_base = key.replace("/", "__")
        fully = (
            not isinstance(leaf, jax.Array)
            or not hasattr(leaf, "addressable_shards")
            or getattr(leaf, "is_fully_addressable", True)
        )
        if fully:
            if process_index != 0:
                continue               # primary is the single writer
            arr = _host_leaf(leaf)
            fn = fn_base + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            entries[key] = {
                "file": fn, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "crc32": _crc(arr),
            }
        else:
            chunks = []
            for i, (index, arr) in enumerate(_owned_chunks(leaf)):
                fn = f"{fn_base}__p{process_index}_{i}.npy"
                np.save(os.path.join(tmp, fn), arr)
                chunks.append({
                    "file": fn, "index": index, "crc32": _crc(arr),
                })
            if chunks:
                entries[key] = {
                    "chunks": chunks,
                    "shape": list(leaf.shape),
                    "dtype": str(np.dtype(leaf.dtype)),
                }
    partial = {
        "step": step,
        "process": process_index,
        "num_processes": num_processes,
        "entries": entries,
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, f"manifest.p{process_index}.json"), "w") as f:
        json.dump(partial, f, indent=1)
    return tmp


def finalize_process_save(
    directory: str,
    step: int,
    *,
    num_processes: int,
    keys: Optional[List[str]] = None,
) -> str:
    """Merge the per-process partial manifests and publish atomically.

    Called by the PRIMARY after a barrier.  Verifies every process stamped
    its partial and — for chunked leaves — that the chunks tile the full
    logical array (no process's share can silently go missing).  ``keys``
    optionally pins the expected leaf set.
    """
    tmp = os.path.join(directory, f"step_{step:010d}.tmp")
    final = os.path.join(directory, f"step_{step:010d}")
    merged: Dict[str, Any] = {}
    metadata: Dict[str, Any] = {}
    for p in range(num_processes):
        pf = os.path.join(tmp, f"manifest.p{p}.json")
        if not os.path.isfile(pf):
            raise FileNotFoundError(
                f"process {p} never stamped its partial manifest in {tmp}"
            )
        with open(pf) as f:
            partial = json.load(f)
        metadata.update(partial.get("metadata") or {})
        for key, e in partial["entries"].items():
            if "chunks" not in e:
                if key in merged:
                    raise ValueError(f"two writers for whole leaf {key!r}")
                merged[key] = e
            else:
                slot = merged.setdefault(key, {
                    "chunks": [], "shape": e["shape"], "dtype": e["dtype"],
                })
                if "chunks" not in slot or slot["shape"] != e["shape"]:
                    raise ValueError(f"mixed layouts for leaf {key!r}")
                slot["chunks"].extend(e["chunks"])
    if keys is not None:
        missing = set(keys) - set(merged)
        if missing:
            raise ValueError(f"checkpoint missing leaves: {sorted(missing)}")
    for key, e in merged.items():
        if "chunks" not in e:
            continue
        size = int(np.prod(e["shape"])) if e["shape"] else 1
        covered = sum(
            int(np.prod([b - a for a, b in c["index"]])) if c["index"] else 1
            for c in e["chunks"]
        )
        if covered != size:
            raise ValueError(
                f"chunks of {key!r} cover {covered} of {size} elements"
            )
    manifest = {"step": step, "entries": merged, "metadata": metadata}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    for p in range(num_processes):        # partials are staging-only
        os.remove(os.path.join(tmp, f"manifest.p{p}.json"))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def save(
    directory: str,
    step: int,
    tree: PyTree,
    *,
    metadata: Optional[Dict[str, Any]] = None,
) -> str:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    # snapshot to host np arrays, assembled per-shard (see _host_leaf)
    leaves = _flatten_with_paths(tree)
    entries = {}
    for key, leaf in leaves:
        arr = _host_leaf(leaf)
        fn = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        entries[key] = {
            "file": fn,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF,
        }
    manifest = {
        "step": step,
        "entries": entries,
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def _entry_files(e: Dict[str, Any]) -> List[Tuple[str, int]]:
    """(file, crc) pairs of an entry, whole-leaf or chunked."""
    if "chunks" in e:
        return [(c["file"], c["crc32"]) for c in e["chunks"]]
    return [(e["file"], e["crc32"])]


def _load_entry(path: str, e: Dict[str, Any], verify_crc: bool) -> np.ndarray:
    """Materialize one manifest entry (assembling chunks if needed)."""
    if "chunks" not in e:
        arr = np.load(os.path.join(path, e["file"]))
        if verify_crc and _crc(arr) != e["crc32"]:
            raise IOError(f"CRC mismatch for {e['file']} in {path}")
        return arr
    out = np.empty(tuple(e["shape"]), np.dtype(e["dtype"]))
    for c in e["chunks"]:
        piece = np.load(os.path.join(path, c["file"]))
        if verify_crc and _crc(piece) != c["crc32"]:
            raise IOError(f"CRC mismatch for {c['file']} in {path}")
        out[tuple(slice(a, b) for a, b in c["index"])] = piece
    return out


def _is_valid(path: str, verify_crc: bool = False) -> bool:
    mf = os.path.join(path, _MANIFEST)
    if not os.path.isfile(mf):
        return False
    try:
        with open(mf) as f:
            manifest = json.load(f)
        for key, e in manifest["entries"].items():
            for fn, crc in _entry_files(e):
                fp = os.path.join(path, fn)
                if not os.path.isfile(fp):
                    return False
                if verify_crc and _crc(np.load(fp)) != crc:
                    return False
        return True
    except Exception:
        return False


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if _is_valid(os.path.join(directory, name)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(
    directory: str,
    like: PyTree,
    *,
    step: Optional[int] = None,
    shardings: Optional[PyTree] = None,
    verify_crc: bool = True,
) -> Tuple[PyTree, Dict[str, Any]]:
    """Restore into the structure of ``like``; re-shard via ``shardings``.

    ``shardings`` (a pytree of NamedSharding matching ``like``) may describe a
    DIFFERENT mesh than the one that saved — elastic restore is just
    ``jax.device_put(host_leaf, new_sharding)``.
    Returns (tree, metadata).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    entries = manifest["entries"]

    keys_like = _flatten_with_paths(like)
    flat_shardings = (
        [s for _, s in _flatten_with_paths(shardings)] if shardings is not None
        else [None] * len(keys_like)
    )
    out_leaves = []
    for (key, ref), shd in zip(keys_like, flat_shardings):
        if key not in entries:
            raise KeyError(f"checkpoint {path} missing leaf {key!r}")
        e = entries[key]
        arr = _load_entry(path, e, verify_crc)
        want_shape = tuple(getattr(ref, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {want_shape}"
            )
        if shd is not None:
            out_leaves.append(jax.device_put(arr, shd))
        else:
            out_leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        _treedef_of(like), out_leaves
    )
    return tree, manifest.get("metadata", {})


@dataclasses.dataclass
class CheckpointManager:
    """Keep-K rotation + async background saves."""

    directory: str
    keep: int = 3
    _thread: Optional[threading.Thread] = None
    _error: Optional[BaseException] = None

    def save(self, step: int, tree: PyTree, metadata=None, *, async_: bool = False):
        if async_:
            # snapshot on the caller thread (per-shard device->host copies),
            # serialize + fsync + rotate on the background thread
            host = jax.tree_util.tree_map(_host_leaf, tree)
            self.wait()

            def work():
                try:
                    save(self.directory, step, host, metadata=metadata)
                    self._rotate()
                except BaseException as e:  # surfaced on next wait()
                    self._error = e

            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            save(self.directory, step, tree, metadata=metadata)
            self._rotate()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, like: PyTree, *, step=None, shardings=None):
        self.wait()
        return restore(self.directory, like, step=step, shardings=shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _rotate(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)


@dataclasses.dataclass
class ClusterCheckpointManager(CheckpointManager):
    """Coordinated multi-process saves behind the CheckpointManager API.

    Each process holds one of these; ``save`` runs the single-writer
    protocol (:func:`save_process` everywhere -> barrier -> primary
    :func:`finalize_process_save` + rotate -> barrier), so a ``Session`` in
    cluster mode checkpoints through exactly the same call sites as a
    single-process one.  Saves are synchronous — the cross-process barrier
    IS the stall, a background thread would just hide a torn save.

    ``sync`` duck-types the coordinator transport
    (``barrier(tag)``); ``process_index == 0`` is the primary.
    """

    process_index: int = 0
    num_processes: int = 1
    sync: Any = None

    def _barrier(self, tag: str):
        if self.sync is not None and self.num_processes > 1:
            self.sync.barrier(tag)

    def save(self, step: int, tree: PyTree, metadata=None, *, async_: bool = False):
        save_process(
            self.directory, step, tree,
            process_index=self.process_index,
            num_processes=self.num_processes,
            metadata=metadata,
        )
        self._barrier(f"ckpt-stamp/{step}")
        if self.process_index == 0:
            finalize_process_save(
                self.directory, step, num_processes=self.num_processes,
            )
            self._rotate()
        self._barrier(f"ckpt-publish/{step}")
