from repro.checkpoint.manager import CheckpointManager, restore, save

__all__ = ["CheckpointManager", "save", "restore"]
