from repro.checkpoint.manager import (
    CheckpointManager, ClusterCheckpointManager, finalize_process_save,
    restore, save, save_process,
)

__all__ = [
    "CheckpointManager",
    "ClusterCheckpointManager",
    "finalize_process_save",
    "restore",
    "save",
    "save_process",
]
