"""Benchmark harness: one module per paper table/figure, plus the
Session-API end-to-end smoke.  All suites go through ``repro.api``
(``FleetSpec`` presets / ``Session``) — no hand-rolled fleet wiring here.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table1     # one
"""
from __future__ import annotations

import sys
import time


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    from benchmarks import (
        accuracy_parity, fig6_throughput, fig7_speedup, session_smoke,
        table1_tuning, table2_energy,
    )

    suites = {
        "table1": lambda: table1_tuning.run(),
        "fig6": lambda: fig6_throughput.run(),
        "fig7": lambda: (fig7_speedup.run(), print(fig7_speedup.validate())),
        "table2": lambda: (table2_energy.run(), print(table2_energy.validate())),
        "accuracy": lambda: print(accuracy_parity.run()),
        "session": lambda: print(session_smoke._checks(session_smoke.run())),
    }
    wanted = argv or list(suites)
    rc = 0
    for name in wanted:
        if name not in suites:
            print(f"unknown suite {name!r}; have {list(suites)}")
            return 2
        t0 = time.time()
        try:
            suites[name]()
            print(f"[{name}] done in {time.time()-t0:.1f}s")
        except Exception as e:
            rc = 1
            print(f"[{name}] FAILED: {type(e).__name__}: {e}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
