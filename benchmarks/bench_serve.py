"""Serving benchmark: the continuous-batching engine under closed-loop load.

Emits ``BENCH_serve.json`` — the perf trajectory anchor for ``repro.serve``.
For one dense, one MoE, and one recurrent family (smoke configs, CPU or
whatever jax finds) it drives :func:`repro.serve.run_load`: ``--requests``
synthetic users all submit up-front (queue depth == concurrency) and the
engine drains them through its slot batch.  Recorded per family:

  * ``requests_per_s`` / ``decode_tok_s`` — sustained drain throughput
  * ``latency_p50_ms`` / ``latency_p99_ms`` — submit->finish (queueing-
    dominated at this depth, which is the point)
  * ``ttft_p50_ms`` / ``ttft_p99_ms``       — submit->first token
  * ``prefix_hit_rate``                     — with ``--shared-prefix`` > 0,
    how much prompt work the block cache absorbed

Usage:
    PYTHONPATH=src python benchmarks/bench_serve.py            # 256 requests
    PYTHONPATH=src python benchmarks/bench_serve.py --requests 32  # CI-sized
"""
from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.configs import smoke_config
from repro.models.api import get_model
from repro.serve import EngineConfig, ServeEngine, run_load

ARCHS = ["deepseek-7b", "qwen3-moe-30b-a3b", "rwkv6-7b"]
PROMPT_LEN = 16
MAX_NEW = 8


def bench_one(arch: str, *, requests: int, shared_prefix: int, seed: int,
              kv_cache_dtype: str = "native", name: str = None):
    cfg = smoke_config(arch).with_(kv_cache_dtype=kv_cache_dtype)
    model = get_model(cfg)
    params, _ = model.init_params(key=jax.random.PRNGKey(seed))
    # prefill_chunk == block_size so every block boundary is a chunk
    # boundary: recurrent families can snapshot (and later hit) the shared
    # prefix; attention families publish full blocks at completion anyway
    engine = ServeEngine(model=model, params=params, config=EngineConfig(
        max_slots=8, max_len=PROMPT_LEN + MAX_NEW + 8, block_size=8,
        num_blocks=64, prefill_chunk=8, token_budget=32,
    ))
    report = run_load(
        engine, n_requests=requests, prompt_len=PROMPT_LEN,
        max_new_tokens=MAX_NEW, shared_prefix_len=shared_prefix, seed=seed,
    )
    rec = report.to_json()
    rec["name"] = name or arch
    rec["kv_cache_dtype"] = kv_cache_dtype
    # resident page-pool footprint — THE serving memory cost; int8 KV
    # (+ per-row scales) should land at ~1/4 of the native pool.  Recurrent
    # families have no page pool; report their slot state instead.
    store = getattr(engine.adapter, "pool", None)
    if store is None:
        store = engine.adapter.cache
    rec["kv_pool_bytes"] = sum(
        int(leaf.nbytes) for leaf in jax.tree_util.tree_leaves(store)
    )
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--shared-prefix", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    runs = [dict(arch=a) for a in ARCHS]
    # int8 KV-cache pool A/B against the native deepseek record
    runs.append(dict(arch="deepseek-7b", kv_cache_dtype="int8",
                     name="deepseek-7b-kv-int8"))
    records = []
    for kw in runs:
        rec = bench_one(requests=args.requests,
                        shared_prefix=args.shared_prefix, seed=args.seed, **kw)
        records.append(rec)
        print(f"[bench_serve] {rec['name']:20s} {rec['requests_per_s']:8.2f} req/s  "
              f"p50={rec['latency_p50_ms']:.0f}ms p99={rec['latency_p99_ms']:.0f}ms  "
              f"ttft_p50={rec['ttft_p50_ms']:.0f}ms  "
              f"hit_rate={rec['prefix_hit_rate']:.3f}  "
              f"kv_pool={rec['kv_pool_bytes']:,}B")

    out = {
        "benchmark": "serve_load",
        "backend": jax.default_backend(),
        "note": (
            "smoke configs; closed-loop load (all requests submitted "
            "up-front, concurrency == n_requests); latency is submit->finish "
            "so it is queueing-dominated at this depth"
        ),
        "records": records,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"[bench_serve] wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
