"""Fig. 6 reproduction: aggregate images/sec vs number of CSDs.

The paper's curves: throughput grows near-linearly with CSD count; per-node
slowdown from synchronization stalls fades beyond 5-6 nodes (the ring
allreduce cost per node is ~independent of n).  We reproduce through the
fleet model: distributed_step_time = max(compute) + ring_allreduce_time.
"""
from __future__ import annotations

from typing import Dict, List

from repro.api import FleetSpec
from repro.core import topology, tuner

NETS = {
    # name: (n_params for allreduce volume, MACs proxy unused)
    "mobilenetv2": 3.47e6,
    "nasnet": 5.3e6,
    "inceptionv3": 23.83e6,
    "squeezenet": 1.25e6,
}
CSD_COUNTS = [0, 1, 2, 4, 8, 12, 16, 20, 24]


def run(verbose: bool = True) -> Dict[str, List[float]]:
    curves: Dict[str, List[float]] = {}
    for net, n_params in NETS.items():
        pts = []
        for n in CSD_COUNTS:
            fleet = FleetSpec.paper(max(n, 1), net).build()
            r = tuner.tune(fleet, max_iters=128)
            batches = dict(r.batches)
            if n == 0:
                batches["newport"] = 0
            tput = topology.fleet_throughput(fleet, batches, int(n_params))
            pts.append(tput)
        curves[net] = pts
    if verbose:
        print("\n== Fig. 6: aggregate throughput (samples/s) vs #CSDs ==")
        print(f"{'#CSD':>5s} " + " ".join(f"{n:>12s}" for n in NETS))
        for i, n in enumerate(CSD_COUNTS):
            print(f"{n:>5d} " + " ".join(f"{curves[k][i]:>12.1f}" for k in NETS))
    return curves


if __name__ == "__main__":
    run()
