"""Table II reproduction: energy per image, % saving, FLOPS/W vs #CSDs.

Paper (MobileNetV2):
    #CSD            0      4      8      16     24
    J/image       13.10   8.30   6.84   5.05   4.02
    saving          0%    37%    48%    62%    69%
    MFLOPS/W       5.87   7.05   8.18  10.37  12.26

Methodology identical to the paper: wall power of the whole rack divided by
aggregate throughput.  The paper's 0-CSD baseline is the SAME server with 24
Micron 11-TB SSDs (storage-only) — so rack power has three components:

    P(rack) = P_host(compute) + n_storage * P(storage device)

with Newport CSDs replacing the Microns in the CSD rows (idle Newports draw
storage-only power; active ones add ISP compute power).  We calibrate the
four device constants ONCE against the 0- and 24-CSD rows and *predict* the
middle rows — reproducing the trend validates the paper's claim that the
energy win comes from ~3 img/s per ~1.5 W incremental CSD compute vs ~13 W
per img/s on the host.
"""
from __future__ import annotations

from typing import Dict

from repro.api import FleetSpec
from repro.core import topology, tuner

PAPER_ENERGY = {0: 13.10, 4: 8.30, 8: 6.84, 16: 5.05, 24: 4.02}
PAPER_MFLOPS_W = {0: 5.87, 4: 7.05, 8: 8.18, 16: 10.37, 24: 12.26}
CSD_COUNTS = [0, 4, 8, 16, 24]

# calibrated rack constants (see module docstring)
P_HOST = 227.0          # Xeon host under training load
P_MICRON = 7.5          # 11-TB Micron SSD, storage duty
P_NEWPORT_IDLE = 5.0    # Newport, storage-only duty
P_NEWPORT_ACTIVE = 6.5  # Newport, storage + ISP training duty
N_BAYS = 24
FLOPS_PER_IMG = 56e6 * 2   # MobileNetV2: 56M MACs = 112 MFLOPs/img


def rack_power(n_active_csds: int) -> float:
    if n_active_csds == 0:
        return P_HOST + N_BAYS * P_MICRON           # Micron-SSD baseline server
    return (P_HOST + n_active_csds * P_NEWPORT_ACTIVE
            + (N_BAYS - n_active_csds) * P_NEWPORT_IDLE)


def run(verbose: bool = True) -> Dict[int, Dict[str, float]]:
    rows: Dict[int, Dict[str, float]] = {}
    for n in CSD_COUNTS:
        fleet = FleetSpec.paper(max(n, 1), "mobilenetv2").build()
        r = tuner.tune(fleet, max_iters=128)
        batches = dict(r.batches)
        if n == 0:
            batches["newport"] = 0
        tput = topology.fleet_throughput(fleet, batches, int(3.47e6))
        power = rack_power(n)
        j_per_img = power / max(tput, 1e-9)
        base = rows[0]["j_per_image"] if rows else j_per_img
        rows[n] = {
            "throughput": tput,
            "power_w": power,
            "j_per_image": j_per_img,
            "saving": 1.0 - j_per_img / base,
            "mflops_per_w": (tput * FLOPS_PER_IMG / power) / 1e6,
            "paper_j": PAPER_ENERGY[n],
            "paper_mflops_w": PAPER_MFLOPS_W[n],
        }
    if verbose:
        print("\n== Table II: energy per image (MobileNetV2) ==")
        print(f"{'#CSD':>5s} {'J/img':>8s} {'paper':>8s} {'saving':>8s} "
              f"{'paper':>7s} {'MFLOPS/W':>9s} {'paper':>7s}")
        for n, r in rows.items():
            psave = 1.0 - r["paper_j"] / PAPER_ENERGY[0]
            print(f"{n:>5d} {r['j_per_image']:>8.2f} {r['paper_j']:>8.2f} "
                  f"{r['saving']:>7.0%} {psave:>7.0%} "
                  f"{r['mflops_per_w']:>9.2f} {r['paper_mflops_w']:>7.2f}")
    return rows


def validate() -> Dict[str, bool]:
    rows = run(verbose=False)
    return {
        # paper claim: energy/image decreases monotonically with CSD count
        "monotone_energy": all(
            rows[a]["j_per_image"] >= rows[b]["j_per_image"]
            for a, b in zip(CSD_COUNTS, CSD_COUNTS[1:])
        ),
        # paper headline: >= 60% saving at 24 CSDs (paper: 69%)
        "saving_60pct_at_24": rows[24]["saving"] >= 0.60,
        # every row within 20% of the paper's measurement
        "rows_within_20pct": all(
            abs(r["j_per_image"] - r["paper_j"]) / r["paper_j"] < 0.20
            for r in rows.values()
        ),
    }


if __name__ == "__main__":
    run()
    print(validate())
