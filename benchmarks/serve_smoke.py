"""End-to-end ServeEngine smoke: the continuous-batching acceptance path.

Asserts the three properties that make the engine an *engine* rather than a
batched generate loop:

  1. **Admit-mid-decode** — a request submitted while another is decoding
     starts streaming before the first finishes, and neither request's
     tokens change versus running each alone (continuous batching does not
     perturb outputs).
  2. **Prefix-cache reuse** — requests sharing a prompt prefix hit the
     block cache (hit counter rises) and still produce exactly the tokens
     of a cold run (reused blocks are bit-identical).
  3. **Streaming order** — per-request events arrive with consecutive
     indices, exactly one terminal event each, and the streamed tokens
     equal the final output.

Runs one attention family (paged KV blocks) and one recurrent family
(state-snapshot blocks) on smoke configs.

    PYTHONPATH=src python benchmarks/serve_smoke.py
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.api import get_model
from repro.serve import EngineConfig, ServeEngine

CONFIG = EngineConfig(
    max_slots=2, max_len=48, block_size=4, num_blocks=32,
    prefill_chunk=8, token_budget=16,
)


def _build(arch: str):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params, _ = model.init_params(key=jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params) -> ServeEngine:
    return ServeEngine(model=model, params=params, config=CONFIG)


def check_admit_mid_decode(cfg, model, params) -> None:
    rng = np.random.default_rng(0)
    p0 = rng.integers(0, cfg.vocab, size=6).tolist()
    p1 = rng.integers(0, cfg.vocab, size=6).tolist()

    solo = {}
    for name, p in (("p0", p0), ("p1", p1)):
        solo[name] = _engine(model, params).generate_batch(
            [p], max_new_tokens=6
        )[0].tokens

    eng = _engine(model, params)
    r0 = eng.submit(p0, max_new_tokens=6)
    eng.step()                                   # r0 prefills + starts decoding
    r1 = eng.submit(p1, max_new_tokens=3)        # lands mid-decode
    first_r1_step, r0_done_step, step = None, None, 1
    while eng.has_work():
        for ev in eng.step():
            if ev.request_id == r1 and first_r1_step is None:
                first_r1_step = step
            if ev.request_id == r0 and ev.done:
                r0_done_step = step
        step += 1
    assert first_r1_step is not None and r0_done_step is not None
    assert first_r1_step < r0_done_step, \
        "second request must stream before the first finishes"
    assert eng.output(r0).tokens == solo["p0"], "interleaving changed r0"
    assert eng.output(r1).tokens == solo["p1"][:3], "interleaving changed r1"
    print(f"  admit-mid-decode: r1 first token at step {first_r1_step}, "
          f"r0 finished at step {r0_done_step}, outputs match solo runs")


def check_prefix_cache(cfg, model, params) -> None:
    rng = np.random.default_rng(1)
    prefix = rng.integers(0, cfg.vocab, size=12).tolist()
    prompts = [prefix + rng.integers(0, cfg.vocab, size=4).tolist()
               for _ in range(3)]

    cold = [_engine(model, params).generate_batch([p], max_new_tokens=4)[0]
            for p in prompts]

    eng = _engine(model, params)
    warm = eng.generate_batch(prompts, max_new_tokens=4)
    stats = eng.prefix_cache_stats
    assert stats.hit_blocks > 0, "shared prefix produced no cache hits"
    for got, want in zip(warm, cold):
        assert got.tokens == want.tokens, "cache hit changed tokens"
    print(f"  prefix-cache: hit_rate={stats.hit_rate:.3f} "
          f"({stats.hit_blocks}/{stats.queries} block probes), "
          "hits bit-identical to cold prefill")


def check_streaming_order(cfg, model, params) -> None:
    eng = _engine(model, params)
    rng = np.random.default_rng(2)
    rids = [eng.submit(rng.integers(0, cfg.vocab, size=5).tolist(),
                       max_new_tokens=4) for _ in range(3)]
    events = {r: [] for r in rids}
    while eng.has_work():
        for ev in eng.step():
            events[ev.request_id].append(ev)
    for rid in rids:
        evs = events[rid]
        assert [e.index for e in evs] == list(range(len(evs)))
        assert sum(e.done for e in evs) == 1 and evs[-1].done
        assert [e.token for e in evs] == eng.output(rid).tokens
    print(f"  streaming: {len(rids)} requests, consecutive indices, "
          "one terminal event each")


def main() -> int:
    for arch in ("deepseek-7b", "rwkv6-7b"):
        cfg, model, params = _build(arch)
        print(f"[serve_smoke] {arch} ({cfg.family})")
        check_admit_mid_decode(cfg, model, params)
        check_prefix_cache(cfg, model, params)
        check_streaming_order(cfg, model, params)
    print("[serve_smoke] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
