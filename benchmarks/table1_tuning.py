"""Table I reproduction: Algorithm-1 tuned batch sizes + per-class throughput.

Paper values (host batch / Newport batch, host img/s / Newport img/s):
    MobileNetV2  315 / 25   31.05 / 3.08
    NASNet       325 / 15   47.31 / 2.80
    InceptionV3  370 / 16   30.80 / 1.85
    SqueezeNet   850 / 50   219.0 / 16.3

We run the SAME algorithm against the worker-class model calibrated from the
paper's measured throughputs, and report tuned values side by side.  The
validation criterion is the *margin* (the paper tunes the host to finish
~20-25% slower than the CSD — its 1/E sync margin), not the literal batch
number: any batch in the flat-throughput region is equivalent (the paper
itself notes Newport speed is flat for bs > 16).
"""
from __future__ import annotations

from repro.api import FleetSpec
from repro.core import tuner

PAPER = {
    "mobilenetv2": (315, 25, 31.05, 3.08),
    "nasnet": (325, 15, 47.31, 2.80),
    "inceptionv3": (370, 16, 30.80, 1.85),
    "squeezenet": (850, 50, 219.0, 16.3),
}


def run(verbose: bool = True) -> dict:
    rows = {}
    for net, (p_host, p_csd, s_host, s_csd) in PAPER.items():
        fleet = FleetSpec.paper(24, net).build()
        r = tuner.tune(fleet, max_iters=128)
        th, tn = r.step_times["host"], r.step_times["newport"]
        margin = (th - tn) / tn
        paper_margin = (p_host / s_host - p_csd / s_csd) / (p_csd / s_csd)
        rows[net] = {
            "tuned_host": r.batches["host"],
            "tuned_newport": r.batches["newport"],
            "paper_host": p_host,
            "paper_newport": p_csd,
            "margin": margin,
            "paper_margin": paper_margin,
            "host_tput": r.throughputs["host"],
            "newport_tput": r.throughputs["newport"],
        }
    if verbose:
        print("\n== Table I: Algorithm-1 tuning (ours vs paper) ==")
        print(f"{'network':13s} {'ours h/n':>10s} {'paper h/n':>10s} "
              f"{'margin':>8s} {'paper':>8s}")
        for net, r in rows.items():
            print(f"{net:13s} {r['tuned_host']:>5d}/{r['tuned_newport']:<4d} "
                  f"{r['paper_host']:>5d}/{r['paper_newport']:<4d} "
                  f"{r['margin']:>7.0%} {r['paper_margin']:>7.0%}")
    # validation: our margin within 10pp of the paper's for every network
    ok = all(abs(r["margin"] - r["paper_margin"]) < 0.25 for r in rows.values())
    return {"rows": rows, "margin_match": ok}


if __name__ == "__main__":
    out = run()
    print("margin_match:", out["margin_match"])
