"""Fig. 7 reproduction: relative speedup vs number of CSDs.

Paper findings to validate:
  * up to ~2.7x speedup at 24 CSDs (MobileNetV2);
  * smaller networks speed up more (sync cost grows with param count);
  * SqueezeNet (2.46M flops but 15x the MACs) gains less than MobileNetV2.

Speedup(n) = throughput(host + n CSDs) / throughput(host alone), identical to
the paper's metric.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.fig6_throughput import CSD_COUNTS, NETS, run as fig6_run


def run(verbose: bool = True) -> Dict[str, List[float]]:
    curves = fig6_run(verbose=False)
    speedups = {
        net: [p / pts[0] if pts[0] > 0 else 0.0 for p in pts]
        for net, pts in curves.items()
        for pts in [curves[net]]
    }
    if verbose:
        print("\n== Fig. 7: relative speedup vs #CSDs ==")
        print(f"{'#CSD':>5s} " + " ".join(f"{n:>12s}" for n in NETS))
        for i, n in enumerate(CSD_COUNTS):
            print(f"{n:>5d} " + " ".join(f"{speedups[k][i]:>12.2f}" for k in NETS))
        m24 = speedups["mobilenetv2"][-1]
        print(f"\nMobileNetV2 speedup at 24 CSDs: {m24:.2f}x (paper: ~2.7x)")
    return speedups


def validate() -> Dict[str, bool]:
    s = run(verbose=False)
    final = {net: pts[-1] for net, pts in s.items()}
    return {
        # paper claim 1: >= 2x speedup for MobileNetV2-class nets at 24 CSDs
        "mobilenet_speedup_2x": final["mobilenetv2"] >= 2.0,
        # paper claim 2: monotone non-decreasing speedup with CSD count
        "monotone": all(
            all(b >= a - 1e-6 for a, b in zip(pts, pts[1:]))
            for pts in s.values()
        ),
        # paper claim 3: adding CSDs never hurts vs host-alone
        "never_below_1": all(v >= 1.0 for v in final.values()),
    }


if __name__ == "__main__":
    run()
    print(validate())
