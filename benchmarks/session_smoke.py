"""End-to-end Session-API smoke: the whole pipeline plus elastic events.

Exercises what the paper's rack would see in production: tune -> plan ->
place -> compile -> train, then a drift re-tune (must NOT recompile) and a
node loss (paper's backfill remedy), all through ``repro.api.Session``.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.api import (
    DriftDetected, FleetSpec, Session, SessionConfig, WorkerLost,
)
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig
from repro.models.api import get_model
from repro.optim import adamw

STEPS = 8


def _session(n_csds: int = 3) -> Session:
    cfg = smoke_config("deepseek-7b")
    spec = FleetSpec.demo(n_csds=n_csds)
    return Session(
        model=get_model(cfg),
        optimizer=adamw(),
        fleet=spec,
        data=DataConfig(vocab=cfg.vocab, seq_len=16),
        shards=spec.shards(private_per_worker={"csd": 64}, public=4096),
        config=SessionConfig(total_steps=STEPS),
    )


def run(verbose: bool = True) -> Dict[str, float]:
    s = _session()
    report = s.run()
    loss0, loss1 = report.history[0]["loss"], report.final_loss

    # online re-tune: shapes pinned => the compiled step must survive
    compiles_before = s.compile_count
    drift = s.apply(DriftDetected())
    assert not drift.recompiled and s.compile_count == compiles_before

    # node loss: one dp-group gone, survivors re-plan (backfill remedy);
    # training continues with optimizer moments and warmup progress intact
    lost = s.apply(WorkerLost(["csd/1"]))
    report2 = s.run(report.params, opt_state=report.opt_state, steps=2)

    out = {
        "loss_start": loss0,
        "loss_end": loss1,
        "loss_after_loss_event": report2.final_loss,
        "drift_recompiled": float(drift.recompiled),
        "groups_after_loss": float(lost.tune_plan.schedule.n_groups),
        "compile_count": float(s.compile_count),
    }
    if verbose:
        print("\n== Session-API smoke ==")
        for k, v in out.items():
            print(f"  {k:>22s}: {v:.4f}")
    return out


def validate() -> Dict[str, bool]:
    m = run(verbose=False)
    return {
        "loss_decreases": m["loss_end"] < m["loss_start"],
        "drift_no_recompile": m["drift_recompiled"] == 0.0,
        "survives_node_loss": bool(np.isfinite(m["loss_after_loss_event"])),
    }
