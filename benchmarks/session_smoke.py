"""End-to-end Session-API smoke: the whole pipeline plus elastic events.

Exercises what the paper's rack would see in production: tune -> plan ->
place -> shard -> compile -> train, then a drift re-tune (must NOT
recompile) and a node loss (paper's backfill remedy), all through
``repro.api.Session`` — pulled through the selected :mod:`repro.storage`
backend (``--backend synthetic|flash|meshfeed``).  The meshfeed run on a
multi-device host (``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
is the multi-device acceptance path: batches land pre-sharded on a real
``jax.sharding.Mesh``, and the smoke asserts the compiled step's input
shardings are the ShardingPlan's (explicit, not GSPMD defaults) and that
trained state + batches actually land on them.

    PYTHONPATH=src python benchmarks/session_smoke.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python benchmarks/session_smoke.py --backend meshfeed
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.api import (
    DriftDetected, FleetSpec, Session, SessionConfig, WorkerLost,
)
from repro.configs import smoke_config
from repro.models.api import get_model
from repro.optim import adamw
from repro.storage import DataConfig

STEPS = 8


def _session(n_csds: int = 3, backend: str = "synthetic") -> Session:
    cfg = smoke_config("deepseek-7b")
    spec = FleetSpec.demo(n_csds=n_csds).with_storage(backend)
    return Session(
        model=get_model(cfg),
        optimizer=adamw(),
        fleet=spec,
        data=DataConfig(vocab=cfg.vocab, seq_len=16),
        shards=spec.shards(private_per_worker={"csd": 64}, public=4096),
        config=SessionConfig(total_steps=STEPS),
    )


def run(verbose: bool = True, backend: str = "synthetic") -> Dict[str, float]:
    s = _session(backend=backend)
    report = s.run()
    loss0, loss1 = report.history[0]["loss"], report.final_loss

    # online re-tune: shapes pinned => the compiled step must survive
    compiles_before = s.compile_count
    drift = s.apply(DriftDetected())
    assert not drift.recompiled and s.compile_count == compiles_before

    # node loss: one dp-group gone, survivors re-plan (backfill remedy);
    # training continues with optimizer moments and warmup progress intact.
    # Custody routes through the DeviceFleet: csd/1's private shard is
    # quarantined, its public custody re-homes to a survivor.
    lost = s.apply(WorkerLost(["csd/1"]))
    report2 = s.run(report.params, opt_state=report.opt_state, steps=2)

    from repro.core.privacy import audit_custody
    audit = audit_custody(s.devices.custody_log)

    # sharding-explicit execution: the (re-derived, post-loss) plan must be
    # exactly what the compiled step declares, and what state/batches use
    import jax

    plan = s.shard()
    compiled = s.compile()
    explicit = compiled.in_shardings == (plan.params, plan.opt, plan.batch)
    p_leaves = jax.tree_util.tree_leaves(report2.params)
    sh_leaves = jax.tree_util.tree_leaves(plan.params)
    params_on_plan = len(p_leaves) == len(sh_leaves) and all(
        l.sharding.is_equivalent_to(sh, l.ndim)
        for l, sh in zip(p_leaves, sh_leaves)
    )
    tok = s.dataset.next_device_batch()["tokens"]
    batch_on_plan = tok.sharding.is_equivalent_to(
        plan.batch["tokens"], tok.ndim
    )

    mesh = s.devices.mesh
    out = {
        "loss_start": loss0,
        "loss_end": loss1,
        "loss_after_loss_event": report2.final_loss,
        "drift_recompiled": float(drift.recompiled),
        "groups_after_loss": float(lost.tune_plan.schedule.n_groups),
        "compile_count": float(s.compile_count),
        "private_shards_rehomed": float(audit["private_shards_rehomed"]),
        "feed_devices": float(mesh.shape["data"]) if mesh is not None else 1.0,
        "data_axis": float(plan.data_axis),
        "sharding_explicit": float(explicit),
        "state_on_plan": float(params_on_plan and batch_on_plan),
    }
    if verbose:
        print(f"\n== Session-API smoke [{backend}] ==")
        for k, v in out.items():
            print(f"  {k:>22s}: {v:.4f}")
    return out


def _checks(m: Dict[str, float]) -> Dict[str, bool]:
    return {
        "loss_decreases": m["loss_end"] < m["loss_start"],
        "drift_no_recompile": m["drift_recompiled"] == 0.0,
        "survives_node_loss": bool(np.isfinite(m["loss_after_loss_event"])),
        "no_private_rehome": m["private_shards_rehomed"] == 0.0,
        # the compiled step's input shardings ARE the ShardingPlan's
        "sharding_explicit": m["sharding_explicit"] == 1.0,
        # trained params + fed batches land on the plan's NamedShardings
        "state_on_plan": m["state_on_plan"] == 1.0,
    }


def validate(backend: str = "synthetic") -> Dict[str, bool]:
    return _checks(run(verbose=False, backend=backend))


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="synthetic",
                    choices=["synthetic", "flash", "meshfeed"])
    args = ap.parse_args()
    checks = _checks(run(backend=args.backend))
    print("checks:", checks)
    sys.exit(0 if all(checks.values()) else 1)
