"""Cluster acceptance smoke: N worker processes (default 4 x 2 fake devices).

Launches a REAL multi-process cluster (``repro.launch.cluster``) and proves
the acceptance properties of multi-process execution:

  1. **Addressable-only placement** — each worker process ``device_put``s
     only its addressable slice of the plan's ``NamedSharding``s: every
     receipt destination is a local device, the per-step h2d bytes equal
     exactly this host's row-slab bytes (no cross-host batch bytes — the
     global-array assembly itself runs under
     ``jax.transfer_guard_host_to_device("disallow")``), and the manifest
     shows only local dp-groups with real custody (the rest are ``remote``
     records).
  2. **No-recompile elasticity** — ``compile_count`` stays 1 across a
     drift re-tune in every worker process (capacity-pinned shapes).
  3. **Single-process equivalence** — the N-process run's losses match a
     single-process run batch-for-batch, and a checkpoint SAVED at N
     processes (single-writer-per-shard, coordinator-merged) RESTORES at 1
     process and continues on the single-process loss curve.
  4. **Compressed transport correctness** — a second run over the int8
     ring transport (error-feedback compression, overlapped buckets) keeps
     every replica BIT-identical (equal param digests and exact loss
     equality — the pid-ordered deterministic accumulation), compresses the
     wire at least 3x, and its loss curve tracks the uncompressed run
     within the error-feedback tolerance.

    PYTHONPATH=src python benchmarks/cluster_smoke.py
    PYTHONPATH=src python benchmarks/cluster_smoke.py --processes 4 --steps 6
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
from typing import Dict

import numpy as np

STEPS = 6
RESUME = 2
SEQ_LEN = 16
BYTES_PER_TOKEN = 4 + 4 + 4       # tokens i32 + labels i32 + loss_mask f32


# the production transport exercised by the compressed phase
_TX = {"compression": "int8", "buckets": 2, "overlap": True,
       "topology": "ring", "timeout": 300.0}
# error-feedback keeps the compressed curve NEAR the uncompressed one, not
# on it; measured drift after 6 steps is ~0.05%, gate at 2%
TX_LOSS_RTOL = 2e-2


def run(verbose: bool = True, processes: int = 4, steps: int = STEPS,
        local_devices: int = None) -> Dict[str, float]:
    from repro.core.topology import ClusterSpec
    from repro.launch.cluster import demo_session_factory, run_cluster

    if local_devices is None:
        local_devices = max(1, 8 // processes)

    run_dir = tempfile.mkdtemp(prefix="repro-cluster-smoke-")
    ckpt_dir = os.path.join(run_dir, "ckpt")
    result = run_cluster(
        ClusterSpec(processes=processes, local_devices=local_devices),
        "repro.launch.cluster:demo_session_factory",
        {"processes": processes, "steps": steps, "seq_len": SEQ_LEN,
         "checkpoint_dir": ckpt_dir},
        run_dir=run_dir, resume_steps=RESUME, timeout=600,
    )
    if not result.ok:
        raise RuntimeError(
            f"cluster run failed: rc={result.returncodes}; "
            f"logs under {run_dir}"
        )
    recs = result.records

    # per-process invariants
    addressable_only = all(r["addressable_only"] for r in recs)
    custody_local_only = all(
        set(r["manifest_local"]) == set(r["local_workers"])
        and not (set(r["local_workers"]) & set(r["remote_workers"]))
        for r in recs
    )
    feed_exact = all(
        r["receipt"]["bytes_put"]
        == r["receipt"]["rows_local"] * SEQ_LEN * BYTES_PER_TOKEN
        for r in recs
    )
    replicas_agree = all(
        np.allclose(recs[0]["losses"], r["losses"], rtol=1e-6)
        for r in recs
    )
    one_compile = all(
        r["compile_count"] == 1 and r["drift_no_recompile"] for r in recs
    )

    # single-process equivalence: same factory, one process, no cluster
    single = demo_session_factory(
        processes=1, steps=steps + RESUME, seq_len=SEQ_LEN
    )
    single_losses = [h["loss"] for h in single.run().history]
    cluster_losses = recs[0]["losses"]
    resumed = recs[0]["resumed_losses"]
    match_train = np.allclose(
        single_losses[:steps], cluster_losses, rtol=1e-4
    )
    match_resume = np.allclose(
        single_losses[steps:], resumed, rtol=1e-4
    )

    # compressed production transport on the SAME problem: int8 ring with
    # error-feedback and overlapped buckets.  Replicas must stay
    # BIT-identical (pid-ordered deterministic accumulation) and the loss
    # curve must track the uncompressed run within TX_LOSS_RTOL.
    tx_result = run_cluster(
        ClusterSpec(processes=processes, local_devices=local_devices,
                    transport=dict(_TX)),
        "repro.launch.cluster:demo_session_factory",
        {"processes": processes, "steps": steps, "seq_len": SEQ_LEN},
        resume_steps=0, timeout=600,
    )
    if not tx_result.ok:
        raise RuntimeError(
            f"compressed-transport run failed: rc={tx_result.returncodes}; "
            f"logs under {tx_result.run_dir}"
        )
    tx_recs = tx_result.records
    tx_identical = (
        len({r["param_digest"] for r in tx_recs}) == 1
        and all(r["losses"] == tx_recs[0]["losses"] for r in tx_recs)
    )
    tx_info = tx_recs[0]["transport"]
    tx_ratio = tx_info["compression_ratio"]
    tx_loss = tx_recs[0]["losses"][-1]
    tx_tracks = abs(tx_loss - cluster_losses[-1]) <= (
        TX_LOSS_RTOL * abs(cluster_losses[-1])
    )

    # the saved-at-N checkpoint restores at ONE process and stays on curve
    restored = demo_session_factory(
        processes=1, steps=steps + RESUME, seq_len=SEQ_LEN,
        checkpoint_dir=ckpt_dir,
    )
    rep = restored.run()
    restore_losses = [h["loss"] for h in rep.history]
    match_restore = (
        rep.start_step == steps
        and np.allclose(single_losses[steps:], restore_losses, rtol=1e-4)
    )

    out = {
        "processes": float(processes),
        "global_devices": float(recs[0]["global_devices"]),
        "data_axis": float(recs[0]["data_axis"]),
        "local_fraction": recs[0]["receipt"]["local_fraction"],
        "addressable_only": float(addressable_only),
        "custody_local_only": float(custody_local_only),
        "feed_bytes_exact": float(feed_exact),
        "replicas_agree": float(replicas_agree),
        "one_compile_across_drift": float(one_compile),
        "matches_single_process": float(match_train and match_resume),
        "restore_at_one_process": float(match_restore),
        "chunked_save_ok": float(all(
            bool(r["chunked_save_ok"]) for r in recs
            if r["chunked_save_ok"] is not None
        )),
        "replicas_identical": float(
            len({r["param_digest"] for r in recs}) == 1
        ),
        "tx_replicas_identical": float(tx_identical),
        "tx_compression_ratio": float(tx_ratio),
        "tx_loss_tracks_uncompressed": float(tx_tracks),
        "tx_topology_ring": float(tx_info["topology"] == "ring"),
        "loss_start": cluster_losses[0],
        "loss_end": (resumed or cluster_losses)[-1],
    }
    if verbose:
        print(f"\n== Cluster smoke [{processes} proc x "
              f"{local_devices} dev] ==")
        for k, v in out.items():
            print(f"  {k:>24s}: {v:.4f}")
    return out


def _checks(m: Dict[str, float]) -> Dict[str, bool]:
    return {
        "spans_processes": m["global_devices"] > 4 and m["data_axis"] > 1,
        "addressable_only": m["addressable_only"] == 1.0,
        "custody_local_only": m["custody_local_only"] == 1.0,
        # each host moved EXACTLY its row-slab bytes, nothing more
        "no_cross_host_batch_bytes": (
            m["feed_bytes_exact"] == 1.0 and m["local_fraction"] < 1.0
        ),
        "replicas_agree": m["replicas_agree"] == 1.0,
        "one_compile_across_drift": m["one_compile_across_drift"] == 1.0,
        "matches_single_process": m["matches_single_process"] == 1.0,
        "restore_at_one_process": m["restore_at_one_process"] == 1.0,
        "chunked_single_writer_save": m["chunked_save_ok"] == 1.0,
        "replicas_bit_identical": m["replicas_identical"] == 1.0,
        "tx_replicas_bit_identical": m["tx_replicas_identical"] == 1.0,
        "tx_compresses_3x": m["tx_compression_ratio"] >= 3.0,
        "tx_loss_tracks_uncompressed": (
            m["tx_loss_tracks_uncompressed"] == 1.0
        ),
        "tx_ring_topology": m["tx_topology_ring"] == 1.0,
        "losses_finite": bool(np.isfinite(m["loss_end"])),
    }


def validate(processes: int = 4, steps: int = STEPS,
             local_devices: int = None) -> Dict[str, bool]:
    return _checks(run(verbose=True, processes=processes, steps=steps,
                       local_devices=local_devices))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--processes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=STEPS)
    ap.add_argument("--local-devices", type=int, default=None,
                    help="devices per process (default: 8 // processes)")
    args = ap.parse_args()
    checks = validate(processes=args.processes, steps=args.steps,
                      local_devices=args.local_devices)
    print("checks:", checks)
    sys.exit(0 if all(checks.values()) else 1)
