"""Step/init benchmark: the sharding-explicit execution path, measured.

Emits ``BENCH_step.json`` — the perf trajectory anchor for the compiled
step.  For each storage backend (``synthetic`` host-delivery vs ``meshfeed``
mesh-sharded) on an 8-fake-device CPU mesh it records:

  * ``steps_per_s``       — steady-state training throughput (post-warmup)
  * ``compile_count``     — must be 1 per session (the no-recompile probe)
  * ``init_h2d_bytes``    — host->device bytes moved materializing the model
    state.  The jitted ``out_shardings``-directed init is proven to move
    ZERO bytes by running under ``jax.transfer_guard("disallow")`` (the PRNG
    seed is created outside the guard); ``host_init_bytes`` records what the
    legacy host-init + replicate path would have staged (params + opt).
  * ``step_h2d_bytes``    — host bytes fed per training step (the batch)
  * ``data_axis`` / ``n_devices`` — the plan's mesh

Usage:
    PYTHONPATH=src python benchmarks/bench_step.py [--steps 8] [--out BENCH_step.json]
"""
from __future__ import annotations

import os

# MUST run before any jax import: jax locks the device count on first init.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import sys
import time
from typing import Dict

import jax
import numpy as np

from repro.api import FleetSpec, Session, SessionConfig
from repro.configs import smoke_config
from repro.models.api import get_model
from repro.models.param import param_bytes
from repro.optim import adamw
from repro.storage import DataConfig

ARCH = "deepseek-7b"
SEQ_LEN = 16
WARMUP = 2


def _session(backend: str, steps: int) -> Session:
    cfg = smoke_config(ARCH)
    spec = FleetSpec.demo(n_csds=3).with_storage(backend)
    return Session(
        model=get_model(cfg),
        optimizer=adamw(),
        fleet=spec,
        data=DataConfig(vocab=cfg.vocab, seq_len=SEQ_LEN),
        shards=spec.shards(private_per_worker={"csd": 64}, public=4096),
        config=SessionConfig(total_steps=steps),
    )


def bench_one(backend: str, steps: int) -> Dict:
    s = _session(backend, steps)
    compiled = s.compile()
    plan = s.shard()

    # -- init: jitted + out_shardings-directed => zero host->device bytes.
    # The transfer guard turns any host staging into a hard error, so the
    # number below is measured, not asserted by construction.  (Only the
    # host->device direction is guarded: replicating the 8-byte PRNG key
    # across the mesh is a device->device copy and perfectly fine.)
    key = jax.random.PRNGKey(0)                 # the seed moves outside
    t0 = time.perf_counter()
    try:
        with jax.transfer_guard_host_to_device("disallow"):
            params, opt_state = s.init_state(plan, key=key)
        jax.block_until_ready(jax.tree_util.tree_leaves(params)[0])
        init_h2d = 0
    except Exception as e:                      # pragma: no cover - regression
        params, opt_state = s.init_state(plan, key=key)
        init_h2d = -1                           # unknown: guard tripped
        print(f"[bench] transfer guard tripped during init: {e}", file=sys.stderr)
    init_s = time.perf_counter() - t0

    p_bytes = param_bytes(params)
    opt_bytes = sum(
        int(np.prod(l.shape)) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(opt_state)
    )

    # -- steady-state step throughput (the step consumes the fleet batches)
    dataset = s.dataset
    host_batch = {
        k: v for k, v in dataset.next_batch().items()
        if k in ("tokens", "labels", "loss_mask")
    }
    step_h2d = sum(int(v.nbytes) for v in host_batch.values())

    for _ in range(WARMUP):
        batch = dataset.next_device_batch()
        params, opt_state, metrics = compiled.step_fn(params, opt_state, batch)
    jax.block_until_ready(metrics["loss"])
    t0 = time.perf_counter()
    for _ in range(steps):
        batch = dataset.next_device_batch()
        params, opt_state, metrics = compiled.step_fn(params, opt_state, batch)
    jax.block_until_ready(metrics["loss"])
    dt = time.perf_counter() - t0

    return {
        "backend": backend,
        "arch": ARCH,
        "steps": steps,
        "n_processes": 1,
        "steps_per_s": round(steps / dt, 3),
        "compile_count": s.compile_count,
        "init_s": round(init_s, 4),
        "init_h2d_bytes": init_h2d,
        "host_init_bytes": p_bytes + opt_bytes,   # what replicate-from-host moves
        "param_bytes": p_bytes,
        "step_h2d_bytes": step_h2d,
        "global_rows": plan.global_rows,
        "data_axis": plan.data_axis,
        "n_devices": plan.n_devices,
        "loss_final": float(metrics["loss"]),
    }


def bench_cluster(steps: int, processes: int = 2, local_devices: int = 4) -> Dict:
    """The multi-PROCESS record: N worker processes, one global mesh,
    per-host addressable feeding, coordinator-summed gradients (hostsync on
    CPU).  Throughput is the slowest worker's — the cluster steps at the
    barrier's pace."""
    from repro.core.topology import ClusterSpec
    from repro.launch.cluster import run_cluster

    result = run_cluster(
        ClusterSpec(processes=processes, local_devices=local_devices),
        "repro.launch.cluster:demo_session_factory",
        {"processes": processes, "n_csds": 3, "steps": steps,
         "seq_len": SEQ_LEN, "arch": ARCH},
        resume_steps=0,
        timeout=900,
    )
    if not result.ok:
        raise RuntimeError(
            f"cluster bench failed: rc={result.returncodes} "
            f"(logs under {result.run_dir})"
        )
    recs = result.records
    r0 = result.record(0)
    return {
        "backend": "cluster",
        "arch": ARCH,
        "steps": steps,
        "n_processes": processes,
        "mode": r0["mode"],
        "steps_per_s": min(r["steps_per_s"] for r in recs),
        "compile_count": max(r["compile_count"] for r in recs),
        "feed_bytes_per_step": sum(
            r["receipt"]["bytes_put"] for r in recs if r["receipt"]
        ),
        "addressable_only": all(r["addressable_only"] for r in recs),
        "local_fraction": r0["receipt"]["local_fraction"] if r0["receipt"] else 1.0,
        "global_rows": r0["global_rows"],
        "data_axis": r0["data_axis"],
        "n_devices": r0["global_devices"],
        "loss_final": float(recs[0]["losses"][-1]),
        "losses_agree": all(
            abs(a - b) < 1e-6
            for a, b in zip(recs[0]["losses"], recs[-1]["losses"])
        ),
    }


def run(steps: int = 8, out: str = "BENCH_step.json", verbose: bool = True,
        cluster: bool = True):
    records = [bench_one(b, steps) for b in ("synthetic", "meshfeed")]
    if cluster:
        records.append(bench_cluster(steps))
    payload = {
        "bench": "step",
        "device_count": len(jax.devices()),
        "records": records,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    if verbose:
        for r in records:
            if r["backend"] == "cluster":
                print(
                    f"[{r['backend']:>9s}] {r['steps_per_s']:6.2f} steps/s  "
                    f"compiles={r['compile_count']}  "
                    f"procs={r['n_processes']} ({r['mode']})  "
                    f"feed={r['feed_bytes_per_step']:,}B/step "
                    f"addressable_only={r['addressable_only']}  "
                    f"data_axis={r['data_axis']}/{r['n_devices']}dev"
                )
                continue
            print(
                f"[{r['backend']:>9s}] {r['steps_per_s']:6.2f} steps/s  "
                f"compiles={r['compile_count']}  "
                f"init h2d={r['init_h2d_bytes']}B "
                f"(host path would move {r['host_init_bytes']:,}B)  "
                f"batch h2d={r['step_h2d_bytes']:,}B/step  "
                f"data_axis={r['data_axis']}/{r['n_devices']}dev"
            )
        print(f"wrote {out}")
    return payload


def _checks(payload: Dict) -> Dict[str, bool]:
    recs = payload["records"]
    cluster = [r for r in recs if r["backend"] == "cluster"]
    return {
        "one_compile_each": all(r["compile_count"] == 1 for r in recs),
        "init_moves_zero_bytes": all(
            r["init_h2d_bytes"] == 0 for r in recs if "init_h2d_bytes" in r
        ),
        "meshfeed_multidevice": any(
            r["backend"] == "meshfeed" and r["data_axis"] > 1 for r in recs
        ) or payload["device_count"] == 1,
        "losses_finite": all(np.isfinite(r["loss_final"]) for r in recs),
        "cluster_addressable_only": all(
            r["addressable_only"] for r in cluster
        ),
        "cluster_replicas_agree": all(r["losses_agree"] for r in cluster),
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--out", default="BENCH_step.json")
    ap.add_argument("--no-cluster", action="store_true",
                    help="skip the 2-process cluster record")
    args = ap.parse_args()
    payload = run(steps=args.steps, out=args.out, cluster=not args.no_cluster)
    checks = _checks(payload)
    print("checks:", checks)
    sys.exit(0 if all(checks.values()) else 1)
