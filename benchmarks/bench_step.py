"""Step/init benchmark: the sharding-explicit execution path, measured.

Emits ``BENCH_step.json`` — the perf trajectory anchor for the compiled
step.  For each storage backend (``synthetic`` host-delivery vs ``meshfeed``
mesh-sharded) on an 8-fake-device CPU mesh it records:

  * ``steps_per_s``       — steady-state training throughput (post-warmup)
  * ``compile_count``     — must be 1 per session (the no-recompile probe)
  * ``init_h2d_bytes``    — host->device bytes moved materializing the model
    state.  The jitted ``out_shardings``-directed init is proven to move
    ZERO bytes by running under ``jax.transfer_guard("disallow")`` (the PRNG
    seed is created outside the guard); ``host_init_bytes`` records what the
    legacy host-init + replicate path would have staged (params + opt).
  * ``step_h2d_bytes``    — host bytes fed per training step (the batch)
  * ``data_axis`` / ``n_devices`` — the plan's mesh

Beyond the two backend records it benches per-arch hot paths: the MoE model
with the fused Pallas dispatch kernel vs the dense gather/scatter path (the
A/B for the fusion work), the rwkv6 linear-recurrence arch, and the flash
backend at both spool codecs (``spool_bytes`` records the at-rest payload —
the narrow codec writes ~4x less).  The ``dense-int8`` / ``moe-int8``
records run the same problems with ``train_precision="int8-fused"`` (the
in-kernel low-precision path); their ``residual_bytes`` /
``residual_bytes_f32`` fields price the saved-for-backward memory both ways
(eval_shape only) — the A/B for the quantized-kernel work.

Cluster records measure the multi-process transport: the legacy
star/uncompressed baseline (``cluster``), the production int8 ring with
overlap on the same problem (``cluster-tx``), and — with ``--scaling`` —
the {1,2,4,8}-process curve (``cluster-pN``, n_csds=7, production
transport).  ``steps_per_s`` for cluster records is the slowest worker's
STEADY-STATE rate (post-jit-warmup); ``steps_per_s_wall`` keeps the old
steps/total-wall metric for continuity.

``--compare SNAPSHOT`` re-runs the bench and exits nonzero if any record
regresses more than 25% in ``steps_per_s`` vs the committed snapshot —
the CI throughput gate.  Cluster records gate too, at a looser 50%:
barrier-paced subprocess throughput on a shared core is noisy, but a
halving still means the transport broke.

Usage:
    PYTHONPATH=src python benchmarks/bench_step.py [--steps 8] [--out BENCH_step.json]
    PYTHONPATH=src python benchmarks/bench_step.py --compare BENCH_step.json
"""
from __future__ import annotations

import os

# MUST run before any jax import: jax locks the device count on first init.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import sys
import time
from typing import Dict

import jax
import numpy as np

from repro.api import FleetSpec, Session, SessionConfig
from repro.configs import smoke_config
from repro.models.api import get_model
from repro.models.param import param_bytes
from repro.optim import adamw
from repro.storage import DataConfig

ARCH = "deepseek-7b"
SEQ_LEN = 16
WARMUP = 2


def _session(backend: str, steps: int, arch: str = ARCH,
             codec: str = None, precision: str = None) -> Session:
    cfg = smoke_config(arch)
    if precision is not None:
        cfg = cfg.with_(train_precision=precision)
    storage_kw = {"codec": codec} if codec else {}
    spec = FleetSpec.demo(n_csds=3).with_storage(backend, **storage_kw)
    return Session(
        model=get_model(cfg),
        optimizer=adamw(),
        fleet=spec,
        data=DataConfig(vocab=cfg.vocab, seq_len=SEQ_LEN),
        shards=spec.shards(private_per_worker={"csd": 64}, public=4096),
        config=SessionConfig(total_steps=steps),
    )


def bench_one(backend: str, steps: int, *, arch: str = ARCH,
              name: str = None, moe_impl: str = None,
              codec: str = None, precision: str = None) -> Dict:
    """One throughput record.  ``moe_impl`` forces the MoE dispatch path
    (the fused-vs-dense A/B); ``codec`` selects the flash spool width;
    ``precision`` sets ``train_precision`` (the int8-fused A/B)."""
    from repro.models import moe as moe_mod

    saved_impl = moe_mod.MOE_IMPL
    if moe_impl is not None:
        moe_mod.MOE_IMPL = moe_impl
    try:
        return _bench_one_inner(backend, steps, arch=arch, name=name,
                                moe_impl=moe_impl, codec=codec,
                                precision=precision)
    finally:
        moe_mod.MOE_IMPL = saved_impl


def _bench_one_inner(backend: str, steps: int, *, arch: str,
                     name: str, moe_impl: str, codec: str,
                     precision: str = None) -> Dict:
    s = _session(backend, steps, arch=arch, codec=codec, precision=precision)
    compiled = s.compile()
    plan = s.shard()

    # -- init: jitted + out_shardings-directed => zero host->device bytes.
    # The transfer guard turns any host staging into a hard error, so the
    # number below is measured, not asserted by construction.  (Only the
    # host->device direction is guarded: replicating the 8-byte PRNG key
    # across the mesh is a device->device copy and perfectly fine.)
    key = jax.random.PRNGKey(0)                 # the seed moves outside
    t0 = time.perf_counter()
    try:
        with jax.transfer_guard_host_to_device("disallow"):
            params, opt_state = s.init_state(plan, key=key)
        jax.block_until_ready(jax.tree_util.tree_leaves(params)[0])
        init_h2d = 0
    except Exception as e:                      # pragma: no cover - regression
        params, opt_state = s.init_state(plan, key=key)
        init_h2d = -1                           # unknown: guard tripped
        print(f"[bench] transfer guard tripped during init: {e}", file=sys.stderr)
    init_s = time.perf_counter() - t0

    p_bytes = param_bytes(params)
    opt_bytes = sum(
        int(np.prod(l.shape)) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(opt_state)
    )

    # -- steady-state step throughput (the step consumes the fleet batches)
    dataset = s.dataset
    host_batch = {
        k: v for k, v in dataset.next_batch().items()
        if k in ("tokens", "labels", "loss_mask")
    }
    step_h2d = sum(int(v.nbytes) for v in host_batch.values())

    for _ in range(WARMUP):
        batch = dataset.next_device_batch()
        params, opt_state, metrics = compiled.step_fn(params, opt_state, batch)
    jax.block_until_ready(metrics["loss"])
    t0 = time.perf_counter()
    for _ in range(steps):
        batch = dataset.next_device_batch()
        params, opt_state, metrics = compiled.step_fn(params, opt_state, batch)
    jax.block_until_ready(metrics["loss"])
    dt = time.perf_counter() - t0

    rec = {
        "name": name or backend,
        "backend": backend,
        "arch": arch,
        "steps": steps,
        "n_processes": 1,
        "steps_per_s": round(steps / dt, 3),
        "compile_count": s.compile_count,
        "init_s": round(init_s, 4),
        "init_h2d_bytes": init_h2d,
        "host_init_bytes": p_bytes + opt_bytes,   # what replicate-from-host moves
        "param_bytes": p_bytes,
        "step_h2d_bytes": step_h2d,
        "global_rows": plan.global_rows,
        "data_axis": plan.data_axis,
        "n_devices": plan.n_devices,
        "loss_final": float(metrics["loss"]),
    }
    if moe_impl is not None:
        rec["moe_impl"] = moe_impl
    if precision is not None:
        # price the saved-for-backward residuals at this precision vs f32
        # (remat/scan off: the raw footprint is what int8-fused shrinks —
        # eval_shape only, nothing is allocated)
        from repro.train.steps import abstract_batch, residual_bytes

        base = smoke_config(arch).with_(remat=False, scan_layers=False)
        batch_abs = abstract_batch(plan.global_rows, SEQ_LEN)
        rec["train_precision"] = precision
        rec["residual_bytes"] = residual_bytes(
            get_model(base.with_(train_precision=precision)), batch_abs)
        rec["residual_bytes_f32"] = residual_bytes(get_model(base), batch_abs)
    if backend == "flash":
        # bytes each device wrote to its own flash (the paper's at-rest cost)
        devices = list(s.devices)
        rec["codec"] = devices[0].codec if devices else codec
        rec["spool_bytes"] = sum(
            getattr(d, "spooled_bytes", 0) for d in devices
        )
    return rec


def bench_cluster(steps: int, processes: int = 2, local_devices: int = 4,
                  *, n_csds: int = 3, transport: Dict = None,
                  name: str = "cluster", timeout: float = 900.0) -> Dict:
    """One multi-PROCESS record: N worker processes, one global mesh,
    per-host addressable feeding, transport-reduced gradients (hostsync on
    CPU).  Throughput is the slowest worker's steady-state rate (post-jit
    warmup) — the cluster steps at the barrier's pace.  ``transport`` is a
    ``TransportSpec`` kwargs dict (compression / overlap / topology)."""
    from repro.core.topology import ClusterSpec
    from repro.launch.cluster import run_cluster

    spec_kw = {"transport": transport} if transport else {}
    result = run_cluster(
        ClusterSpec(processes=processes, local_devices=local_devices,
                    **spec_kw),
        "repro.launch.cluster:demo_session_factory",
        {"processes": processes, "n_csds": n_csds, "steps": steps,
         "seq_len": SEQ_LEN, "arch": ARCH},
        resume_steps=0,
        timeout=timeout,
    )
    if not result.ok:
        raise RuntimeError(
            f"cluster bench {name!r} failed: rc={result.returncodes} "
            f"(logs under {result.run_dir})"
        )
    recs = result.records
    r0 = result.record(0)
    rec = {
        "name": name,
        "backend": "cluster",
        "arch": ARCH,
        "steps": steps,
        "n_processes": processes,
        "mode": r0["mode"],
        "steps_per_s": min(r["steps_per_s"] for r in recs),
        "steps_per_s_wall": min(r["steps_per_s_wall"] for r in recs),
        "compile_count": max(r["compile_count"] for r in recs),
        "feed_bytes_per_step": sum(
            r["receipt"]["bytes_put"] for r in recs if r["receipt"]
        ),
        "addressable_only": all(r["addressable_only"] for r in recs),
        "local_fraction": r0["receipt"]["local_fraction"] if r0["receipt"] else 1.0,
        "global_rows": r0["global_rows"],
        "data_axis": r0["data_axis"],
        "n_devices": r0["global_devices"],
        "loss_final": float(recs[0]["losses"][-1]),
        "losses_agree": all(
            abs(a - b) < 1e-6
            for a, b in zip(recs[0]["losses"], recs[-1]["losses"])
        ),
        # bit-identical replicas: sha256 over every param leaf must match
        "digests_identical": len(
            {r.get("param_digest") for r in recs}
        ) == 1,
    }
    if r0.get("transport"):
        t = r0["transport"]
        rec["transport"] = {
            "topology": t["topology"],
            "compression": t["spec"]["compression"],
            "buckets": t["spec"]["buckets"],
            "overlap": t["spec"]["overlap"],
            "compression_ratio": t.get("compression_ratio"),
            "wire_s_per_step": t.get("wire_s_per_step"),
            "encode_s_per_step": t.get("encode_s_per_step"),
        }
    return rec


# the production transport used by the scaling-curve records
_TX = {"compression": "int8", "buckets": 2, "overlap": True,
       "topology": "ring"}


def bench_scaling(steps: int) -> list:
    """The {1,2,4,8}-process scaling curve: same global problem (n_csds=7
    -> 8 dp-groups, 8 global devices), production transport, each process
    holding 8/P devices.  On a single-core host this measures transport +
    barrier overhead, not parallel speedup — the curve's value is the
    TREND across PRs, and that replicas stay bit-identical at every width.
    The 8-process point oversubscribes one core heavily; its generous
    timeout absorbs worker startup skew."""
    out = []
    for procs in (1, 2, 4, 8):
        out.append(bench_cluster(
            steps, processes=procs, local_devices=8 // procs,
            n_csds=7, transport=dict(_TX, timeout=600.0),
            name=f"cluster-p{procs}",
            timeout=1800.0 if procs == 8 else 900.0,
        ))
    return out


def run(steps: int = 8, out: str = "BENCH_step.json", verbose: bool = True,
        cluster: bool = True, scaling: bool = False):
    records = [
        bench_one("synthetic", steps),
        bench_one("meshfeed", steps),
        # fused-vs-dense MoE dispatch A/B (same arch, same data)
        bench_one("synthetic", steps, arch="qwen3-moe-30b-a3b",
                  moe_impl="fused", name="moe-fused"),
        bench_one("synthetic", steps, arch="qwen3-moe-30b-a3b",
                  moe_impl="dense", name="moe-dense"),
        # int8-fused in-kernel training A/B vs the f32 records above; the
        # residual_bytes fields carry the memory side of the trade
        bench_one("synthetic", steps, precision="int8-fused",
                  name="dense-int8"),
        bench_one("synthetic", steps, arch="qwen3-moe-30b-a3b",
                  moe_impl="fused", precision="int8-fused", name="moe-int8"),
        bench_one("synthetic", steps, arch="rwkv6-7b", name="rwkv6"),
        # flash spool width A/B: same samples, 4x fewer bytes at rest
        bench_one("flash", steps, codec="i32", name="flash-i32"),
        bench_one("flash", steps, codec="auto", name="flash-auto"),
    ]
    if cluster:
        # legacy star/uncompressed record (the transport A/B baseline) and
        # the production transport on the same 2-process problem
        records.append(bench_cluster(steps))
        records.append(bench_cluster(
            steps, transport=_TX, name="cluster-tx"))
    if scaling:
        records.extend(bench_scaling(steps))
    payload = {
        "bench": "step",
        "device_count": len(jax.devices()),
        "records": records,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    if verbose:
        for r in records:
            if r["backend"] == "cluster":
                tx = r.get("transport")
                txs = (
                    f"  tx={tx['topology']}/{tx['compression']}"
                    f" x{tx['compression_ratio']:.1f}"
                    if tx else "  tx=star/none"
                )
                print(
                    f"[{r['name']:>10s}] {r['steps_per_s']:6.2f} steps/s  "
                    f"compiles={r['compile_count']}  "
                    f"procs={r['n_processes']} ({r['mode']})  "
                    f"feed={r['feed_bytes_per_step']:,}B/step "
                    f"identical={r['digests_identical']}  "
                    f"data_axis={r['data_axis']}/{r['n_devices']}dev{txs}"
                )
                continue
            extra = ""
            if "spool_bytes" in r:
                extra = f"  spool={r['spool_bytes']:,}B ({r['codec']})"
            if "residual_bytes" in r:
                extra = (
                    f"  resid={r['residual_bytes']:,}B "
                    f"(f32 {r['residual_bytes_f32']:,}B)"
                )
            print(
                f"[{r['name']:>10s}] {r['steps_per_s']:6.2f} steps/s  "
                f"compiles={r['compile_count']}  "
                f"init h2d={r['init_h2d_bytes']}B "
                f"(host path would move {r['host_init_bytes']:,}B)  "
                f"batch h2d={r['step_h2d_bytes']:,}B/step  "
                f"data_axis={r['data_axis']}/{r['n_devices']}dev{extra}"
            )
        print(f"wrote {out}")
    return payload


def compare(payload: Dict, snapshot, threshold: float = 0.25,
            cluster_threshold: float = 0.5):
    """Gate against a committed snapshot (path or loaded payload): any record
    whose ``steps_per_s`` drops more than its threshold below the snapshot's
    is a regression.  Cluster records gate too, but at the looser
    ``cluster_threshold`` — their throughput is barrier-paced across worker
    subprocesses on a shared core and carries scheduler noise a single-
    process record doesn't."""
    if isinstance(snapshot, str):
        with open(snapshot) as f:
            old = json.load(f)
    else:
        old = snapshot
    old_by = {r.get("name", r["backend"]): r for r in old["records"]}
    regressions = []
    for r in payload["records"]:
        key = r.get("name", r["backend"])
        thr = cluster_threshold if r["backend"] == "cluster" else threshold
        o = old_by.get(key)
        if o is None:
            print(f"[compare] {key:>10s} (new record — no baseline)")
            continue
        floor = o["steps_per_s"] * (1.0 - thr)
        ok = r["steps_per_s"] >= floor
        print(
            f"[compare] {key:>10s} {o['steps_per_s']:8.2f} -> "
            f"{r['steps_per_s']:8.2f} steps/s  "
            f"({'ok' if ok else f'REGRESSED below {floor:.2f}'})"
        )
        if not ok:
            regressions.append(key)
    return regressions


def _checks(payload: Dict) -> Dict[str, bool]:
    recs = payload["records"]
    cluster = [r for r in recs if r["backend"] == "cluster"]
    return {
        "one_compile_each": all(r["compile_count"] == 1 for r in recs),
        "init_moves_zero_bytes": all(
            r["init_h2d_bytes"] == 0 for r in recs if "init_h2d_bytes" in r
        ),
        "meshfeed_multidevice": any(
            r["backend"] == "meshfeed" and r["data_axis"] > 1 for r in recs
        ) or payload["device_count"] == 1,
        "losses_finite": all(np.isfinite(r["loss_final"]) for r in recs),
        "cluster_addressable_only": all(
            r["addressable_only"] for r in cluster
        ),
        "cluster_replicas_agree": all(r["losses_agree"] for r in cluster),
        "cluster_replicas_identical": all(
            r["digests_identical"] for r in cluster
        ),
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--out", default="BENCH_step.json")
    ap.add_argument("--no-cluster", action="store_true",
                    help="skip the 2-process cluster records")
    ap.add_argument("--scaling", action="store_true",
                    help="also run the {1,2,4,8}-process scaling curve "
                         "(slow — used when regenerating the snapshot)")
    ap.add_argument("--compare", metavar="SNAPSHOT",
                    help="gate against a committed BENCH_step.json: exit "
                         "nonzero if any record regresses >25%% in steps/s "
                         "(cluster records gate at 50%% — barrier noise)")
    args = ap.parse_args()
    # load the snapshot BEFORE run() — --out may overwrite the same file
    snapshot = None
    if args.compare:
        with open(args.compare) as f:
            snapshot = json.load(f)
    payload = run(steps=args.steps, out=args.out,
                  cluster=not args.no_cluster, scaling=args.scaling)
    checks = _checks(payload)
    print("checks:", checks)
    rc = 0 if all(checks.values()) else 1
    if snapshot is not None:
        regressions = compare(payload, snapshot)
        if regressions:
            print(f"REGRESSIONS: {regressions}")
            rc = 1
    sys.exit(rc)
