"""§V-C reproduction: heterogeneous-distributed vs single-node accuracy parity.

Paper: 416k images, 1 node vs 6 nodes; loss 1.1859 -> 1.1907 (+0.5%), same
accuracy (0.31).  The claim under test: *heterogeneous distribution with
tuned unequal batch sizes does not degrade training quality* when the LR
follows the Goyal linear-scaling + warmup rule.

Our version, on a real LM (reduced deepseek-7b): train the SAME total token
budget (a) single-group, (b) 3 heterogeneous groups (tuned 8/2/2 split via
the masked-union batch).  The theory (tests/test_hetero.py) says the GRADIENTS
are identical when the union batch matches; here the union batches differ per
step (different data order) so we verify the final-loss gap stays < 2%.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core.hetero import BatchSchedule
from repro.storage import DataConfig, synth_sequence
from repro.models.api import get_model
from repro.optim import sgd_momentum
from repro.optim.schedules import goyal_schedule
from repro.train.steps import make_train_step

import numpy as np

SEQ = 32
STEPS = 60
VALID_PER_STEP = 12     # union batch size in both setups


def _make_batch(dcfg, sched: BatchSchedule, step: int):
    """Group-major masked batch; all groups read one shared stream."""
    R, S = sched.global_rows, dcfg.seq_len
    toks = np.zeros((R, S + 1), np.int32)
    mask = sched.row_mask()
    ml = sched.max_local
    i = 0
    for g, b in enumerate(sched.group_batches):
        for r in range(b):
            toks[g * ml + r] = synth_sequence(dcfg, "shared", step * VALID_PER_STEP + i)
            i += 1
    return {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
        "loss_mask": jnp.asarray(mask[:, None] * np.ones((1, S), np.float32)),
    }


def _train(sched: BatchSchedule, seed: int = 0) -> float:
    cfg = smoke_config("deepseek-7b")
    model = get_model(cfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=SEQ, seed=7)
    params, _ = model.init_params(key=jax.random.PRNGKey(seed))
    opt = sgd_momentum(momentum=0.9)
    lr = goyal_schedule(3e-2, sched.valid_rows, base_batch=VALID_PER_STEP,
                        warmup_steps=10, total_steps=STEPS)
    step_fn = jax.jit(make_train_step(model, opt, lr))
    state = opt.init(params)
    losses = []
    for i in range(STEPS):
        batch = _make_batch(dcfg, sched, i)
        params, state, m = step_fn(params, state, batch)
        losses.append(float(m["loss"]))
    return float(np.mean(losses[-10:]))


def run(verbose: bool = True) -> Dict[str, float]:
    single = _train(BatchSchedule((VALID_PER_STEP,)))
    hetero = _train(BatchSchedule((8, 2, 2)))
    gap = abs(hetero - single) / single
    if verbose:
        print("\n== §V-C: accuracy parity (single vs heterogeneous) ==")
        print(f"single-group final loss : {single:.4f}")
        print(f"hetero (8/2/2) final    : {hetero:.4f}")
        print(f"relative gap            : {gap:.2%} (paper: 0.5%; gate: <2%)")
    return {"single": single, "hetero": hetero, "gap": gap, "ok": gap < 0.02}


if __name__ == "__main__":
    print(run())
