"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps on
CPU with the full Stannis pipeline (tune -> balance -> place -> train), with
checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_100m.py --steps 300

Runtime note: on a single CPU core expect ~2 min of XLA compile plus a few
seconds per step at the default seq 64 (use --seq 128 --steps 300 for the
full run on a real machine).
"""
import argparse
import time

import jax

from repro.core.privacy import Shard
from repro.core.topology import Fleet, WorkerClass
from repro.data.pipeline import DataConfig
from repro.models.api import get_model
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # ~100M params: 12L x 768 (GPT-small-ish geometry, llama-style blocks)
    cfg = ModelConfig(
        name="dense-100m", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=2048, vocab=32768, scan_layers=True, remat=False,
    )
    model = get_model(cfg)
    print(f"params: {cfg.param_count():,}")

    fleet = Fleet(classes=(
        WorkerClass("host", 1, 50.0, 8, max_batch=8, active_power=400.0),
        WorkerClass("csd", 2, 12.0, 2, max_batch=2, active_power=7.0),
    ))
    shards = [
        Shard("private-csd/0", 512, True, "csd/0"),
        Shard("private-csd/1", 512, True, "csd/1"),
        Shard("public", 1 << 20, False),
    ]
    trainer = Trainer(
        model=model,
        optimizer=adamw(weight_decay=0.01),
        fleet=fleet,
        data_cfg=DataConfig(vocab=cfg.vocab, seq_len=args.seq),
        cfg=TrainerConfig(
            total_steps=args.steps,
            base_lr=3e-4,
            warmup_steps=30,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=100,
            async_checkpoint=True,
        ),
        shards=shards,
    ).setup()

    print("tuned:", trainer.tune_result.batches,
          "| schedule:", trainer.schedule.group_batches,
          "| epoch:", trainer.plan.steps_per_epoch, "steps")
    t0 = time.time()
    _, hist = trainer.train(
        on_metrics=lambda i, m: print(
            f"  step {i:4d}  loss {m['loss']:.4f}  lr {m['lr']:.2e}  "
            f"{m['step_time']*1e3:.0f} ms"
        ) if i % 25 == 0 else None
    )
    dt = time.time() - t0
    tok_s = sum(h["tokens"] for h in hist) / dt
    print(f"done: {len(hist)} steps in {dt:.0f}s ({tok_s:,.0f} tok/s); "
          f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
