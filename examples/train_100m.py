"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps on
CPU with the full Stannis pipeline (tune -> balance -> place -> train), with
checkpoint/restart fault tolerance — all through the Session API.

    PYTHONPATH=src python examples/train_100m.py --steps 300

Runtime note: on a single CPU core expect ~2 min of XLA compile plus a few
seconds per step at the default seq 64 (use --seq 128 --steps 300 for the
full run on a real machine).
"""
import argparse

from repro.api import FleetSpec, Session, SessionConfig
from repro.storage import DataConfig
from repro.models.api import get_model
from repro.models.config import ModelConfig
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # ~100M params: 12L x 768 (GPT-small-ish geometry, llama-style blocks)
    cfg = ModelConfig(
        name="dense-100m", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=2048, vocab=32768, scan_layers=True, remat=False,
    )
    model = get_model(cfg)
    print(f"params: {cfg.param_count():,}")

    spec = FleetSpec.demo(
        n_csds=2, host_tput=50.0, csd_tput=12.0,
        host_max_batch=8, csd_max_batch=2,
    )
    session = Session(
        model=model,
        optimizer=adamw(weight_decay=0.01),
        fleet=spec,
        data=DataConfig(vocab=cfg.vocab, seq_len=args.seq),
        shards=spec.shards(private_per_worker={"csd": 512}, public=1 << 20),
        config=SessionConfig(
            total_steps=args.steps,
            base_lr=3e-4,
            warmup_steps=30,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=100,
            async_checkpoint=True,
        ),
    )

    tune_plan = session.tune()
    print("tuned:", tune_plan.batches,
          "| schedule:", tune_plan.schedule.group_batches,
          "| epoch:", session.plan().steps_per_epoch, "steps")

    session.callbacks.on_step(
        lambda i, m: print(
            f"  step {i:4d}  loss {m['loss']:.4f}  lr {m['lr']:.2e}  "
            f"{m['step_time']*1e3:.0f} ms"
        ) if i % 25 == 0 else None
    )
    report = session.run()
    hist = report.history
    tok_s = sum(h["tokens"] for h in hist) / max(report.wall_time, 1e-9)
    print(f"done: {report.steps_run} steps in {report.wall_time:.0f}s "
          f"({tok_s:,.0f} tok/s); "
          f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
