"""Batched serving example: prefill + KV-cache decode across architectures,
including the recurrent (O(1)-state) families.

    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-7b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_config
from repro.models.api import get_model
from repro.train.steps import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=ARCHS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = model.init_params(key=key)

    B, P, N = args.batch, args.prompt_len, args.tokens
    cache_len = P + N + 1
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["frames"] = jax.random.normal(key, (B, cfg.n_frames, cfg.d_model))
    elif cfg.family == "vlm":
        kwargs["patch_embeds"] = jax.random.normal(key, (B, 4, cfg.d_model))

    logits, cache = model.prefill(params, prompt, cache_len, **kwargs)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    serve = jax.jit(make_serve_step(model))

    pos0 = P + (4 if cfg.family == "vlm" else 0)
    out = [tok]
    t0 = time.time()
    for t in range(N):
        pos = jnp.full((B,), pos0 + t, jnp.int32)
        tok, _, cache = serve(params, tok, cache, pos)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"{cfg.name}: decoded {N} tokens x {B} seqs in {dt:.2f}s "
          f"({N * B / dt:.0f} tok/s)")
    print("sample:", jnp.concatenate(out, axis=1)[0].tolist()[:16], "...")


if __name__ == "__main__":
    main()
