"""Quickstart: the Stannis pipeline end to end on a reduced model, in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import smoke_config
from repro.core.privacy import Shard
from repro.core.topology import Fleet, WorkerClass
from repro.data.pipeline import DataConfig
from repro.models.api import get_model
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig

# 1. A heterogeneous fleet: one fast "host" class + two slow "CSD"-class
#    workers (the paper's Newport role, scaled to this machine).
fleet = Fleet(classes=(
    WorkerClass("host", count=1, peak_throughput=100.0, saturation_batch=8,
                max_batch=16, active_power=400.0),
    WorkerClass("csd", count=2, peak_throughput=25.0, saturation_batch=2,
                max_batch=4, active_power=7.0),
))

# 2. Data: private shards pinned to their owners + a public pool.
shards = [
    Shard("private-csd/0", 64, private=True, owner="csd/0"),
    Shard("private-csd/1", 64, private=True, owner="csd/1"),
    Shard("public", 4096, private=False),
]

# 3. Model: any of the ten assigned architectures (reduced dims here).
cfg = smoke_config("deepseek-7b")
model = get_model(cfg)

trainer = Trainer(
    model=model,
    optimizer=adamw(),
    fleet=fleet,
    data_cfg=DataConfig(vocab=cfg.vocab, seq_len=32),
    cfg=TrainerConfig(total_steps=20),
    shards=shards,
).setup()

print("Algorithm-1 tuned batches :", trainer.tune_result.batches)
print("Eq.-1 steps per epoch     :", trainer.plan.steps_per_epoch,
      f"(imbalance {trainer.plan.imbalance_steps()} steps)")
print("group schedule            :", trainer.schedule.group_batches,
      f"pad {trainer.schedule.pad_fraction:.0%}")

params, history = trainer.train(
    on_metrics=lambda i, m: print(f"  step {i:3d}  loss {m['loss']:.4f}")
    if i % 5 == 0 else None
)
print(f"loss {history[0]['loss']:.4f} -> {history[-1]['loss']:.4f}")
