"""Quickstart: the Stannis pipeline end to end through the Session API.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import FleetSpec, Session, SessionConfig
from repro.configs import smoke_config
from repro.storage import DataConfig
from repro.models.api import get_model
from repro.optim import adamw

# 1. A heterogeneous fleet: one fast "host" class + two slow "CSD"-class
#    workers (the paper's Newport role, scaled to this machine).  Each worker
#    gets a storage device; swap the data plane with one line, e.g.
#    FleetSpec.demo(n_csds=2).with_storage("flash")  (or "meshfeed").
spec = FleetSpec.demo(n_csds=2)

# 2. Data: private shards pinned to their owners + a public pool.
shards = spec.shards(private_per_worker={"csd": 64}, public=4096)

# 3. Model: any of the ten assigned architectures (reduced dims here).
cfg = smoke_config("deepseek-7b")
model = get_model(cfg)

session = Session(
    model=model,
    optimizer=adamw(),
    fleet=spec,
    data=DataConfig(vocab=cfg.vocab, seq_len=32),
    shards=shards,
    config=SessionConfig(total_steps=20),
)

# Each stage is an explicit, cached, inspectable artifact.
tune_plan = session.tune()      # Algorithm 1
epoch = session.plan()          # Eq. 1
manifest = session.place()      # privacy placement, fleet-aware

print("Algorithm-1 tuned batches :", tune_plan.batches)
print("storage devices           :",
      {d.worker: f"{d.backend}:{len(d.custody)} shards"
       for d in manifest.devices})
print("Eq.-1 steps per epoch     :", epoch.steps_per_epoch,
      f"(imbalance {epoch.imbalance_steps()} steps)")
print("group schedule            :", tune_plan.schedule.group_batches,
      f"pad {tune_plan.schedule.pad_fraction:.0%}")

session.callbacks.on_step(
    lambda i, m: print(f"  step {i:3d}  loss {m['loss']:.4f}")
    if i % 5 == 0 else None
)
report = session.run()
print(f"loss {report.history[0]['loss']:.4f} -> {report.final_loss:.4f}")
