#!/usr/bin/env bash
# Tier-1 CI: install the test extra (when the network allows), run the
# suite, then the Session-API benchmark smoke (elastic paths + the
# meshfeed multi-device storage backend).  Reproduces the green/red state
# locally:  ./scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

if python -m pip install -q -e ".[test]" 2>/dev/null; then
    echo "[ci] installed package + test extra"
else
    # offline container: fall back to the preinstalled toolchain; the
    # pyproject pytest config supplies pythonpath=src, hypothesis-backed
    # property tests skip cleanly via tests/_hypothesis_compat.py
    echo "[ci] pip install unavailable; using preinstalled deps"
fi

echo "[ci] kernel parity suite (interpret-mode Pallas vs jnp oracles):"
echo "[ci]   every public repro.kernels.ops export — flash/decode/paged"
echo "[ci]   attention (+ int8 KV variants), fused MoE, rglru/rwkv6 scans,"
echo "[ci]   int8 quantize — against its *_ref, fwd and (where vjp'd) grads"
python -m pytest -x -q tests/test_kernels.py

python -m pytest -x -q --ignore=tests/test_kernels.py

echo "[ci] static analysis gate (custody-taint, use-after-donate,"
echo "[ci]   jit-purity, kernel-parity-coverage, sharding-rule-coverage):"
echo "[ci]   blocking; suppressions live in analysis-baseline.json, the"
echo "[ci]   full report lands in analysis-report.json"
PYTHONPATH=src python -m repro.analysis \
    --baseline analysis-baseline.json --json analysis-report.json

echo "[ci] session smoke (synthetic backend)"
PYTHONPATH=src python benchmarks/session_smoke.py

echo "[ci] sharded session smoke (meshfeed backend, 8-device CPU mesh):"
echo "[ci]   asserts the compiled step's input shardings match the"
echo "[ci]   ShardingPlan (explicit in_shardings, not GSPMD defaults)"
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python benchmarks/session_smoke.py --backend meshfeed

echo "[ci] cluster smoke (4 worker PROCESSES x 2 fake devices each):"
echo "[ci]   asserts every process device_put only ADDRESSABLE shards of"
echo "[ci]   the global mesh (byte-exact receipts, no cross-host batch"
echo "[ci]   bytes), compile_count stays 1 across a drift re-tune,"
echo "[ci]   save-at-4-processes/restore-at-1-process matches the"
echo "[ci]   single-process loss curve, and the int8 RING transport keeps"
echo "[ci]   all 4 replicas bit-identical while tracking the uncompressed"
echo "[ci]   loss (each worker sets its own XLA_FLAGS device count)"
PYTHONPATH=src python benchmarks/cluster_smoke.py --processes 4

echo "[ci] serve smoke (continuous batching): asserts a request admitted"
echo "[ci]   mid-decode streams before the first finishes with unchanged"
echo "[ci]   outputs, prefix-cache hits are bit-identical to cold prefill,"
echo "[ci]   and per-request events stream in order (dense + rwkv6)"
PYTHONPATH=src python benchmarks/serve_smoke.py

echo "[ci] step benchmark (8-device CPU mesh + 2-process cluster records:"
echo "[ci]   star/uncompressed baseline and the int8 ring transport)"
echo "[ci]   -> BENCH_step.json; gated against the committed snapshot:"
echo "[ci]   >25% steps/s regression on any single-process record fails"
echo "[ci]   CI; cluster records gate at the looser 50% (barrier noise)."
echo "[ci]   The committed {1,2,4,8}-process scaling curve regenerates"
echo "[ci]   with --scaling (too slow for per-commit CI)."
PYTHONPATH=src python benchmarks/bench_step.py --steps 4 --compare BENCH_step.json

echo "[ci] serve benchmark (CI-sized load; the committed BENCH_serve.json"
echo "[ci]   is the 256-request run) -> BENCH_serve.json"
PYTHONPATH=src python benchmarks/bench_serve.py --requests 24

echo "[ci] OK"
