#!/usr/bin/env bash
# Tier-1 CI: install the test extra (when the network allows) and run the
# suite.  Reproduces the green/red state locally:  ./scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

if python -m pip install -q -e ".[test]" 2>/dev/null; then
    echo "[ci] installed package + test extra"
else
    # offline container: fall back to the preinstalled toolchain; the
    # pyproject pytest config supplies pythonpath=src, hypothesis-backed
    # property tests skip cleanly via tests/_hypothesis_compat.py
    echo "[ci] pip install unavailable; using preinstalled deps"
fi

exec python -m pytest -x -q
