"""Diagnostic: top collective ops in one cell's compiled HLO (1-cycle unrolled).

  PYTHONPATH=src python scripts/diag_collectives.py qwen3-moe-30b-a3b train_4k
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import re
import sys

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.distributed.sharding import make_rules, set_rules
from repro.launch.dryrun import _lower_and_compile, _with_layers, _cycle_len
from repro.launch.mesh import make_production_mesh
from repro.roofline.collectives import _LINE_RE, _shape_bytes, _group_size


def main():
    arch, shape_name = sys.argv[1], sys.argv[2]
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    rules = make_rules(fsdp=cfg.fsdp)
    rules.setdefault("seq_data", None)
    set_rules(rules)
    c = _cycle_len(cfg)
    compiled = _lower_and_compile(_with_layers(cfg, c), shape, mesh, rules)
    ops = []
    for line in compiled.as_text().splitlines():
        m = _LINE_RE.search(line)
        if not m or m.group(4) == "-done":
            continue
        kind = m.group(3)
        rb = _shape_bytes(m.group(1) or m.group(2))
        n = _group_size(line)
        wire = {"all-reduce": 2 * rb * (n - 1) / n,
                "all-gather": rb * (n - 1) / n,
                "reduce-scatter": rb * (n - 1),
                "all-to-all": rb * (n - 1) / n,
                "collective-permute": rb}[kind]
        meta = ""
        mm = re.search(r'metadata=\{op_name="([^"]*)"', line)
        if mm:
            meta = mm.group(1)[-110:]
        ops.append((wire, kind, rb, n, meta))
    ops.sort(reverse=True)
    total = sum(o[0] for o in ops)
    print(f"{arch} x {shape_name}: {len(ops)} collectives, {total:.3e} wire B/dev (1 cycle)")
    for wire, kind, rb, n, meta in ops[:25]:
        print(f"  {wire:.2e} {kind:18s} n={n:<3d} result={rb:.2e}  {meta}")


if __name__ == "__main__":
    main()
