"""Insert the generated roofline table into EXPERIMENTS.md (placeholder
<!-- ROOFLINE_TABLE -->), single-pod rows first then multi-pod."""
import json
import sys

sys.path.insert(0, "src")

from repro.roofline.analysis import report_table  # noqa: E402


def main():
    src = sys.argv[1] if len(sys.argv) > 1 else "results_baseline.json"
    with open(src) as f:
        records = json.load(f)
    single = [r for r in records if r.get("mesh", "single_pod") == "single_pod"
              or r.get("status") == "skipped"]
    # skipped records appear once per mesh; dedupe by (arch, shape)
    seen = set()
    uniq = []
    for r in single:
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        uniq.append(r)
    table = report_table(uniq)
    multi = [r for r in records if r.get("mesh") == "multi_pod"
             and r.get("status") == "ok"]
    mtable = report_table(multi)
    block = (
        "### Single-pod (256 chips) baseline\n\n" + table
        + "\n\n### Multi-pod (512 chips) baseline\n\n" + mtable
    )
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = text.replace("<!-- ROOFLINE_TABLE -->", block)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("table inserted:", len(uniq), "single-pod rows,", len(multi), "multi-pod rows")


if __name__ == "__main__":
    main()
