"""Reproduce the §Perf hillclimbed cells (EXPERIMENTS.md) — baseline vs
optimized records for the three chosen (arch x shape) pairs.

  PYTHONPATH=src python scripts/perf_cells.py [--out results_perf.json]

The baseline rows force the dense MoE dispatch (REPRO_MOE_IMPL=dense is set
by the runner below for those rows); optimized rows use the shard_map EP
path + the per-cell winning rule overrides from the perf log.
"""
import argparse
import json
import subprocess
import sys
import os

CELLS = {
    "qwen3-moe-30b-a3b": {
        "shape": "train_4k",
        "overrides": {
            "batch": ["pod", "data", "model"],
            "heads": None, "kv_heads": None,
            "act_heads": None, "act_kv_heads": None, "act_mlp": None,
        },
        "zero1": True,
        "remat": False,
    },
    "deepseek-7b": {
        "shape": "train_4k",
        "overrides": {
            "batch": ["pod", "data", "model"],
            "heads": None, "kv_heads": None,
            "act_heads": None, "act_kv_heads": None,
        },
        "zero1": True,
        "remat": False,
    },
    "dbrx-132b": {
        "shape": "train_4k",
        "overrides": {
            "batch": ["pod", "data", "model"],
            "heads": None, "kv_heads": None,
            "act_heads": None, "act_kv_heads": None, "act_mlp": None,
        },
        "zero1": False,   # 132B: params alone exceed HBM under ZeRO-1
        "remat": True,
    },
}

RUNNER = r"""
import json, sys
spec = json.loads(sys.argv[1])
import repro.configs as C
orig = C.get_config
if not spec["remat"]:
    C.get_config = lambda n: orig(n).with_(remat=False) if n == spec["arch"] else orig(n)
import repro.launch.dryrun as D
D.get_config = C.get_config
over = {k: (tuple(v) if isinstance(v, list) else v)
        for k, v in spec["overrides"].items()} if spec["overrides"] else None
rec = D.dryrun_cell(spec["arch"], spec["shape"], zero1=spec["zero1"],
                    rules_overrides=over, verbose=False)
print("RESULT " + json.dumps(rec))
"""


def run_cell(arch, spec, mode):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["REPRO_MOE_IMPL"] = "dense" if mode == "baseline" else "auto"
    payload = {
        "arch": arch, "shape": spec["shape"],
        "overrides": None if mode == "baseline" else spec["overrides"],
        "zero1": False if mode == "baseline" else spec["zero1"],
        "remat": True if mode == "baseline" else spec["remat"],
    }
    out = subprocess.run(
        [sys.executable, "-c", RUNNER, json.dumps(payload)],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(out.stderr[-2000:])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results_perf.json")
    args = ap.parse_args()
    rows = []
    for arch, spec in CELLS.items():
        for mode in ("baseline", "optimized"):
            rec = run_cell(arch, spec, mode)
            rec["mode"] = mode
            coll = sum(rec["collective_bytes"].values())
            print(f"{arch} [{mode:9s}]: flops {rec['flops']:.3e} "
                  f"hbm {rec['hbm_bytes']:.3e} coll {coll:.3e}")
            rows.append(rec)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
