"""Tests for repro.analysis — the static custody/jit-safety CI gate.

Each rule gets at least one minimal synthetic project where it MUST fire and
the corrected form of the same code where it must stay silent.  The last
section runs the analyzer over this repository itself with the checked-in
baseline and asserts the gate is green — the same invocation scripts/ci.sh
makes.
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import Baseline, Project, Suppression, Violation, run_analysis
from repro.analysis.__main__ import main as analysis_main

REPO = Path(__file__).resolve().parents[1]


def make_project(tmp_path, files):
    for rel, src in files.items():
        f = tmp_path / rel
        f.parent.mkdir(parents=True, exist_ok=True)
        f.write_text(textwrap.dedent(src))
    return Project.load(tmp_path)


def analyze(tmp_path, files, rule):
    proj = make_project(tmp_path, files)
    res = run_analysis(tmp_path, rules=[rule], project=proj)
    return res.violations


DEVICE_MOD = """
    class BaseStorageDevice:
        def read(self, key):
            return b"private-bytes"

        def assemble(self, draws):
            return b"rows"
"""


# ---------------------------------------------------------------------------
# custody-taint
# ---------------------------------------------------------------------------


def test_custody_private_read_to_checkpoint_sink_fires(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/storage/device.py": DEVICE_MOD,
        "src/repro/api/train.py": """
            from repro.storage.device import BaseStorageDevice

            class Trainer:
                def __init__(self):
                    self.device = BaseStorageDevice()

                def snapshot(self, ckpt):
                    batch = self.device.read("shard-0")
                    ckpt.save(0, {"batch": batch})
        """,
    }, "custody-taint")
    assert any("checkpoint sink" in v.message for v in vs), vs


def test_custody_checkpoint_of_clean_state_is_silent(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/storage/device.py": DEVICE_MOD,
        "src/repro/api/train.py": """
            from repro.storage.device import BaseStorageDevice

            class Trainer:
                def __init__(self):
                    self.device = BaseStorageDevice()

                def snapshot(self, ckpt, params):
                    batch = self.device.read("shard-0")
                    ckpt.save(0, {"params": params})
        """,
    }, "custody-taint")
    assert vs == []


def test_custody_serialization_sink_fires(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/storage/dump.py": """
            import json

            def leak(device, fh):
                batch = device.read("shard-0")
                json.dump({"rows": batch}, fh)
        """,
    }, "custody-taint")
    assert any("json.dump" in v.message for v in vs), vs


def test_custody_unguarded_feed_fires(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/storage/feedmod.py": """
            class Feeder:
                def feed(self, batch):
                    return batch

            def land(feeder, device):
                batch = device.read("shard-0")
                return feeder.feed(batch)
        """,
    }, "custody-taint")
    assert any("host->device boundary" in v.message for v in vs), vs


def test_custody_guarded_feed_sanitizes(tmp_path):
    # the guard inside the callee both permits the crossing AND declassifies
    # the result: downstream serialization of the fed batch is fine
    vs = analyze(tmp_path, {
        "src/repro/storage/feedmod.py": """
            import jax
            import json

            class Feeder:
                def feed(self, batch):
                    with jax.transfer_guard_host_to_device("disallow"):
                        return batch

            def land(feeder, device, fh):
                batch = device.read("shard-0")
                out = feeder.feed(batch)
                json.dump({"loss": out}, fh)
                return out
        """,
    }, "custody-taint")
    assert vs == []


def test_custody_lexical_guard_at_call_site_is_silent(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/storage/feedmod.py": """
            import jax

            def land(feeder, device):
                batch = device.read("shard-0")
                with jax.transfer_guard_host_to_device("disallow"):
                    out = feeder.feed(batch)
                return out
        """,
    }, "custody-taint")
    assert vs == []


def test_custody_event_audit_permits_feed(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/storage/feedmod.py": """
            from repro.core.privacy import CustodyEvent

            def land(feeder, device, custody_log):
                batch = device.read("shard-0")
                custody_log.append(CustodyEvent("feed", "w0", "mesh"))
                return feeder.feed(batch)
        """,
    }, "custody-taint")
    assert vs == []


def test_custody_taint_flows_through_method_return(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/storage/batcher.py": """
            import pickle

            class Batcher:
                def __init__(self, device):
                    self.dev = device

                def next_batch(self):
                    return self.dev.read("shard-0")

            def leak(b: Batcher, fh):
                rows = b.next_batch()
                pickle.dump(rows, fh)
        """,
    }, "custody-taint")
    assert any("pickle.dump" in v.message for v in vs), vs


# ---------------------------------------------------------------------------
# use-after-donate
# ---------------------------------------------------------------------------


def test_donated_cache_read_after_call_fires(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/serve/runner.py": """
            import jax

            class StepRunner:
                def __init__(self):
                    self.decode = jax.jit(self._decode_fn, donate_argnums=(2,))

                def _decode_fn(self, params, tokens, cache):
                    return tokens, cache

                def step(self, params, tokens, cache):
                    out, new_cache = self.decode(params, tokens, cache)
                    stale = cache["k"]
                    return out, new_cache, stale
        """,
    }, "use-after-donate")
    assert any("'cache' read after being donated" in v.message for v in vs), vs


def test_donated_cache_rebound_in_same_statement_is_silent(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/serve/runner.py": """
            import jax

            class StepRunner:
                def __init__(self):
                    self.decode = jax.jit(self._decode_fn, donate_argnums=(2,))

                def _decode_fn(self, params, tokens, cache):
                    return tokens, cache

                def step(self, params, tokens, cache):
                    out, cache = self.decode(params, tokens, cache)
                    return out, cache
        """,
    }, "use-after-donate")
    assert vs == []


def test_donation_in_loop_without_rebind_fires(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/api/loop.py": """
            import jax

            def train(step_fn, params, batches):
                step = jax.jit(step_fn, donate_argnums=(0,))
                for b in batches:
                    out = step(params, b)
                return out
        """,
    }, "use-after-donate")
    assert any("donated inside a loop" in v.message for v in vs), vs


def test_donation_in_loop_with_rebind_is_silent(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/api/loop.py": """
            import jax

            def train(step_fn, params, batches):
                step = jax.jit(step_fn, donate_argnums=(0,))
                for b in batches:
                    params, out = step(params, b)
                return params, out
        """,
    }, "use-after-donate")
    assert vs == []


def test_lowered_aot_chain_is_exempt(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/launch/aot.py": """
            import jax

            def lower_only(step_fn, params, batch):
                lowered = jax.jit(step_fn, donate_argnums=(0,)).lower(params, batch)
                cost = lowered.compile().cost_analysis()
                return cost, params
        """,
    }, "use-after-donate")
    assert vs == []


def test_immediate_jit_invocation_fires(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/launch/aot.py": """
            import jax

            def run_once(step_fn, params, batch):
                out = jax.jit(step_fn, donate_argnums=(0,))(params, batch)
                norm = params["w"].sum()
                return out, norm
        """,
    }, "use-after-donate")
    assert any("'params' read after being donated" in v.message for v in vs), vs


# ---------------------------------------------------------------------------
# jit-purity
# ---------------------------------------------------------------------------


def test_host_clock_inside_jit_fires(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/api/stepmod.py": """
            import time
            import jax

            @jax.jit
            def step(x):
                t0 = time.perf_counter()
                return x + t0
        """,
    }, "jit-purity")
    assert any("time.perf_counter" in v.message for v in vs), vs


def test_clock_passed_as_argument_is_silent(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/api/stepmod.py": """
            import jax

            @jax.jit
            def step(x, t0):
                return x + t0
        """,
    }, "jit-purity")
    assert vs == []


def test_set_iteration_inside_jit_fires(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/api/stepmod.py": """
            import jax

            @jax.jit
            def step(x):
                for name in {"wq", "wk", "wv"}:
                    x = x + len(name)
                return x
        """,
    }, "jit-purity")
    assert any("set" in v.message for v in vs), vs


def test_sorted_iteration_inside_jit_is_silent(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/api/stepmod.py": """
            import jax

            @jax.jit
            def step(x):
                for name in ("wq", "wk", "wv"):
                    x = x + len(name)
                return x
        """,
    }, "jit-purity")
    assert vs == []


def test_mutated_closure_capture_fires(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/api/stepmod.py": """
            import jax

            def make_step(scale):
                stats = []

                def step(x):
                    return x * scale + len(stats)

                fn = jax.jit(step)
                stats.append(1)
                return fn
        """,
    }, "jit-purity")
    assert any("captures mutable 'stats'" in v.message for v in vs), vs


def test_immutable_capture_is_silent(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/api/stepmod.py": """
            import jax

            def make_step(scale):
                def step(x):
                    return x * scale

                return jax.jit(step)
        """,
    }, "jit-purity")
    assert vs == []


def test_numpy_random_inside_jitted_method_fires(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/api/stepmod.py": """
            import jax
            import numpy as np

            class Runner:
                def __init__(self):
                    self.step = jax.jit(self._step)

                def _step(self, x):
                    return x + np.random.rand()
        """,
    }, "jit-purity")
    assert any("random" in v.message for v in vs), vs


# ---------------------------------------------------------------------------
# kernel-parity-coverage
# ---------------------------------------------------------------------------


def test_kernel_without_oracle_fires(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/kernels/ops.py": """
            def fused_matmul(x, y):
                return x @ y
        """,
        "src/repro/kernels/ref.py": "",
        "tests/test_kernels.py": "",
    }, "kernel-parity-coverage")
    assert any("no 'fused_matmul_ref' oracle" in v.message for v in vs), vs


def test_kernel_exercised_but_unverified_fires(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/kernels/ops.py": """
            def fused_matmul(x, y):
                return x @ y
        """,
        "src/repro/kernels/ref.py": """
            def fused_matmul_ref(x, y):
                return x @ y
        """,
        "tests/test_kernels.py": """
            from repro.kernels import ops

            def test_runs():
                assert ops.fused_matmul(1, 2)
        """,
    }, "kernel-parity-coverage")
    assert any("exercised but unverified" in v.message for v in vs), vs


def test_kernel_with_parity_test_is_silent(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/kernels/ops.py": """
            def fused_matmul(x, y):
                return x @ y
        """,
        "src/repro/kernels/ref.py": """
            def fused_matmul_ref(x, y):
                return x @ y
        """,
        "tests/test_kernels.py": """
            from repro.kernels import ops
            from repro.kernels import ref as R

            def test_parity():
                assert ops.fused_matmul(1, 2) == R.fused_matmul_ref(1, 2)
        """,
    }, "kernel-parity-coverage")
    assert vs == []


def test_kernel_assignment_export_is_covered(tmp_path):
    # `dequant = _impl` style public exports count as kernels too
    vs = analyze(tmp_path, {
        "src/repro/kernels/ops.py": """
            def _impl(q, s):
                return q * s

            dequant = _impl
        """,
        "src/repro/kernels/ref.py": "",
        "tests/test_kernels.py": "",
    }, "kernel-parity-coverage")
    assert any(v.symbol == "dequant" for v in vs), vs


# ---------------------------------------------------------------------------
# sharding-rule-coverage
# ---------------------------------------------------------------------------


SHARDING_MOD = """
    def make_rules(data_axis):
        return {
            "batch": (data_axis,),
            "embed": (None,),
        }
"""


def test_unlisted_axis_fires(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/distributed/sharding.py": SHARDING_MOD,
        "src/repro/models/toy.py": """
            def build(b):
                b.param("w", (4, 8), ("embed", "novel_axis"))
        """,
    }, "sharding-rule-coverage")
    assert [v.symbol for v in vs] == ["novel_axis"], vs


def test_listed_axes_are_silent(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/distributed/sharding.py": SHARDING_MOD,
        "src/repro/models/toy.py": """
            def build(b, x):
                b.param("w", (4, 8), ("embed", "batch"))
                return wlc(x, "batch", "embed")
        """,
    }, "sharding-rule-coverage")
    assert vs == []


def test_setdefault_amendment_counts_as_listed(tmp_path):
    vs = analyze(tmp_path, {
        "src/repro/distributed/sharding.py": SHARDING_MOD,
        "src/repro/launch/amend.py": """
            def amend(rules):
                rules.setdefault("seq_data", ("data",))
        """,
        "src/repro/models/toy.py": """
            def build(b):
                b.param("w", (4, 8), ("embed", "seq_data"))
        """,
    }, "sharding-rule-coverage")
    assert vs == []


# ---------------------------------------------------------------------------
# baseline mechanics
# ---------------------------------------------------------------------------


def test_suppression_matching():
    v = Violation(path="a/b.py", line=3, rule="custody-taint",
                  message="m", symbol="f")
    assert Suppression(rule="custody-taint", path="a/b.py", reason="r").matches(v)
    assert Suppression(rule="custody-taint", path="a/b.py", symbol="f",
                       reason="r").matches(v)
    assert not Suppression(rule="custody-taint", path="a/b.py", symbol="g",
                           reason="r").matches(v)
    assert not Suppression(rule="jit-purity", path="a/b.py",
                           reason="r").matches(v)


def test_baseline_reason_is_mandatory(tmp_path):
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps({"suppressions": [
        {"rule": "custody-taint", "path": "x.py"}
    ]}))
    with pytest.raises(ValueError, match="no reason"):
        Baseline.load(bl)


def test_baseline_filters_and_reports_unused(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/storage/dump.py": """
            import json

            def leak(device, fh):
                json.dump(device.read("shard-0"), fh)
        """,
    })
    baseline = Baseline([
        Suppression(rule="custody-taint", path="src/repro/storage/dump.py",
                    symbol="leak", reason="test fixture"),
        Suppression(rule="custody-taint", path="src/repro/storage/other.py",
                    reason="stale entry"),
    ])
    res = run_analysis(tmp_path, rules=["custody-taint"], project=proj,
                       baseline=baseline)
    assert res.ok
    assert res.suppressed == 1
    assert [s.path for s in res.unused_suppressions] == [
        "src/repro/storage/other.py"]


def test_unknown_rule_is_an_error(tmp_path):
    make_project(tmp_path, {"src/repro/x.py": "pass"})
    with pytest.raises(KeyError, match="unknown rule"):
        run_analysis(tmp_path, rules=["no-such-rule"])


# ---------------------------------------------------------------------------
# the repo itself is clean under the checked-in baseline (the CI gate)
# ---------------------------------------------------------------------------


def test_repo_passes_its_own_gate(tmp_path):
    out = tmp_path / "report.json"
    rc = analysis_main([
        "--root", str(REPO),
        "--baseline", "analysis-baseline.json",
        "--json", str(out), "-q",
    ])
    report = json.loads(out.read_text())
    assert rc == 0, report["violations"]
    assert report["ok"]
    assert set(report["rules"]) == {
        "custody-taint", "jit-purity", "kernel-parity-coverage",
        "sharding-rule-coverage", "use-after-donate",
    }
    # every baselined suppression must still be earning its keep
    assert report["unused_suppressions"] == []
