"""Integration: training steps end-to-end through the Session pipeline
(tune -> plan -> place -> train), fault tolerance (restart, node loss), the
data-plane invariants, and the partial-gradient (cluster hostsync) step's
equivalence to the single-program step.  (Formerly ``test_trainer.py`` —
the ``Trainer`` it was named for died in PR 3; the surviving cases live on
here under the name of what they actually test.)"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FleetSpec, Session, SessionConfig, DriftDetected, WorkerLost
from repro.configs import smoke_config
from repro.core.hetero import BatchSchedule
from repro.core.privacy import Shard
from repro.models.api import get_model
from repro.optim import adamw
from repro.storage import DataConfig, SyntheticDevice, synth_sequence


def _spec(n_csds=2):
    return FleetSpec.demo(n_csds)


def _shards(n_csds=2):
    return _spec(n_csds).shards(
        private_per_worker={"csd": 64}, public=4096, prefix="priv"
    )


def _session(tmp_path=None, steps=6, n_csds=2):
    cfg = smoke_config("deepseek-7b")
    return Session(
        model=get_model(cfg),
        optimizer=adamw(),
        fleet=_spec(n_csds),
        data=DataConfig(vocab=cfg.vocab, seq_len=16),
        config=SessionConfig(
            total_steps=steps,
            checkpoint_dir=str(tmp_path) if tmp_path else None,
            checkpoint_every=2,
            async_checkpoint=False,
        ),
        shards=_shards(n_csds),
    )


def test_end_to_end_loss_decreases():
    s = _session(steps=8)
    assert s.plan().imbalance_steps() == 0
    report = s.run()
    assert report.final_loss < report.history[0]["loss"]


def test_restart_resumes_from_checkpoint(tmp_path):
    s = _session(tmp_path, steps=4)
    s.run()
    assert s.plan() is not None
    # second session resumes: runs only the remaining steps
    s2 = _session(tmp_path, steps=6)
    report = s2.run()
    assert report.steps_run == 2  # resumed at step 4 of 6


def test_worker_lost_replans():
    s = _session(steps=2, n_csds=3)
    n_groups = s.tune().schedule.n_groups
    s.apply(WorkerLost(["csd/1"]))
    assert s.tune().schedule.n_groups == n_groups - 1
    assert s.plan().imbalance_steps() == 0
    # the dead worker's private shard is gone — nobody else may read it
    assert all(sh.owner != "csd/1" for sh in s.shards if sh.private)
    report = s.run(steps=2)
    assert np.isfinite(report.final_loss)


def test_retune_keeps_shapes():
    s = _session(steps=2)
    shape_before = s.tune().schedule.global_rows
    s.apply(DriftDetected())
    assert s.tune().schedule.global_rows == shape_before  # no recompilation


# ---------------------------------------------------------------------------
# data plane (repro.storage)
# ---------------------------------------------------------------------------


def test_synth_deterministic_across_processes():
    cfg = DataConfig(vocab=1000, seq_len=32, seed=5)
    a = synth_sequence(cfg, "shard-x", 17)
    b = synth_sequence(cfg, "shard-x", 17)
    np.testing.assert_array_equal(a, b)
    c = synth_sequence(cfg, "shard-y", 17)
    assert not np.array_equal(a, c)


def test_private_store_enforces_ownership():
    cfg = DataConfig(vocab=100, seq_len=8)
    shards = [Shard("p", 10, True, "w0"), Shard("pub", 10, False)]
    s0 = SyntheticDevice("w0", cfg)
    s1 = SyntheticDevice("w1", cfg)
    s0.provision(shards)
    s1.provision(shards)
    s0.read("p", 0)             # owner: fine
    s1.read("pub", 0)           # public: fine
    with pytest.raises(PermissionError):
        s1.read("p", 0)         # private, non-owner: refused


def test_dataset_layout_and_masks():
    s = _session(steps=1)
    b = s.dataset.next_batch()
    R = s.tune().schedule.global_rows
    assert b["tokens"].shape == (R, 16)
    assert b["loss_mask"].shape == (R, 16)
    # mask matches the schedule exactly
    np.testing.assert_array_equal(
        b["loss_mask"][:, 0], s.tune().schedule.row_mask()
    )
    # invalid rows carry zero tokens (never sampled)
    dead = b["tokens"][b["loss_mask"][:, 0] == 0]
    assert (dead == 0).all()


# ---------------------------------------------------------------------------
# the cluster (hostsync) split step == the single-program step
# ---------------------------------------------------------------------------


def test_partial_grad_step_matches_train_step():
    """Summing per-host partial gradients and applying once must reproduce
    the fused masked-global-mean step exactly — the numerical contract the
    multi-process hostsync path stands on."""
    from repro.train.steps import (
        make_apply_step, make_partial_grad_step, make_train_step,
    )

    cfg = smoke_config("deepseek-7b")
    model = get_model(cfg)
    opt = adamw()
    params, _ = model.init_params(key=jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    sched = lambda s: 1e-3  # noqa: E731

    rng = np.random.default_rng(0)
    R, S = 8, 8
    batch = {
        "tokens": rng.integers(0, cfg.vocab, (R, S)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (R, S)).astype(np.int32),
        # heterogeneous validity: one dead row per half
        "loss_mask": np.ones((R, S), np.float32),
    }
    batch["loss_mask"][3] = 0.0
    batch["loss_mask"][6] = 0.0

    fused = make_train_step(model, opt, sched)
    p_ref, o_ref, m_ref = fused(params, opt_state, batch)

    grad_step = make_partial_grad_step(model)
    apply_step = make_apply_step(opt, sched)
    halves = [
        {k: v[:4] for k, v in batch.items()},
        {k: v[4:] for k, v in batch.items()},
    ]
    grads, sums = None, None
    for h in halves:                       # the coordinator's tree-sum
        g, s = grad_step(params, h)
        if grads is None:
            grads, sums = g, s
        else:
            grads = jax.tree_util.tree_map(jnp.add, grads, g)
            sums = jax.tree_util.tree_map(jnp.add, sums, s)
    p_new, o_new, m_new = apply_step(params, opt_state, grads, sums)

    np.testing.assert_allclose(
        float(m_new["loss"]), float(m_ref["loss"]), rtol=1e-6
    )
    np.testing.assert_allclose(
        float(m_new["grad_norm"]), float(m_ref["grad_norm"]), rtol=1e-5
    )
    for a, b in zip(jax.tree_util.tree_leaves(p_new),
                    jax.tree_util.tree_leaves(p_ref)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-7
        )
    assert int(o_new.step) == int(o_ref.step) == 1


# ---------------------------------------------------------------------------
# the removed compat surfaces stay removed
# ---------------------------------------------------------------------------


def test_trainer_and_data_shims_are_gone():
    """Two PRs of deprecation are over: the ``Trainer`` stub and the
    ``repro.data`` pipeline shim no longer exist — stale imports fail at
    import time, not at behavior drift."""
    with pytest.raises(ImportError):
        import repro.train.trainer  # noqa: F401
    with pytest.raises(ImportError):
        import repro.data.pipeline  # noqa: F401
    import repro.train

    assert not hasattr(repro.train, "Trainer")


# ---------------------------------------------------------------------------
# int8-fused training precision
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["deepseek-7b", "qwen3-moe-30b-a3b"])
def test_int8_fused_loss_trajectory_tracks_f32(arch):
    """train_precision='int8-fused' (quantized K/V + int8 residuals) tracks
    the f32 trajectory step for step on dense and MoE smoke models: measured
    divergence is <4% over the horizon; 8% is the documented tolerance."""
    from repro.train.steps import make_train_step

    cfg = smoke_config(arch)

    def run(prec, steps=6):
        m = get_model(cfg.with_(train_precision=prec))
        params, _ = m.init_params(key=jax.random.PRNGKey(0))
        opt = adamw()
        step = jax.jit(make_train_step(m, opt, lambda s: 1e-2))
        state = opt.init(params)
        losses = []
        key = jax.random.PRNGKey(3)
        B, S = 4, 16
        for t in range(steps):
            kt = jax.random.fold_in(key, t)
            toks = jax.random.randint(kt, (B, S + 1), 0, cfg.vocab)
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                     "loss_mask": jnp.ones((B, S), jnp.float32)}
            params, state, metrics = step(params, state, batch)
            losses.append(float(metrics["loss"]))
        return losses

    f32 = run("f32")
    q8 = run("int8-fused")
    np.testing.assert_allclose(q8, f32, rtol=0.08)
    assert f32[-1] < f32[0] and q8[-1] < q8[0]   # both actually learn


def test_int8_fused_shrinks_residual_bytes():
    """The int8 residual pytree is measurably smaller than f32's — the
    memory claim behind in-kernel low-precision training."""
    from repro.train.steps import abstract_batch, residual_bytes

    cfg = smoke_config("deepseek-7b").with_(remat=False, scan_layers=False)
    batch = abstract_batch(4, 16)
    f32 = residual_bytes(get_model(cfg), batch)
    q8 = residual_bytes(get_model(cfg.with_(train_precision="int8-fused")), batch)
    assert q8 < f32
