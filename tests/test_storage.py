"""Storage subsystem: custody enforcement on EVERY backend, synthetic/flash
bit-identity, WorkerLost re-homing (public moves, private quarantines), the
meshfeed mesh, and the multi-device session smoke."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.privacy import Shard, audit_custody
from repro.storage import (
    BACKENDS, DataConfig, DeviceFleet, FlashDevice, FleetManifest,
    StorageSpec, SyntheticDevice, data_axis_size, synth_sequence,
)

from _hypothesis_compat import given, settings, st

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = DataConfig(vocab=128, seq_len=8, seed=3)


def _spec(backend, tmp_path):
    if backend == "flash":
        return StorageSpec(backend="flash", root=str(tmp_path / "spool"))
    return StorageSpec(backend=backend)


def _fleet(backend, tmp_path, workers=("w0", "w1")):
    shards = [
        Shard("priv-w0", 6, True, "w0"),
        Shard("priv-w1", 6, True, "w1"),
        Shard("pub", 12, False),
    ]
    return DeviceFleet.provision(
        list(workers), shards, CFG, spec=_spec(backend, tmp_path)
    )


# ---------------------------------------------------------------------------
# custody: the PermissionError guard, on every backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", sorted(BACKENDS))
def test_cross_worker_private_read_raises(backend, tmp_path):
    fleet = _fleet(backend, tmp_path)
    d0, d1 = fleet.device("w0"), fleet.device("w1")
    assert d0.read("priv-w0", 0).shape == (CFG.seq_len + 1,)   # owner: fine
    assert d1.read("pub", 0) is not None                       # public: fine
    with pytest.raises(PermissionError):
        d1.read("priv-w0", 0)                                  # refused
    with pytest.raises(PermissionError):
        d0.read("priv-w1", 0)
    with pytest.raises(KeyError):
        d0.read("nope", 0)                                     # unknown != denied


@pytest.mark.parametrize("backend", sorted(BACKENDS))
def test_assemble_is_custody_checked(backend, tmp_path):
    fleet = _fleet(backend, tmp_path)
    with pytest.raises(PermissionError):
        fleet.device("w1").assemble([("pub", 0), ("priv-w0", 1)])


# ---------------------------------------------------------------------------
# synthetic <-> flash bit-identity
# ---------------------------------------------------------------------------


def test_flash_matches_synthetic_bit_exact(tmp_path):
    fleet_s = _fleet("synthetic", tmp_path)
    fleet_f = _fleet("flash", tmp_path)
    for sid, n in (("priv-w0", 6), ("pub", 12)):
        for i in range(n):
            np.testing.assert_array_equal(
                fleet_s.device("w0").read(sid, i),
                fleet_f.device("w0").read(sid, i),
            )


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    shard=st.text(alphabet="abcxyz/-", min_size=1, max_size=12),
    index=st.integers(min_value=0, max_value=63),
)
def test_flash_synthetic_bit_identity_property(seed, shard, index, tmp_path_factory):
    """For ANY (seed, shard, index): flash pages == synthetic generation."""
    cfg = DataConfig(vocab=512, seq_len=12, seed=seed)
    root = str(tmp_path_factory.mktemp("flash-prop"))
    sh = Shard(shard, index + 1, False)
    syn = SyntheticDevice("w", cfg)
    syn.provision([sh])
    fl = FlashDevice("w", cfg, root=root)
    fl.provision([sh])
    np.testing.assert_array_equal(syn.read(shard, index), fl.read(shard, index))
    np.testing.assert_array_equal(
        syn.read(shard, index), synth_sequence(cfg, shard, index)
    )


# ---------------------------------------------------------------------------
# spool codecs: narrow bytes at rest, identical samples out
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec,itemsize", [("i32", 4), ("u16", 2),
                                            ("u8", 1), ("auto", 1)])
def test_flash_codec_bit_identity_and_spool_bytes(codec, itemsize, tmp_path):
    """Every codec returns bit-identical samples to synthetic, and the bytes
    written to flash shrink with the width (auto resolves to u8 at vocab 128)."""
    from repro.storage.codec import bytes_per_sample

    sh = Shard("s", 5, False)
    syn = SyntheticDevice("w", CFG)
    syn.provision([sh])
    fl = FlashDevice("w", CFG, root=str(tmp_path), codec=codec)
    fl.provision([sh])
    for i in range(5):
        np.testing.assert_array_equal(syn.read("s", i), fl.read("s", i))
    assert fl.spooled_bytes == 5 * bytes_per_sample(fl.codec, CFG.seq_len)
    assert fl.spooled_bytes == 5 * (CFG.seq_len + 1) * itemsize


def test_flash_codec_too_narrow_refused(tmp_path):
    """u8 cannot hold vocab 1024 losslessly — construction must refuse
    rather than ever rounding ids."""
    big = DataConfig(vocab=1024, seq_len=8, seed=3)
    with pytest.raises(ValueError, match="lossless"):
        FlashDevice("w", big, root=str(tmp_path), codec="u8")
    # auto degrades to a width that fits instead of failing
    assert FlashDevice("w", big, root=str(tmp_path), codec="auto").codec == "u16"


def test_flash_codecs_never_alias_files(tmp_path):
    """Two devices with different codecs over the same root must not read
    each other's layouts: codec-tagged filenames keep them disjoint."""
    sh = Shard("s", 3, False)
    a = FlashDevice("w", CFG, root=str(tmp_path), codec="i32")
    b = FlashDevice("w", CFG, root=str(tmp_path), codec="u8")
    for d in (a, b):
        d.provision([sh])
        d.read("s", 0)
    names = sorted(os.listdir(os.path.join(str(tmp_path), "public")))
    assert names == ["s.i32", "s.u8"]
    np.testing.assert_array_equal(a.read("s", 1), b.read("s", 1))


def test_storage_spec_rejects_unknown_codec():
    with pytest.raises(ValueError):
        StorageSpec(backend="flash", codec="int8")


def test_fleet_codec_flows_to_devices(tmp_path):
    spec = StorageSpec(backend="flash", root=str(tmp_path / "sp"), codec="auto")
    fleet = DeviceFleet.provision(
        ["w0"], [Shard("pub", 4, False)], CFG, spec=spec
    )
    dev = fleet.device("w0")
    assert dev.codec == "u8"                     # vocab 128 fits one byte
    np.testing.assert_array_equal(
        dev.read("pub", 2), synth_sequence(CFG, "pub", 2)
    )


@pytest.mark.parametrize("backend", sorted(BACKENDS))
def test_batcher_output_identical_across_backends(backend, tmp_path):
    """The training math must not depend on the storage medium."""
    from repro.core.hetero import BatchSchedule
    from repro.storage import FleetBatcher

    ref = _fleet("synthetic", tmp_path)
    other = _fleet(backend, tmp_path)
    kw = dict(
        cfg=CFG, schedule=BatchSchedule((2, 3)), group_workers=["w0", "w1"],
        group_sources={"w0": [("priv-w0", 6), ("pub", 4)],
                       "w1": [("priv-w1", 6), ("pub", 4)]},
    )
    a = FleetBatcher(fleet=ref, **kw)
    b = FleetBatcher(fleet=other, **kw)
    for _ in range(3):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
        np.testing.assert_array_equal(ba["labels"], bb["labels"])
        np.testing.assert_array_equal(ba["loss_mask"], bb["loss_mask"])


# ---------------------------------------------------------------------------
# WorkerLost re-homing: public moves, private quarantines — every backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", sorted(BACKENDS))
def test_worker_lost_rehomes_public_quarantines_private(backend, tmp_path):
    fleet = _fleet(backend, tmp_path, workers=("w0", "w1", "w2"))
    assert fleet.custodian("pub") == "w0"       # first provisioned worker
    dropped = fleet.quarantine_workers(["w0"])
    assert dropped == ("priv-w0",)
    assert fleet.workers == ("w1", "w2")
    # public custody re-homed to a survivor
    assert fleet.custodian("pub") in ("w1", "w2")
    # the dead worker's private shard is tombstoned on EVERY survivor:
    # a PermissionError, never bytes, never a silent KeyError
    for w in fleet.workers:
        with pytest.raises(PermissionError, match="quarantined"):
            fleet.device(w).read("priv-w0", 0)
    # survivors' own private shards are untouched
    fleet.device("w1").read("priv-w1", 0)
    # and the audit proves no private shard ever moved
    audit = audit_custody(fleet.custody_log)
    assert audit["private_shards_rehomed"] == 0
    assert audit["private_shards_resurrected"] == 0
    assert audit["duplicate_provisions"] == 0
    kinds = {(e.kind, e.shard_id) for e in fleet.custody_log}
    assert ("quarantine", "priv-w0") in kinds
    assert ("rehome", "pub") in kinds


def test_flash_quarantine_shreds_the_file(tmp_path):
    fleet = _fleet("flash", tmp_path, workers=("w0", "w1"))
    dev0 = fleet.device("w0")
    dev0.read("priv-w0", 0)                     # spools the file
    shard = next(s for s in fleet.shards if s.shard_id == "priv-w0")
    path = dev0._shard_path(shard)
    assert os.path.exists(path)
    # losing w0 through the REAL fleet path shreds its flash: the private
    # bytes cease to exist on disk, not just in the custody table
    fleet.quarantine_workers(["w0"])
    assert not os.path.exists(path)


@pytest.mark.parametrize("backend", sorted(BACKENDS))
def test_joiner_inherits_tombstones(backend, tmp_path):
    """A worker provisioned AFTER a quarantine must still refuse the dead
    shard (late joiners cannot resurrect dead data)."""
    fleet = _fleet(backend, tmp_path, workers=("w0", "w1"))
    fleet.quarantine_workers(["w0"])
    dev = fleet.provision_worker("w9")
    with pytest.raises(PermissionError, match="quarantined"):
        dev.read("priv-w0", 0)
    dev.read("pub", 0)                          # public pool: fine


# ---------------------------------------------------------------------------
# Session-level: private shards never materialize off-owner under WorkerLost
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["synthetic", "flash", "meshfeed"])
def test_session_worker_lost_never_materializes_private_off_owner(backend, tmp_path):
    from repro.api import FleetSpec, Session, SessionConfig, WorkerLost
    from repro.configs import smoke_config
    from repro.models.api import get_model
    from repro.optim import adamw

    cfg = smoke_config("deepseek-7b")
    spec = FleetSpec.demo(3).with_storage(
        backend, **({"root": str(tmp_path)} if backend == "flash" else {})
    )
    s = Session(
        model=get_model(cfg), optimizer=adamw(), fleet=spec,
        data=DataConfig(vocab=cfg.vocab, seq_len=16),
        shards=spec.shards(private_per_worker={"csd": 16}, public=256),
        config=SessionConfig(total_steps=2),
    )
    s.run()
    s.apply(WorkerLost(["csd/1"]))
    # the quarantined shard appears in NO surviving worker's sample sources
    for w, pairs in s.dataset.group_sources.items():
        assert all(sid != "private-csd/1" for sid, _ in pairs)
    # and no device will hand out its bytes
    for dev in s.devices:
        with pytest.raises((PermissionError, KeyError)):
            dev.read("private-csd/1", 0)
    # training continues; custody audit stays clean
    report = s.run(steps=1)
    assert np.isfinite(report.final_loss)
    assert audit_custody(s.devices.custody_log)["private_shards_rehomed"] == 0
    assert "private-csd/1" in s.place().quarantined


# ---------------------------------------------------------------------------
# meshfeed: mesh construction + the multi-device acceptance smoke
# ---------------------------------------------------------------------------


def test_data_axis_size_picks_largest_divisor():
    assert data_axis_size(40, 8) == 8
    assert data_axis_size(30, 8) == 6
    assert data_axis_size(7, 8) == 7
    assert data_axis_size(9, 4) == 3
    assert data_axis_size(0, 8) == 1


def test_meshfeed_single_device_degrades():
    """In the (1-device) test process meshfeed still works: data axis 1."""
    import jax

    from repro.core.hetero import BatchSchedule
    from repro.storage import FleetBatcher

    fleet = _fleet("meshfeed", None)
    b = FleetBatcher(
        cfg=CFG, schedule=BatchSchedule((2, 2)), group_workers=["w0", "w1"],
        group_sources={"w0": [("priv-w0", 6)], "w1": [("priv-w1", 6)]},
        fleet=fleet,
    )
    out = b.next_device_batch()
    assert isinstance(out["tokens"], jax.Array)
    assert out["tokens"].shape == (b.schedule.global_rows, CFG.seq_len)
    assert fleet.mesh is not None and fleet.mesh.shape["data"] == 1
    assert "data" in out["tokens"].sharding.spec


def test_make_host_mesh_rejects_oversized():
    from repro.launch.mesh import make_host_mesh

    with pytest.raises(ValueError, match="device"):
        make_host_mesh(data=64, model=64)       # way beyond any CPU host
    with pytest.raises(ValueError, match="positive"):
        make_host_mesh(data=0, model=1)


def test_storage_spec_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown storage backend"):
        StorageSpec(backend="tape")


def test_meshfeed_session_smoke_multidevice():
    """Acceptance: the session smoke trains through MeshFeedDevice on a
    multi-device CPU mesh, batches born sharded along ``data``."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join([REPO, os.path.join(REPO, "src")])
    code = """
        import jax
        assert len(jax.devices()) == 8, jax.devices()
        from benchmarks.session_smoke import run, _checks
        m = run(verbose=False, backend="meshfeed")
        assert m["feed_devices"] > 1, m          # really fed a multi-device mesh
        checks = _checks(m)
        assert all(checks.values()), checks
        print("MESHFEED-SMOKE OK", m["feed_devices"])
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESHFEED-SMOKE OK" in out.stdout
