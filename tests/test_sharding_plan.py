"""The sharding rule engine and the staged ShardingPlan.

Covers: the `spec_for` no-duplicate-mesh-axis invariant (property-tested),
`_divisible_spec` fallbacks (uneven heads, small meshes), the ShardingPlan
artifact (structure, caching, elastic invalidation, `compile_count` probe),
sharded init (params born on the mesh, never host-replicated), sharded
checkpoint restore, and the `with_logical_constraint` warn-once contract.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api import (
    DriftDetected, FleetSpec, Session, SessionConfig, ShardingPlan, WorkerLost,
)
from repro.configs import smoke_config
from repro.distributed.sharding import (
    _divisible_spec, get_rules, make_rules, spec_for, use_rules,
    with_logical_constraint,
)
from repro.models.api import get_model
from repro.optim import adamw, sgd_momentum
from repro.storage import DataConfig
from repro.train.steps import (
    BATCH_AXES, abstract_batch, abstract_train_state, build_sharding_plan,
)

from _hypothesis_compat import given, settings, st

# every logical axis name any rule table knows about, plus unknowns
_LOGICAL = sorted(make_rules(fsdp=True, seq_shard=True)) + ["unknown", None]


def _flat_axes(spec: P):
    flat = []
    for part in spec:
        if isinstance(part, tuple):
            flat.extend(part)
        elif part is not None:
            flat.append(part)
    return flat


# ---------------------------------------------------------------------------
# rule engine: spec_for never assigns one mesh axis to two dims of a leaf
# ---------------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(
    axes=st.lists(st.sampled_from(_LOGICAL), min_size=1, max_size=6),
    fsdp=st.booleans(),
    seq_shard=st.booleans(),
)
def test_spec_for_never_duplicates_mesh_axis(axes, fsdp, seq_shard):
    """For ANY logical-axis tuple under ANY stock rule table, a mesh axis
    appears at most once in the resulting PartitionSpec (XLA rejects specs
    that shard two dims of one tensor over the same mesh axis)."""
    rules = make_rules(fsdp=fsdp, seq_shard=seq_shard)
    spec = spec_for(tuple(axes), rules)
    flat = _flat_axes(spec)
    assert len(flat) == len(set(flat)), (axes, spec)
    assert len(spec) <= len(axes)          # never longer than the leaf rank


def test_spec_for_duplicate_logical_axes_keep_first():
    """Same logical name twice (e.g. a square (embed, embed) weight): the
    first dim takes the mesh axis, the second replicates."""
    rules = make_rules(fsdp=True)
    spec = spec_for(("embed", "embed"), rules)
    flat = _flat_axes(spec)
    assert len(flat) == len(set(flat))
    assert spec[0] == "data"


# ---------------------------------------------------------------------------
# _divisible_spec fallbacks
# ---------------------------------------------------------------------------


class _FakeMesh:
    def __init__(self, **shape):
        self.shape = shape


def test_divisible_spec_uneven_heads_fall_back_replicated():
    # 56 query heads on a 16-way model axis: 56 % 16 != 0 -> that dim
    # replicates (the memory is carried by the other sharded dims)
    mesh = _FakeMesh(data=16, model=16)
    s = _divisible_spec(P(None, "model", None), (4, 56, 128), mesh)
    assert s == P()
    # 64 heads divide: the axis survives
    assert _divisible_spec(P(None, "model", None), (4, 64, 128), mesh) == P(None, "model")


def test_divisible_spec_small_mesh_drops_absent_axes():
    # batch rows shard over ("pod", "data"); a single-pod host mesh has no
    # "pod" axis -> only "data" survives (and only if it divides)
    mesh = _FakeMesh(data=4, model=1)
    assert _divisible_spec(P(("pod", "data"), None), (8, 16), mesh) == P("data")
    assert _divisible_spec(P(("pod", "data"), None), (6, 16), mesh) == P()


def test_divisible_spec_partial_tuple_keeps_divisible_prefix():
    # (pod=2, data=8): 8 rows fit pod*? -> pod kept (8%2==0), then data
    # needs 2*8=16 | 8 -> dropped; single-axis remainder collapses to str
    mesh = _FakeMesh(pod=2, data=8)
    assert _divisible_spec(P(("pod", "data"),), (8,), mesh) == P("pod")


def test_divisible_spec_rank_overflow_is_replicated():
    # spec longer than the shape: excess dims replicate instead of erroring
    mesh = _FakeMesh(data=2)
    assert _divisible_spec(P("data", "data"), (4,), mesh) == P("data")


# ---------------------------------------------------------------------------
# ShardingPlan: structure, caching, elastic invalidation
# ---------------------------------------------------------------------------


def _session(n_csds=2, steps=2, optimizer=None, spec=None):
    cfg = smoke_config("deepseek-7b")
    spec = spec or FleetSpec.demo(n_csds)
    return Session(
        model=get_model(cfg),
        optimizer=optimizer or adamw(),
        fleet=spec,
        data=DataConfig(vocab=cfg.vocab, seq_len=16),
        shards=spec.shards(private_per_worker={"csd": 64}, public=4096),
        config=SessionConfig(total_steps=steps),
    )


def test_plan_structure_matches_state():
    s = _session()
    plan = s.shard()
    assert isinstance(plan, ShardingPlan)
    params_abs, _, opt_abs = abstract_train_state(s.model, s.optimizer)
    assert (jax.tree_util.tree_structure(plan.params)
            == jax.tree_util.tree_structure(params_abs))
    assert (jax.tree_util.tree_structure(plan.opt)
            == jax.tree_util.tree_structure(opt_abs))
    assert set(plan.batch) == set(BATCH_AXES) == set(
        abstract_batch(4, 8)
    )
    # every leaf is a NamedSharding on the plan's mesh
    for leaf in jax.tree_util.tree_leaves(plan.params):
        assert isinstance(leaf, NamedSharding) and leaf.mesh == plan.mesh
    # batch rows shard over "data"; the step counter is replicated
    assert "data" in _flat_axes(plan.batch["tokens"].spec)
    assert plan.opt.step.spec == P()


def test_plan_sgd_opt_state_has_no_nu():
    s = _session(optimizer=sgd_momentum())
    plan = s.shard()
    assert plan.opt.nu is None
    _, opt_state = s.init_state(plan)
    assert opt_state.nu is None


def test_plan_cached_and_kept_across_drift():
    s = _session()
    s.run()
    plan = s.shard()
    assert s.shard() is plan                   # memoized
    count = s.compile_count
    s.apply(DriftDetected())
    assert s.shard() is plan                   # rows pinned: plan survives
    assert s.compile_count == count            # and so does the step
    s.tune(force=True)
    assert s.shard() is plan


def test_plan_rederived_on_elastic_resize():
    s = _session(n_csds=3)
    plan = s.shard()
    rows = plan.global_rows
    s.apply(WorkerLost(["csd/1"]))
    plan2 = s.shard()
    assert plan2 is not plan                   # mesh resized: re-derived
    assert plan2.global_rows == s.tune().schedule.global_rows != rows


def test_compile_is_sharding_explicit():
    s = _session()
    compiled = s.compile()
    plan = s.shard()
    assert compiled.in_shardings == (plan.params, plan.opt, plan.batch)
    assert compiled.out_shardings == (plan.params, plan.opt, plan.replicated)


def test_fleetspec_sharding_overrides_reach_plan():
    spec = FleetSpec.demo(2).with_sharding(vocab=None)
    s = _session(spec=spec)
    plan = s.shard()
    assert plan.rules["vocab"] is None
    # default rules shard vocab over "model"
    assert make_rules()["vocab"] == "model"
    # overrides merge, later calls win
    spec2 = spec.with_sharding(vocab="model")
    assert dict(spec2.sharding)["vocab"] == "model"


# ---------------------------------------------------------------------------
# sharded init: params are born on the mesh with the plan's shardings
# ---------------------------------------------------------------------------


def test_init_state_places_leaves_on_plan():
    s = _session()
    plan = s.shard()
    params, opt_state = s.init_state(plan)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_sh = jax.tree_util.tree_leaves(plan.params)
    assert len(flat_p) == len(flat_sh)
    for leaf, sh in zip(flat_p, flat_sh):
        assert leaf.sharding.is_equivalent_to(sh, leaf.ndim)
    assert int(opt_state.step) == 0
    # the same init is what run() trains from
    report = s.run()
    for leaf, sh in zip(jax.tree_util.tree_leaves(report.params), flat_sh):
        assert leaf.sharding.is_equivalent_to(sh, leaf.ndim)


def test_run_rehomes_caller_state_onto_plan(tmp_path):
    s = _session(steps=2)
    r1 = s.run()
    # host-side numpy state (e.g. loaded out-of-band) is adopted onto the plan
    host_params = jax.tree_util.tree_map(np.asarray, r1.params)
    r2 = s.run(host_params, opt_state=r1.opt_state, steps=1)
    assert np.isfinite(r2.final_loss)


def test_checkpoint_restore_lands_on_plan(tmp_path):
    cfg_dir = str(tmp_path)
    s = _session(steps=2)
    s.config.checkpoint_dir = cfg_dir
    s.config.checkpoint_every = 2
    s.config.async_checkpoint = False
    s.run()
    s2 = _session(steps=4)
    s2.config.checkpoint_dir = cfg_dir
    s2.config.checkpoint_every = 10
    report = s2.run()
    assert report.start_step == 2              # resumed from the checkpoint
    plan = s2.shard()
    for leaf, sh in zip(jax.tree_util.tree_leaves(report.params),
                        jax.tree_util.tree_leaves(plan.params)):
        assert leaf.sharding.is_equivalent_to(sh, leaf.ndim)


def test_use_rules_installs_and_restores():
    """compile() traces the step under the plan's rule table; the context
    must restore the previous table (and constrain flag) afterwards."""
    before = get_rules()
    override = make_rules(extra={"vocab": None})
    with use_rules(override):
        assert get_rules() is override
        assert get_rules()["vocab"] is None
    assert get_rules() is before


# ---------------------------------------------------------------------------
# with_logical_constraint: expected failures warn ONCE, typos are not silent
# ---------------------------------------------------------------------------


def test_constraint_mismatch_warns_once():
    from repro.compat import set_mesh
    from repro.distributed.sharding import reset_constraint_warnings
    from repro.launch.mesh import make_single_mesh

    # the cache is process-global: clear it so the ONE warning asserted
    # below is observed regardless of which test tripped this key earlier
    reset_constraint_warnings()
    mesh = make_single_mesh()
    x = jnp.zeros((4,))
    with set_mesh(mesh):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            # rank-mismatched constraint (2 sharded parts on a 1-D array):
            # expected ValueError -> identity + ONE RuntimeWarning
            y = with_logical_constraint(x, "batch", "heads")
            np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
            assert len([r for r in w if r.category is RuntimeWarning]) == 1
            with_logical_constraint(x, "batch", "heads")
            assert len([r for r in w if r.category is RuntimeWarning]) == 1
    # a well-formed constraint still applies silently
    with set_mesh(mesh):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            with_logical_constraint(jnp.zeros((4, 4)), "batch", None)
            assert not [r for r in w if r.category is RuntimeWarning]
