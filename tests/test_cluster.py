"""Multi-process cluster execution: process custody, per-host addressable
feeding, membership-driven elasticity, and the 2-process kill/rejoin path.

The expensive end-to-end case (`test_elastic_kill_replan_restore_rejoin`)
launches a REAL 2-process x 4-fake-device cluster, hard-kills one worker
mid-run, and drives the observed death through membership -> ``WorkerLost``
-> ``session.apply`` replanning -> checkpoint restore onto the smaller
mesh, then grows it back with a join.  The full invariant smoke
(addressable-only placement, single-process loss parity) lives in
``benchmarks/cluster_smoke.py``, which CI runs as a separate gate.
"""
import os
import time

import numpy as np
import pytest

from repro.api import (
    DirMembershipSource, ElasticController, FleetSpec, MemberInfo,
    MembershipWatcher, WorkerJoined, WorkerLost,
)
from repro.api.membership import HeartbeatWriter, write_heartbeat
from repro.core.topology import ClusterSpec, ProcessMap

SEQ_LEN = 16


# ---------------------------------------------------------------------------
# process custody (pure accounting)
# ---------------------------------------------------------------------------


def test_process_map_splits_groups_contiguously():
    pm = ProcessMap(("host/0", "csd/0", "csd/1", "csd/2"), 2)
    assert [pm.process_of_group(g) for g in range(4)] == [0, 0, 1, 1]
    assert pm.local_workers(0) == ("host/0", "csd/0")
    assert pm.local_workers(1) == ("csd/1", "csd/2")
    # rows are group-major: each process owns one contiguous slab
    assert pm.row_span(0, 5) == (0, 10)
    assert pm.row_span(1, 5) == (10, 20)
    assert pm.process_of("csd/2") == 1


def test_process_map_rejects_empty_processes():
    with pytest.raises(ValueError, match="dp-group"):
        ProcessMap(("a", "b"), 3)       # a worker process with nothing to do
    with pytest.raises(ValueError):
        ProcessMap(("a",), 0)


def test_cluster_data_axis_never_straddles_processes():
    from repro.launch.mesh import cluster_data_axis

    # must divide rows AND be a multiple of the process count
    assert cluster_data_axis(40, 8, 2) == 8
    assert cluster_data_axis(12, 8, 2) == 6
    assert cluster_data_axis(6, 8, 4) == 4     # fallback: 1 chunk/process
    assert cluster_data_axis(8, 3, 2) == 2


def test_cluster_mesh_takes_equal_share_per_process():
    """When the data axis is SMALLER than the global device count, the mesh
    must still draw data/P devices from EACH process — taking the first
    ``data`` process-major would spill process 0's chunks past its custody
    row slab (regression: global_rows=12 on 2x4 devices -> data axis 6)."""
    import collections

    from repro.launch.mesh import pick_cluster_devices

    Dev = collections.namedtuple("Dev", "process_index id")
    devs = [Dev(p, p * 131072 + i) for p in range(2) for i in range(4)]
    picked = pick_cluster_devices(devs, data=6, model=1, n_processes=2)
    assert [d.process_index for d in picked] == [0, 0, 0, 1, 1, 1]
    with pytest.raises(ValueError, match="does not split"):
        pick_cluster_devices(devs, data=5, model=1, n_processes=2)
    with pytest.raises(ValueError, match="needs 4 from each"):
        pick_cluster_devices(devs[:7], data=8, model=1, n_processes=2)


def test_with_cluster_upgrades_default_storage():
    spec = FleetSpec.demo(3).with_cluster(processes=2, local_devices=4)
    assert spec.cluster == ClusterSpec(processes=2, local_devices=4)
    assert spec.storage.backend == "meshfeed"       # synthetic auto-upgrades
    flash = FleetSpec.demo(3).with_storage("flash").with_cluster(processes=2)
    assert flash.storage.backend == "flash"         # explicit choice kept


# ---------------------------------------------------------------------------
# per-host feeding (single-process degenerate case: everything addressable)
# ---------------------------------------------------------------------------


def test_feed_receipt_accounts_every_byte():
    from repro.launch.cluster import demo_session_factory

    s = demo_session_factory(processes=1, steps=2, seq_len=SEQ_LEN)
    s.shard()
    batch = s.dataset.next_device_batch()
    receipt = s.devices.last_receipt
    assert receipt is not None
    R = s.tune().schedule.global_rows
    assert receipt.rows_local == receipt.rows_global == R
    assert receipt.local_fraction == 1.0
    # tokens i32 + labels i32 + loss_mask f32, every row put exactly once
    assert receipt.bytes_put == R * SEQ_LEN * 12
    import jax

    local = {d.id for d in jax.local_devices()}
    assert set(receipt.devices) <= local
    assert batch["tokens"].shape == (R, SEQ_LEN)


def test_feed_addressable_rejects_rows_outside_custody():
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.storage.meshfeed import MeshFeeder

    feeder = MeshFeeder()
    mesh = None
    from repro.launch.mesh import make_single_mesh

    mesh = make_single_mesh()
    sh = NamedSharding(mesh, P("data", None))
    feeder.adopt_shardings({"tokens": sh}, global_rows=8)
    # this host claims rows [4, 8) but the (1-device) mesh needs [0, 8)
    with pytest.raises(ValueError, match="outside this host's rows"):
        feeder.feed_addressable(
            {"tokens": np.zeros((4, 4), np.int32)},
            row_offset=4, global_rows=8,
        )


# ---------------------------------------------------------------------------
# membership -> events -> session.apply (scripted source: deterministic)
# ---------------------------------------------------------------------------


class ScriptedSource:
    def __init__(self, live):
        self.live = dict(live)

    def poll(self):
        return dict(self.live)


def _controller_session(n_csds=3, steps=2):
    from repro.launch.cluster import demo_session_factory

    return demo_session_factory(
        processes=1, n_csds=n_csds, steps=steps, seq_len=SEQ_LEN
    )


def test_membership_watcher_emits_lost_and_joined():
    m0 = MemberInfo("proc-0", ("host/0", "csd/0"))
    m1 = MemberInfo("proc-1", ("csd/1", "csd/2"))
    src = ScriptedSource({"proc-0": m0, "proc-1": m1})
    w = MembershipWatcher(src)
    assert w.events() == []                       # first poll = baseline
    del src.live["proc-1"]
    assert w.events() == [WorkerLost(("csd/1", "csd/2"))]
    src.live["proc-2"] = MemberInfo("proc-2", ("csd/7", "csd/8"))
    assert w.events() == [WorkerJoined("csd", 2)]


def test_elastic_controller_replans_session():
    s = _controller_session()
    n0 = s.tune().schedule.n_groups
    m0 = MemberInfo("proc-0", ("host/0", "csd/0"))
    m1 = MemberInfo("proc-1", ("csd/1", "csd/2"))
    src = ScriptedSource({"proc-0": m0, "proc-1": m1})
    controller = ElasticController(s, MembershipWatcher(src))
    assert controller.step() == []
    del src.live["proc-1"]
    results = controller.step()
    assert len(results) == 1
    assert s.tune().schedule.n_groups == n0 - 2
    src.live["proc-2"] = MemberInfo("proc-2", ("csd/9",))
    controller.step()
    assert s.tune().schedule.n_groups == n0 - 1   # grew back by one


def test_dir_membership_source_roundtrip(tmp_path):
    d = str(tmp_path)
    src = DirMembershipSource(d, stale_after=5.0)
    hb = HeartbeatWriter(d, "proc-0", ("csd/0",), interval=0.1).start()
    try:
        live = MembershipWatcher(src).wait_for(1, timeout=10)
        assert live["proc-0"].workers == ("csd/0",)
    finally:
        hb.stop(deregister=True)
    assert src.poll() == {}                       # clean leave = gone


# ---------------------------------------------------------------------------
# the 2-process elastic path, end to end
# ---------------------------------------------------------------------------


def test_elastic_kill_replan_restore_rejoin(tmp_path):
    """Kill one worker process of a live 2-process cluster: the membership
    watcher turns the death into ``WorkerLost``, ``session.apply`` replans
    onto the smaller mesh, the (2-process, single-writer) checkpoint
    restores straight onto it, and a subsequent join grows it back."""
    from repro.checkpoint.manager import latest_step
    from repro.launch.cluster import ClusterCoordinator

    ckpt = str(tmp_path / "ckpt")
    coord = ClusterCoordinator(
        ClusterSpec(processes=2, local_devices=4),
        "repro.launch.cluster:demo_session_factory",
        {"processes": 2, "steps": 60, "seq_len": SEQ_LEN,
         "checkpoint_dir": ckpt, "checkpoint_every": 2},
        run_dir=str(tmp_path / "run"),
    )
    coord.launch(resume_steps=0)
    try:
        watcher = MembershipWatcher(
            DirMembershipSource(coord.membership_dir, stale_after=1.5)
        )
        live = watcher.wait_for(2, timeout=240)
        lost_workers = set(live["proc-1"].workers)
        assert len(lost_workers) == 2             # 4 groups, 2 per process

        deadline = time.time() + 240
        while latest_step(ckpt) is None:          # a coordinated save landed
            assert time.time() < deadline, "no checkpoint appeared"
            time.sleep(0.5)

        coord.kill_worker(1)                      # SIGKILL: no goodbye
        # the survivor dies at its poisoned allreduce; wait both out so no
        # save can race the restore below
        for proc in coord.processes:
            proc.wait(timeout=120)

        # observed death -> WorkerLost for exactly the killed process
        event = None
        deadline = time.time() + 60
        while event is None and time.time() < deadline:
            for ev in watcher.events():
                if isinstance(ev, WorkerLost) and set(ev.workers) == lost_workers:
                    event = ev
            time.sleep(0.2)
        assert event is not None, "membership never reported the kill"

        # controller session (full fleet view): replan -> restore -> train
        s = _controller_session(steps=60)
        s.config.checkpoint_dir = ckpt
        assert s.tune().schedule.n_groups == 4
        result = s.apply(event)
        assert s.tune().schedule.n_groups == 2
        assert all(w not in s.tune().group_workers for w in lost_workers)
        saved = latest_step(ckpt)
        rep = s.run(steps=saved + 2)              # restores onto the RESIZED plan
        assert rep.start_step == saved and rep.steps_run == 2
        assert np.isfinite(rep.final_loss)

        # a replacement joins: the mesh grows back and the same checkpoint
        # restores onto the larger plan too
        s.apply(WorkerJoined("csd", 1))
        assert s.tune().schedule.n_groups == 3
        rep2 = s.run(steps=latest_step(ckpt) + 1)
        assert np.isfinite(rep2.final_loss)
    finally:
        coord.close()
