"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import ops
from repro.kernels import ref as R

KEY = jax.random.PRNGKey(7)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Skv, H, Hkv, D, causal, window, dtype
    (2, 128, 128, 4, 4, 64, False, None, jnp.float32),
    (2, 128, 128, 4, 2, 64, True, None, jnp.float32),
    (1, 256, 256, 8, 1, 64, True, 64, jnp.float32),
    (2, 100, 100, 4, 4, 32, True, None, jnp.float32),
    (1, 64, 64, 2, 2, 128, True, None, jnp.bfloat16),
    (1, 64, 64, 2, 1, 16, False, 16, jnp.float32),
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=[str(c[:8]) for c in FLASH_CASES])
def test_flash_attention_matches_oracle(case):
    B, Sq, Skv, H, Hkv, D, causal, window, dtype = case
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (B, Sq, H, D), dtype)
    k = _rand(ks[1], (B, Skv, Hkv, D), dtype)
    v = _rand(ks[2], (B, Skv, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block=64, interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), atol=tol, rtol=tol
    )


def test_flash_attention_grad_matches_oracle():
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (1, 64, 2, 32), jnp.float32)
    k = _rand(ks[1], (1, 64, 2, 32), jnp.float32)
    v = _rand(ks[2], (1, 64, 2, 32), jnp.float32)
    g1 = jax.grad(lambda q: ops.flash_attention(
        q, k, v, causal=True, interpret=True).sum())(q)
    g2 = jax.grad(lambda q: R.flash_attention_ref(q, k, v, causal=True).sum())(q)
    np.testing.assert_allclose(g1, g2, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 256, 4, 4, 64, None, jnp.float32),
    (3, 300, 8, 2, 64, 128, jnp.float32),
    (1, 64, 4, 1, 32, None, jnp.float32),
    (2, 128, 2, 2, 128, None, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DECODE_CASES, ids=[str(c[:6]) for c in DECODE_CASES])
def test_decode_attention_matches_oracle(case):
    B, Skv, H, Hkv, D, window, dtype = case
    ks = jax.random.split(KEY, 4)
    q = _rand(ks[0], (B, 1, H, D), dtype)
    k = _rand(ks[1], (B, Skv, Hkv, D), dtype)
    v = _rand(ks[2], (B, Skv, Hkv, D), dtype)
    valid = jax.random.randint(ks[3], (B,), 1, Skv + 1)
    out = ops.decode_attention(q, k, v, valid, window=window,
                               block_k=128, interpret=True)
    ref = R.decode_attention_ref(q, k, v, valid, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), atol=tol, rtol=tol
    )


# ---------------------------------------------------------------------------
# paged decode attention (block-table over a shared page pool)
# ---------------------------------------------------------------------------

PAGED_CASES = [
    # B, pool_pages, page_size, pages_per_row, H, Hkv, D, window, dtype
    (2, 12, 16, 4, 4, 4, 64, None, jnp.float32),
    (3, 16, 8, 5, 8, 2, 64, None, jnp.float32),
    (2, 10, 16, 3, 4, 1, 32, 24, jnp.float32),
    (2, 8, 8, 4, 2, 2, 128, None, jnp.bfloat16),
]


@pytest.mark.parametrize("case", PAGED_CASES, ids=[str(c[:8]) for c in PAGED_CASES])
def test_paged_decode_attention_matches_oracle(case):
    B, P, bs, NP, H, Hkv, D, window, dtype = case
    ks = jax.random.split(KEY, 5)
    q = _rand(ks[0], (B, 1, H, D), dtype)
    k_pages = _rand(ks[1], (P, bs, Hkv, D), dtype)
    v_pages = _rand(ks[2], (P, bs, Hkv, D), dtype)
    tbl = jax.random.randint(ks[3], (B, NP), 0, P, jnp.int32)
    valid = jax.random.randint(ks[4], (B,), 1, NP * bs + 1)
    out = ops.paged_decode_attention(q, k_pages, v_pages, tbl, valid,
                                     window=window, interpret=True)
    ref = R.paged_decode_attention_ref(q, k_pages, v_pages, tbl, valid,
                                       window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), atol=tol, rtol=tol
    )


def test_paged_decode_shared_prefix_pages_match_dense():
    """Rows sharing pool pages (a cached prefix) == dense attention on the
    per-row gathered cache — the paged path reads shared pages in place."""
    B, P, bs, NP, H, Hkv, D = 3, 8, 8, 4, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (B, 1, H, D), jnp.float32)
    k_pages = _rand(ks[1], (P, bs, Hkv, D), jnp.float32)
    v_pages = _rand(ks[2], (P, bs, Hkv, D), jnp.float32)
    # all rows share prefix pages [0, 1]; suffixes diverge
    tbl = jnp.asarray([[0, 1, 2, 3], [0, 1, 4, 5], [0, 1, 6, 7]], jnp.int32)
    valid = jnp.asarray([NP * bs, 25, 17], jnp.int32)
    out = ops.paged_decode_attention(q, k_pages, v_pages, tbl, valid,
                                     interpret=True)
    k = k_pages[tbl].reshape(B, NP * bs, Hkv, D)
    v = v_pages[tbl].reshape(B, NP * bs, Hkv, D)
    dense = R.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(out, dense, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# int8 decode attention (quantized KV cache, in-kernel dequantize)
# ---------------------------------------------------------------------------


def _quantized_kv(key, B, Skv, Hkv, D):
    k = jax.random.normal(key, (B, Skv, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 1), (B, Skv, Hkv, D))
    kq, ks = R.quantize_int8_ref(k)
    vq, vs = R.quantize_int8_ref(v)
    return kq, ks, vq, vs


INT8_DECODE_CASES = [
    # B, H, Hkv, D, Skv, window, block_k
    (2, 4, 4, 64, 128, None, 64),      # MHA
    (2, 8, 2, 64, 128, None, 64),      # GQA
    (1, 4, 1, 32, 100, None, 32),      # MQA, ragged Skv
    (2, 4, 2, 32, 96, 16, 32),         # GQA + window
    (1, 2, 2, 16, 40, 8, 512),         # window, single oversized block
]


@pytest.mark.parametrize(
    "case", INT8_DECODE_CASES, ids=[str(c) for c in INT8_DECODE_CASES]
)
def test_decode_attention_int8_matches_oracle(case):
    B, H, Hkv, D, Skv, window, block_k = case
    q = _rand(KEY, (B, 1, H, D), jnp.float32)
    kq, ks, vq, vs = _quantized_kv(jax.random.fold_in(KEY, 9), B, Skv, Hkv, D)
    valid = (jnp.arange(B, dtype=jnp.int32) * 13 % Skv) + 3
    out = ops.decode_attention_int8(
        q, kq, ks, vq, vs, valid, window=window, block_k=block_k, interpret=True
    )
    ref = R.decode_attention_int8_ref(q, kq, ks, vq, vs, valid, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_decode_attention_int8_matches_f32_decode_closely():
    """Quantization error stays small: int8 path ≈ f32 path on the same KV."""
    B, H, Hkv, D, Skv = 2, 4, 2, 64, 64
    q = _rand(KEY, (B, 1, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Skv, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, Skv, Hkv, D))
    kq, ks = R.quantize_int8_ref(k)
    vq, vs = R.quantize_int8_ref(v)
    valid = jnp.asarray([33, 64], jnp.int32)
    got = ops.decode_attention_int8(q, kq, ks, vq, vs, valid, interpret=True)
    want = R.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(got, want, atol=5e-2, rtol=5e-2)


INT8_PAGED_CASES = [
    # B, H, Hkv, D, n_pool, page, NP, window
    (3, 4, 4, 64, 16, 8, 4, None),     # MHA
    (2, 8, 2, 32, 12, 8, 5, None),     # GQA
    (2, 4, 2, 32, 10, 16, 3, 12),      # GQA + window
    (1, 2, 1, 16, 6, 8, 4, None),      # MQA
]


@pytest.mark.parametrize(
    "case", INT8_PAGED_CASES, ids=[str(c) for c in INT8_PAGED_CASES]
)
def test_paged_decode_attention_int8_matches_oracle(case):
    B, H, Hkv, D, n_pool, page, NP, window = case
    q = _rand(KEY, (B, 1, H, D), jnp.float32)
    kk = jax.random.fold_in(KEY, 11)
    k_pages = jax.random.normal(kk, (n_pool, page, Hkv, D))
    v_pages = jax.random.normal(jax.random.fold_in(kk, 1), (n_pool, page, Hkv, D))
    kq, ks = R.quantize_int8_ref(k_pages)
    vq, vs = R.quantize_int8_ref(v_pages)
    tbl = (jax.random.permutation(kk, n_pool)[: B * NP]
           .reshape(B, NP).astype(jnp.int32))
    valid = (jnp.arange(B, dtype=jnp.int32) * 7 % (NP * page)) + 2
    out = ops.paged_decode_attention_int8(
        q, kq, ks, vq, vs, tbl, valid, window=window, interpret=True
    )
    ref = R.paged_decode_attention_int8_ref(
        q, kq, ks, vq, vs, tbl, valid, window=window
    )
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# fused MoE (dispatch + expert SwiGLU in one kernel)
# ---------------------------------------------------------------------------


def _moe_inputs(key, T, d, f, E):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    router = jax.random.normal(ks[1], (d, E)) * 0.5
    wg = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[3], (E, d, f)) * 0.1
    wo = jax.random.normal(ks[4], (E, f, d)) * 0.1
    return x, router, wg, wu, wo


FUSED_MOE_CASES = [
    # T, d, f, E, k, capacity
    (64, 32, 64, 8, 2, 32),            # no drops (T*k/E = 16 < C)
    (128, 16, 32, 4, 2, 128),          # multi-block capacity (C > block_c? no: =)
    (128, 32, 64, 8, 2, 8),            # heavy overflow: E*C=64 slots, 256 copies
    (96, 8, 16, 8, 1, 8),              # top-1
    (256, 64, 128, 16, 4, 256),        # two capacity blocks per expert
]


@pytest.mark.parametrize(
    "case", FUSED_MOE_CASES, ids=[str(c) for c in FUSED_MOE_CASES]
)
def test_fused_moe_matches_oracle(case):
    T, d, f, E, k, C = case
    x, router, wg, wu, wo = _moe_inputs(jax.random.fold_in(KEY, 21), T, d, f, E)
    out, aux = ops.fused_moe_mlp(
        x, router, wg, wu, wo, k=k, capacity=C, interpret=True
    )
    ref, aux_ref = R.fused_moe_mlp_ref(x, router, wg, wu, wo, k=k, capacity=C)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(aux, aux_ref, rtol=1e-6)


def test_fused_moe_capacity_overflow_drops_match_oracle():
    """capacity_factor < 1 territory: far fewer slots than token copies —
    the kernel must drop exactly the oracle's overflow copies."""
    T, d, f, E, k, C = 128, 32, 64, 4, 2, 8     # 256 copies, 32 slots
    x, router, wg, wu, wo = _moe_inputs(jax.random.fold_in(KEY, 22), T, d, f, E)
    out, aux = ops.fused_moe_mlp(
        x, router, wg, wu, wo, k=k, capacity=C, interpret=True
    )
    ref, _ = R.fused_moe_mlp_ref(x, router, wg, wu, wo, k=k, capacity=C)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)
    # sanity: overflow actually dropped copies (differs from uncapped run)
    uncapped, _ = R.fused_moe_mlp_ref(x, router, wg, wu, wo, k=k, capacity=T * k)
    assert float(jnp.abs(out - uncapped).max()) > 1e-3


def test_fused_moe_grad_matches_oracle():
    T, d, f, E, k, C = 64, 16, 32, 8, 2, 8
    x, router, wg, wu, wo = _moe_inputs(jax.random.fold_in(KEY, 23), T, d, f, E)

    def loss(fn, args):
        out, aux = fn(*args)
        return jnp.sum(out ** 2) + aux

    gk = jax.grad(lambda a: loss(
        lambda *t: ops.fused_moe_mlp(*t, k=k, capacity=C, interpret=True), a
    ))((x, router, wg, wu, wo))
    gr = jax.grad(lambda a: loss(
        lambda *t: R.fused_moe_mlp_ref(*t, k=k, capacity=C), a
    ))((x, router, wg, wu, wo))
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_fused_moe_matches_dense_model_path():
    """The kernel reproduces models/moe.py::_moe_mlp_dense (same routing,
    same capacity layout, same drops) — the wiring-level parity claim."""
    from repro.models import moe as M
    from repro.models.config import ModelConfig

    cfg = ModelConfig(family="moe", n_experts=8, experts_per_token=2,
                      d_model=32, d_ff=64, capacity_factor=0.5)
    B, S = 4, 32
    x = jax.random.normal(jax.random.fold_in(KEY, 24), (B, S, 32), jnp.float32)
    _, router, wg, wu, wo = _moe_inputs(jax.random.fold_in(KEY, 25), 1, 32, 64, 8)
    p = {"router": router, "wi_gate": wg, "wi_up": wu, "wo": wo}
    out_f, aux_f = M._moe_mlp_fused(p, x, cfg)
    out_d, aux_d = M._moe_mlp_dense(p, x, cfg)
    np.testing.assert_allclose(out_f, out_d, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(aux_f, aux_d, rtol=1e-6)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

RGLRU_CASES = [
    (2, 64, 128, jnp.float32),
    (1, 100, 300, jnp.float32),
    (3, 256, 64, jnp.float32),
    (1, 33, 96, jnp.bfloat16),
]


@pytest.mark.parametrize("case", RGLRU_CASES, ids=[str(c[:3]) for c in RGLRU_CASES])
def test_rglru_scan_matches_oracle(case):
    B, S, W, dtype = case
    ks = jax.random.split(KEY, 2)
    a = (jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))) * 0.99).astype(dtype)
    x = _rand(ks[1], (B, S, W), dtype)
    out = ops.rglru_scan(a, x, chunk=32, interpret=True)
    ref = R.rglru_scan_ref(a, x)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), atol=tol, rtol=tol
    )


def test_rglru_extreme_decay_stable():
    """Near-zero decays (log a ~ -150) must not overflow the chunked form."""
    B, S, W = 1, 64, 32
    a = jnp.full((B, S, W), 1e-30, jnp.float32)
    x = jnp.ones((B, S, W), jnp.float32)
    out = ops.rglru_scan(a, x, chunk=16, interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, R.rglru_scan_ref(a, x), atol=1e-5)


# ---------------------------------------------------------------------------
# RWKV6 scan
# ---------------------------------------------------------------------------

RWKV_CASES = [
    (2, 64, 2, 32, 16, jnp.float32),
    (1, 100, 4, 64, 32, jnp.float32),
    (2, 32, 2, 16, 32, jnp.float32),
    (1, 48, 2, 64, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("case", RWKV_CASES, ids=[str(c[:5]) for c in RWKV_CASES])
def test_rwkv6_scan_matches_oracle(case):
    B, S, H, D, chunk, dtype = case
    ks = jax.random.split(KEY, 5)
    r = _rand(ks[0], (B, S, H, D), dtype) * 0.5
    k = _rand(ks[1], (B, S, H, D), dtype) * 0.5
    v = _rand(ks[2], (B, S, H, D), dtype) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, D)))).astype(dtype)
    u = _rand(ks[4], (H, D), jnp.float32) * 0.5
    out, s_fin = ops.rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    ref, s_ref = R.rwkv6_scan_ref(r, k, v, w, u)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), atol=tol, rtol=tol
    )
    np.testing.assert_allclose(s_fin, s_ref, atol=tol, rtol=tol)


def test_rwkv6_extreme_decay_stable():
    """w -> 0 (log w ~ -148 after the model's clip) must stay finite — the
    overflow-safe chunking claim."""
    B, S, H, D = 1, 64, 1, 16
    ks = jax.random.split(KEY, 4)
    r = _rand(ks[0], (B, S, H, D), jnp.float32)
    k = _rand(ks[1], (B, S, H, D), jnp.float32)
    v = _rand(ks[2], (B, S, H, D), jnp.float32)
    w = jnp.full((B, S, H, D), jnp.exp(-jnp.exp(5.0)), jnp.float32)  # ~e^-148
    u = jnp.zeros((H, D), jnp.float32)
    out, s = ops.rwkv6_scan(r, k, v, w, u, chunk=16, interpret=True)
    ref, s_ref = R.rwkv6_scan_ref(r, k, v, w, u)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# int8 quantize
# ---------------------------------------------------------------------------


def test_quantize_matches_oracle():
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (100, 256)) * 3
    noise = jax.random.uniform(ks[1], (100, 256))
    q, s = ops.quantize_int8(x, noise, interpret=True)
    qr, sr = R.quantize_int8_ref(x, noise)
    assert bool(jnp.all(q == qr))
    np.testing.assert_allclose(s, sr, rtol=1e-6)  # 1-ulp division-order skew


def test_quantize_error_bounded_by_scale():
    x = jax.random.normal(KEY, (64, 128)) * 5
    noise = jax.random.uniform(jax.random.fold_in(KEY, 1), (64, 128))
    q, s = ops.quantize_int8(x, noise, interpret=True)
    err = jnp.abs(ops.dequantize_int8(q, s) - x)
    assert float(jnp.max(err - s)) <= 1e-6  # |err| <= scale (stochastic floor)


def test_dequantize_round_trip_matches_oracle():
    """dequantize(quantize(x)) agrees with the reference pair end to end."""
    ks = jax.random.split(jax.random.fold_in(KEY, 42), 2)
    x = jax.random.normal(ks[0], (48, 192)) * 2.5
    noise = jax.random.uniform(ks[1], (48, 192))
    q, s = ops.quantize_int8(x, noise, interpret=True)
    got = ops.dequantize_int8(q, s)
    want = R.dequantize_int8_ref(*R.quantize_int8_ref(x, noise))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_dequantize_dtype_matches_oracle():
    x = jax.random.normal(KEY, (8, 64))
    noise = jax.random.uniform(jax.random.fold_in(KEY, 3), (8, 64))
    q, s = ops.quantize_int8(x, noise, interpret=True)
    got = ops.dequantize_int8(q, s, dtype=jnp.bfloat16)
    want = R.dequantize_int8_ref(q, s, dtype=jnp.bfloat16)
    assert got.dtype == jnp.bfloat16
    assert bool(jnp.all(got == want))


def test_quantize_stochastic_unbiased():
    """E[dequant(quant(x))] == x across noise draws."""
    x = jnp.full((1, 64), 0.3141, jnp.float32)
    outs = []
    for i in range(200):
        noise = jax.random.uniform(jax.random.fold_in(KEY, i), (1, 64))
        q, s = ops.quantize_int8(x, noise, interpret=True)
        outs.append(ops.dequantize_int8(q, s))
    mean = jnp.mean(jnp.stack(outs))
    assert abs(float(mean) - 0.3141) < 2e-3


@pytest.mark.parametrize("R_rows", [1, 5, 7, 100, 300, 511, 513])
@pytest.mark.parametrize("block_rows", [8, 256])
def test_quantize_ragged_rows_match_oracle(R_rows, block_rows):
    """Row counts not divisible by block_rows: the wrapper pads (sublane-
    aligned) and slices — every real row must still match the oracle."""
    ks = jax.random.split(jax.random.fold_in(KEY, R_rows), 2)
    x = jax.random.normal(ks[0], (R_rows, 40)) * 3
    noise = jax.random.uniform(ks[1], (R_rows, 40))
    q, s = ops.quantize_int8(x, noise, block_rows=block_rows, interpret=True)
    qr, sr = R.quantize_int8_ref(x, noise)
    assert q.shape == (R_rows, 40) and s.shape == (R_rows, 1)
    assert bool(jnp.all(q == qr))
    np.testing.assert_allclose(s, sr, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    val=st.floats(min_value=-4.0, max_value=4.0,
                  allow_nan=False, allow_infinity=False),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_quantize_stochastic_rounding_unbiased_property(val, seed):
    """Property: E[dequantize(quantize(x))] ≈ x over noise seeds, for any
    magnitude — the error-feedback-free unbiasedness claim."""
    rows = jnp.linspace(-abs(val) - 1e-3, abs(val) + 1e-3, 32).reshape(1, 32)
    key = jax.random.PRNGKey(seed)
    acc = jnp.zeros_like(rows)
    n = 64
    for i in range(n):
        noise = jax.random.uniform(jax.random.fold_in(key, i), rows.shape)
        q, s = ops.quantize_int8(rows, noise, interpret=True)
        acc = acc + ops.dequantize_int8(q, s)
    mean = acc / n
    # per-element CI: one quantization step is `s`; mean of n uniform-floor
    # draws concentrates within ~s/sqrt(n) (4 sigma margin)
    step = float(s.max())
    np.testing.assert_allclose(mean, rows, atol=4 * step / np.sqrt(n) + 1e-6)


# ---------------------------------------------------------------------------
# q8 ops (int8-fused training: in-kernel dequant + int8 residuals)
# ---------------------------------------------------------------------------


def _q8_roundtrip(x):
    """Deterministic round-half-up quantize->dequantize, as the q8 ops do."""
    q, s = R.quantize_int8_ref(x, jnp.full(x.shape, 0.5, jnp.float32))
    return R.dequantize_int8_ref(q, s)


FLASH_Q8_CASES = [
    # B, S, H, Hkv, D, causal, window
    (2, 128, 4, 2, 64, True, None),
    (1, 100, 2, 2, 32, True, 32),
    (2, 64, 4, 4, 64, False, None),
]


@pytest.mark.parametrize("case", FLASH_Q8_CASES, ids=[str(c) for c in FLASH_Q8_CASES])
def test_flash_attention_q8_matches_oracle(case):
    B, S, H, Hkv, D, causal, window = case
    ks = jax.random.split(jax.random.fold_in(KEY, 31), 3)
    q = _rand(ks[0], (B, S, H, D), jnp.float32)
    k = _rand(ks[1], (B, S, Hkv, D), jnp.float32)
    v = _rand(ks[2], (B, S, Hkv, D), jnp.float32)
    out = ops.flash_attention_q8(
        q, k, v, causal=causal, window=window, block=64, interpret=True
    )
    ref = R.flash_attention_q8_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)
    # the off-Pallas fallback IS the oracle, bit for bit
    fb = ops.flash_attention_q8(
        q, k, v, causal=causal, window=window, use_kernel=False
    )
    assert bool(jnp.all(fb == ref))


def test_flash_attention_q8_close_to_f32():
    """Documented tolerance of the int8-KV attention vs full precision."""
    ks = jax.random.split(jax.random.fold_in(KEY, 32), 3)
    q = _rand(ks[0], (2, 128, 4, 64), jnp.float32)
    k = _rand(ks[1], (2, 128, 4, 64), jnp.float32)
    v = _rand(ks[2], (2, 128, 4, 64), jnp.float32)
    out = ops.flash_attention_q8(q, k, v, causal=True, interpret=True)
    f32 = R.flash_attention_ref(q, k, v, causal=True)
    assert float(jnp.max(jnp.abs(out - f32))) < 5e-2


def test_flash_attention_q8_grad_matches_oracle():
    """Straight-through estimator: grads equal the base oracle's grads
    evaluated AT the dequantized K/V point (quantize has degenerate grads,
    so grad-of-q8-oracle is NOT the comparison)."""
    ks = jax.random.split(jax.random.fold_in(KEY, 33), 3)
    q = _rand(ks[0], (1, 64, 2, 32), jnp.float32)
    k = _rand(ks[1], (1, 64, 2, 32), jnp.float32)
    v = _rand(ks[2], (1, 64, 2, 32), jnp.float32)
    got = jax.grad(lambda t: ops.flash_attention_q8(
        *t, causal=True, interpret=True).sum())((q, k, v))
    kd, vd = _q8_roundtrip(k), _q8_roundtrip(v)
    want = jax.grad(lambda t: R.flash_attention_ref(
        *t, causal=True).sum())((q, kd, vd))
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=2e-5, rtol=2e-5)


RWKV_Q8_CASES = [
    (2, 64, 2, 32, 16),
    (1, 100, 4, 64, 32),
]


@pytest.mark.parametrize("case", RWKV_Q8_CASES, ids=[str(c) for c in RWKV_Q8_CASES])
def test_rwkv6_scan_q8_matches_oracle(case):
    B, S, H, D, chunk = case
    ks = jax.random.split(jax.random.fold_in(KEY, 34), 5)
    r = _rand(ks[0], (B, S, H, D), jnp.float32) * 0.5
    k = _rand(ks[1], (B, S, H, D), jnp.float32) * 0.5
    v = _rand(ks[2], (B, S, H, D), jnp.float32) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, D))))
    u = _rand(ks[4], (H, D), jnp.float32) * 0.5
    out, s_fin = ops.rwkv6_scan_q8(r, k, v, w, u, chunk=chunk, interpret=True)
    ref, s_ref = R.rwkv6_scan_q8_ref(r, k, v, w, u)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(s_fin, s_ref, atol=5e-5, rtol=5e-5)
    fb_out, fb_s = ops.rwkv6_scan_q8(r, k, v, w, u, use_kernel=False)
    assert bool(jnp.all(fb_out == ref)) and bool(jnp.all(fb_s == s_ref))


def test_rwkv6_scan_q8_grad_matches_oracle():
    B, S, H, D = 1, 48, 2, 16
    ks = jax.random.split(jax.random.fold_in(KEY, 35), 5)
    r = _rand(ks[0], (B, S, H, D), jnp.float32) * 0.5
    k = _rand(ks[1], (B, S, H, D), jnp.float32) * 0.5
    v = _rand(ks[2], (B, S, H, D), jnp.float32) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, D))))
    u = _rand(ks[4], (H, D), jnp.float32) * 0.5

    def loss(fn, t):
        out, s = fn(t)
        return jnp.sum(out ** 2) + jnp.sum(s ** 2)

    got = jax.grad(lambda t: loss(
        lambda a: ops.rwkv6_scan_q8(*a, w, u, chunk=16, interpret=True), t
    ))((r, k, v))
    rd, kd, vd = _q8_roundtrip(r), _q8_roundtrip(k), _q8_roundtrip(v)
    want = jax.grad(lambda t: loss(
        lambda a: R.rwkv6_scan_ref(*a, w, u), t
    ))((rd, kd, vd))
    for g, x in zip(got, want):
        np.testing.assert_allclose(g, x, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("case", [(2, 64, 128), (1, 100, 300)],
                         ids=["(2,64,128)", "(1,100,300)"])
def test_rglru_scan_q8_matches_oracle(case):
    B, S, W = case
    ks = jax.random.split(jax.random.fold_in(KEY, 36), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))) * 0.99
    x = _rand(ks[1], (B, S, W), jnp.float32)
    out = ops.rglru_scan_q8(a, x, chunk=32, interpret=True)
    ref = R.rglru_scan_q8_ref(a, x)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)
    fb = ops.rglru_scan_q8(a, x, use_kernel=False)
    assert bool(jnp.all(fb == ref))


def test_rglru_scan_q8_grad_matches_oracle():
    B, S, W = 1, 64, 96
    ks = jax.random.split(jax.random.fold_in(KEY, 37), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))) * 0.99
    x = _rand(ks[1], (B, S, W), jnp.float32)
    got = jax.grad(lambda t: ops.rglru_scan_q8(
        t[0], t[1], chunk=16, interpret=True).sum())((a, x))
    xd = _q8_roundtrip(x)
    want = jax.grad(lambda t: R.rglru_scan_ref(t[0], t[1]).sum())((a, xd))
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# fused MoE combine (one-hot-matmul scatter-add)
# ---------------------------------------------------------------------------


def _combine_case(seed, T=64, d=32, E=8, k=2, C=8):
    from repro.kernels import fused_moe as FM

    ks = jax.random.split(jax.random.fold_in(KEY, seed), 3)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    router = jax.random.normal(ks[1], (d, E)) * 0.5
    slot_tok, _gate, st, slot, keep, _aux = FM.moe_routing(x, router, k, C)
    y = jax.random.normal(ks[2], (E * C, d), jnp.float32)
    got = FM.fused_moe_combine(y, slot_tok, T, interpret=True)
    want = FM._combine_xla(y, st, slot, keep, T, E, C)
    assert bool(jnp.all(got == want)), f"combine not bit-exact (seed {seed})"


def test_fused_moe_combine_bitexact_vs_xla():
    """The one-hot-matmul combine is BIT-exact vs the XLA scatter-add:
    each token row receives <= k nonzero addends, and adding exact zeros is
    the identity in f32.  Includes heavy capacity overflow (dropped copies)."""
    _combine_case(41, C=32)          # no drops
    _combine_case(42, C=8)           # moderate overflow
    _combine_case(43, k=4, C=4)      # heavy overflow: most copies dropped
    _combine_case(44, T=100, d=48, E=4, k=1, C=16)  # ragged T vs block_t


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
    k=st.integers(min_value=1, max_value=4),
    C=st.integers(min_value=1, max_value=48),
)
def test_fused_moe_combine_bitexact_property(seed, k, C):
    """Property form of the bit-exactness claim over random routings,
    top-k widths, and capacities (incl. overflow-drop regimes)."""
    _combine_case(seed % 1000 + 100, k=k, C=C)
