"""Integration: the full Stannis pipeline (tune -> plan -> place -> train),
fault tolerance (restart, node loss), and the data-plane invariants.  (This
file kept its name through the Trainer -> Session migration so the tier-1
history lines up; the ``Trainer`` stub and the ``repro.data`` compat shim
are deleted now that every caller is on ``Session`` + ``repro.storage``.)"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FleetSpec, Session, SessionConfig, DriftDetected, WorkerLost
from repro.configs import smoke_config
from repro.core.hetero import BatchSchedule
from repro.core.privacy import Shard
from repro.models.api import get_model
from repro.optim import adamw
from repro.storage import DataConfig, SyntheticDevice, synth_sequence


def _spec(n_csds=2):
    return FleetSpec.demo(n_csds)


def _shards(n_csds=2):
    return _spec(n_csds).shards(
        private_per_worker={"csd": 64}, public=4096, prefix="priv"
    )


def _session(tmp_path=None, steps=6, n_csds=2):
    cfg = smoke_config("deepseek-7b")
    return Session(
        model=get_model(cfg),
        optimizer=adamw(),
        fleet=_spec(n_csds),
        data=DataConfig(vocab=cfg.vocab, seq_len=16),
        config=SessionConfig(
            total_steps=steps,
            checkpoint_dir=str(tmp_path) if tmp_path else None,
            checkpoint_every=2,
            async_checkpoint=False,
        ),
        shards=_shards(n_csds),
    )


def test_end_to_end_loss_decreases():
    s = _session(steps=8)
    assert s.plan().imbalance_steps() == 0
    report = s.run()
    assert report.final_loss < report.history[0]["loss"]


def test_restart_resumes_from_checkpoint(tmp_path):
    s = _session(tmp_path, steps=4)
    s.run()
    assert s.plan() is not None
    # second session resumes: runs only the remaining steps
    s2 = _session(tmp_path, steps=6)
    report = s2.run()
    assert report.steps_run == 2  # resumed at step 4 of 6


def test_worker_lost_replans():
    s = _session(steps=2, n_csds=3)
    n_groups = s.tune().schedule.n_groups
    s.apply(WorkerLost(["csd/1"]))
    assert s.tune().schedule.n_groups == n_groups - 1
    assert s.plan().imbalance_steps() == 0
    # the dead worker's private shard is gone — nobody else may read it
    assert all(sh.owner != "csd/1" for sh in s.shards if sh.private)
    report = s.run(steps=2)
    assert np.isfinite(report.final_loss)


def test_retune_keeps_shapes():
    s = _session(steps=2)
    shape_before = s.tune().schedule.global_rows
    s.apply(DriftDetected())
    assert s.tune().schedule.global_rows == shape_before  # no recompilation


# ---------------------------------------------------------------------------
# data plane (repro.storage)
# ---------------------------------------------------------------------------


def test_synth_deterministic_across_processes():
    cfg = DataConfig(vocab=1000, seq_len=32, seed=5)
    a = synth_sequence(cfg, "shard-x", 17)
    b = synth_sequence(cfg, "shard-x", 17)
    np.testing.assert_array_equal(a, b)
    c = synth_sequence(cfg, "shard-y", 17)
    assert not np.array_equal(a, c)


def test_private_store_enforces_ownership():
    cfg = DataConfig(vocab=100, seq_len=8)
    shards = [Shard("p", 10, True, "w0"), Shard("pub", 10, False)]
    s0 = SyntheticDevice("w0", cfg)
    s1 = SyntheticDevice("w1", cfg)
    s0.provision(shards)
    s1.provision(shards)
    s0.read("p", 0)             # owner: fine
    s1.read("pub", 0)           # public: fine
    with pytest.raises(PermissionError):
        s1.read("p", 0)         # private, non-owner: refused


def test_dataset_layout_and_masks():
    s = _session(steps=1)
    b = s.dataset.next_batch()
    R = s.tune().schedule.global_rows
    assert b["tokens"].shape == (R, 16)
    assert b["loss_mask"].shape == (R, 16)
    # mask matches the schedule exactly
    np.testing.assert_array_equal(
        b["loss_mask"][:, 0], s.tune().schedule.row_mask()
    )
    # invalid rows carry zero tokens (never sampled)
    dead = b["tokens"][b["loss_mask"][:, 0] == 0]
    assert (dead == 0).all()


# ---------------------------------------------------------------------------
# the removed compat surfaces stay removed
# ---------------------------------------------------------------------------


def test_trainer_and_data_shims_are_gone():
    """Two PRs of deprecation are over: the ``Trainer`` stub and the
    ``repro.data`` pipeline shim no longer exist — stale imports fail at
    import time, not at behavior drift."""
    with pytest.raises(ImportError):
        import repro.train.trainer  # noqa: F401
    with pytest.raises(ImportError):
        import repro.data.pipeline  # noqa: F401
    import repro.train

    assert not hasattr(repro.train, "Trainer")
