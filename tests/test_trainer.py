"""Integration: the full Stannis pipeline (tune -> plan -> place -> train),
fault tolerance (restart, node loss), and the data pipeline invariants."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FleetSpec
from repro.configs import smoke_config
from repro.core.hetero import BatchSchedule
from repro.core.privacy import Shard
from repro.data.pipeline import DataConfig, PrivateShardStore, synth_sequence
from repro.models.api import get_model
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def _fleet(n_csds=2):
    return FleetSpec.demo(n_csds).build()


def _shards(n_csds=2):
    return FleetSpec.demo(n_csds).shards(
        private_per_worker={"csd": 64}, public=4096, prefix="priv"
    )


def _trainer(tmp_path=None, steps=6, n_csds=2):
    cfg = smoke_config("deepseek-7b")
    return Trainer(
        model=get_model(cfg),
        optimizer=adamw(),
        fleet=_fleet(n_csds),
        data_cfg=DataConfig(vocab=cfg.vocab, seq_len=16),
        cfg=TrainerConfig(
            total_steps=steps,
            checkpoint_dir=str(tmp_path) if tmp_path else None,
            checkpoint_every=2,
            async_checkpoint=False,
        ),
        shards=_shards(n_csds),
    ).setup()


def test_end_to_end_loss_decreases():
    tr = _trainer(steps=8)
    assert tr.plan.imbalance_steps() == 0
    _, hist = tr.train()
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_restart_resumes_from_checkpoint(tmp_path):
    tr = _trainer(tmp_path, steps=4)
    tr.train()
    assert tr.plan is not None
    # second trainer resumes: runs only the remaining steps
    tr2 = _trainer(tmp_path, steps=6)
    _, hist = tr2.train()
    assert len(hist) == 2  # resumed at step 4 of 6


def test_drop_workers_replans():
    tr = _trainer(steps=2, n_csds=3)
    n_groups = tr.schedule.n_groups
    tr.drop_workers(["csd/1"])
    assert tr.schedule.n_groups == n_groups - 1
    assert tr.plan.imbalance_steps() == 0
    # the dead worker's private shard is gone — nobody else may read it
    assert all(s.owner != "csd/1" for s in tr.shards if s.private)
    _, hist = tr.train(steps=2)
    assert np.isfinite(hist[-1]["loss"])


def test_retune_keeps_shapes():
    tr = _trainer(steps=2)
    shape_before = tr.schedule.global_rows
    tr.retune()
    assert tr.schedule.global_rows == shape_before  # no recompilation


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_synth_deterministic_across_processes():
    cfg = DataConfig(vocab=1000, seq_len=32, seed=5)
    a = synth_sequence(cfg, "shard-x", 17)
    b = synth_sequence(cfg, "shard-x", 17)
    np.testing.assert_array_equal(a, b)
    c = synth_sequence(cfg, "shard-y", 17)
    assert not np.array_equal(a, c)


def test_private_store_enforces_ownership():
    cfg = DataConfig(vocab=100, seq_len=8)
    shards = [Shard("p", 10, True, "w0"), Shard("pub", 10, False)]
    s0 = PrivateShardStore("w0", shards, cfg)
    s1 = PrivateShardStore("w1", shards, cfg)
    s0.sample("p", 0)           # owner: fine
    s1.sample("pub", 0)         # public: fine
    with pytest.raises(PermissionError):
        s1.sample("p", 0)       # private, non-owner: refused


def test_dataset_layout_and_masks():
    tr = _trainer(steps=1)
    b = tr.dataset.next_batch()
    R = tr.schedule.global_rows
    assert b["tokens"].shape == (R, 16)
    assert b["loss_mask"].shape == (R, 16)
    # mask matches the schedule exactly
    np.testing.assert_array_equal(
        b["loss_mask"][:, 0], tr.schedule.row_mask()
    )
    # invalid rows carry zero tokens (never sampled)
    dead = b["tokens"][b["loss_mask"][:, 0] == 0]
    assert (dead == 0).all()
