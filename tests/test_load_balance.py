"""Eq. 1 load balancing + privacy placement tests."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import load_balance as lb
from repro.core import privacy


def test_eq1_literal():
    # paper: dataset_host = dataset_card / batch_card * batch_host
    assert lb.eq1_dataset_size(3000, 25, 315) == 37800


def test_plan_aligns_steps():
    plan = lb.plan_epoch(
        {"host": 315, "csd0": 25, "csd1": 25},
        {"host": 0, "csd0": 500, "csd1": 2000},
        72000,
    )
    assert plan.imbalance_steps() == 0
    assert plan.steps_per_epoch > 0


def test_backfill_remedy():
    """Worker with little private data gets public backfill (paper remedy 1)."""
    plan = lb.plan_epoch({"a": 10, "b": 10}, {"a": 1000, "b": 10}, 2000)
    sa, sb = plan.share_for("a"), plan.share_for("b")
    assert sb.n_public > sa.n_public or sa.n_private > sb.n_private
    assert sa.steps == sb.steps


def test_duplication_remedy():
    """When public data runs dry, private data is replayed (paper remedy 2)."""
    plan = lb.plan_epoch({"a": 10, "b": 10}, {"a": 1000, "b": 100}, 0)
    sb = plan.share_for("b")
    assert sb.n_duplicated > 0
    assert plan.imbalance_steps() == 0


@settings(max_examples=50, deadline=None)
@given(
    batches=st.lists(st.integers(1, 64), min_size=1, max_size=8),
    privates=st.lists(st.integers(0, 500), min_size=8, max_size=8),
    n_public=st.integers(0, 10_000),
)
def test_plan_properties(batches, privates, n_public):
    names = [f"w{i}" for i in range(len(batches))]
    plan = lb.plan_epoch(
        dict(zip(names, batches)),
        dict(zip(names, privates[: len(batches)])),
        n_public,
    )
    # P1: all workers finish together
    assert plan.imbalance_steps() == 0
    # P2: no worker uses more private than it owns
    for s in plan.shares:
        owned = dict(zip(names, privates))[s.worker]
        assert s.n_private <= owned
    # P3: public assignments never exceed the pool
    assert sum(s.n_public for s in plan.shares) <= n_public
    # P4: shares match steps*batch within one batch
    for s in plan.shares:
        assert s.total >= plan.steps_per_epoch * s.batch


def test_privacy_placement_never_moves_private():
    shards = [
        privacy.Shard("p0", 100, True, "w0"),
        privacy.Shard("p1", 100, True, "w1"),
        privacy.Shard("pub", 1000, False),
    ]
    m = privacy.place(shards, {"w0": 500, "w1": 200})
    rep = privacy.leakage_report(m, {s.shard_id: s for s in shards})
    assert rep["private_samples_moved"] == 0


def test_privacy_validate_raises_on_leak():
    shards = {"p0": privacy.Shard("p0", 10, True, "w0")}
    bad = privacy.PlacementManifest(
        assignments=(privacy.Assignment("w1", "p0", 5, True),)
    )
    with pytest.raises(PermissionError):
        bad.validate(shards)


def test_private_shard_requires_owner():
    with pytest.raises(ValueError):
        privacy.Shard("p0", 10, True, None)
