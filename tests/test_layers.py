"""Layer-level property tests: attention paths agree, RoPE invariants hold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import layers as L

KEY = jax.random.PRNGKey(3)


@settings(max_examples=15, deadline=None)
@given(
    sq=st.integers(4, 48),
    skv=st.integers(4, 48),
    chunk=st.integers(3, 17),
    causal=st.booleans(),
    seed=st.integers(0, 1000),
)
def test_chunked_sdpa_matches_exact(sq, skv, chunk, causal, seed):
    """The flash-style chunked XLA path == exact sdpa for ANY chunking."""
    if causal and skv < sq:
        skv = sq
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, sq, 2, 16))
    k = jax.random.normal(ks[1], (1, skv, 2, 16))
    v = jax.random.normal(ks[2], (1, skv, 2, 16))
    exact = L.sdpa(q, k, v, causal=causal)
    chunked = L.chunked_sdpa(q, k, v, causal=causal, chunk=chunk)
    np.testing.assert_allclose(exact, chunked, atol=2e-5, rtol=2e-5)


def test_rope_is_relative():
    """Attention logits depend only on position differences."""
    ks = jax.random.split(KEY, 2)
    q = jax.random.normal(ks[0], (1, 8, 1, 32))
    k = jax.random.normal(ks[1], (1, 8, 1, 32))

    def logits(offset):
        pos = jnp.arange(8) + offset
        qr = L.apply_rope(q, pos)
        kr = L.apply_rope(k, pos)
        return jnp.einsum("bqhd,bkhd->bqk", qr, kr)

    np.testing.assert_allclose(logits(0), logits(1000), atol=1e-3, rtol=1e-3)


def test_mrope_equals_rope_when_streams_equal():
    """Text tokens (all three M-RoPE streams equal) == standard RoPE."""
    x = jax.random.normal(KEY, (1, 8, 2, 24))
    pos = jnp.arange(8)[None]                  # (B, S)
    pos3 = jnp.broadcast_to(pos[None], (3, 1, 8))
    a = L.apply_mrope(x, pos3, sections=(4, 4, 4), theta=10000.0)
    b = L.apply_rope(x, pos[0], theta=10000.0)
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_gqa_repeat_matches_explicit():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 8, 4, 16))
    k = jax.random.normal(ks[1], (1, 8, 2, 16))
    v = jax.random.normal(ks[2], (1, 8, 2, 16))
    gqa = L.sdpa(q, k, v, causal=True)
    mha = L.sdpa(q, jnp.repeat(k, 2, axis=2), jnp.repeat(v, 2, axis=2),
                 causal=True)
    np.testing.assert_allclose(gqa, mha, atol=1e-6)


def test_local_window_masks_far_keys():
    ks = jax.random.split(KEY, 3)
    S, W = 16, 4
    q = jax.random.normal(ks[0], (1, S, 1, 8))
    k = jax.random.normal(ks[1], (1, S, 1, 8))
    v = jax.random.normal(ks[2], (1, S, 1, 8))
    # zero out keys outside every window: result must be identical
    out1 = L.sdpa(q, k, v, causal=True, window=W)
    k2 = k.at[:, : S - W].set(jax.random.normal(ks[0], (1, S - W, 1, 8)))
    v2 = v.at[:, : S - W].set(jax.random.normal(ks[1], (1, S - W, 1, 8)))
    out2 = L.sdpa(q, k2, v2, causal=True, window=W)
    # positions >= W see only in-window keys, which are unchanged
    np.testing.assert_allclose(out1[:, S - 1], out2[:, S - 1], atol=1e-6)


def test_masked_softmax_rows_fully_masked_are_zero():
    """window+causal can fully mask early rows; output must be 0, not NaN."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 1, 8))
    k = jax.random.normal(ks[1], (1, 4, 1, 8))
    v = jax.random.normal(ks[2], (1, 4, 1, 8))
    out = L.chunked_sdpa(q, k, v, causal=True, window=1, q_offset=0, chunk=2)
    assert bool(jnp.all(jnp.isfinite(out)))
