"""Checkpoint: atomic save/restore, CRC, rotation, async, elastic re-shard."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, latest_step, restore, save


@pytest.fixture
def tree():
    k = jax.random.PRNGKey(0)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros(16)},
        "opt": {"mu": jnp.ones((8, 16)), "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path, tree):
    save(str(tmp_path), 10, tree, metadata={"step": 10})
    got, meta = restore(str(tmp_path), tree)
    assert meta["step"] == 10
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(a, b)


def test_latest_valid_wins(tmp_path, tree):
    save(str(tmp_path), 1, tree)
    save(str(tmp_path), 2, tree)
    assert latest_step(str(tmp_path)) == 2


def test_corrupt_checkpoint_skipped(tmp_path, tree):
    save(str(tmp_path), 1, tree)
    p2 = save(str(tmp_path), 2, tree)
    os.remove(os.path.join(p2, "manifest.json"))   # simulate crash mid-write
    assert latest_step(str(tmp_path)) == 1         # falls back to newest valid


def test_crc_detects_corruption(tmp_path, tree):
    p = save(str(tmp_path), 1, tree)
    # flip bytes in one leaf file
    fn = [f for f in os.listdir(p) if f.endswith(".npy")][0]
    path = os.path.join(p, fn)
    arr = np.load(path)
    arr = arr.copy()
    arr.reshape(-1)[0] += 1.0 if arr.dtype.kind == "f" else 1
    np.save(path, arr)
    with pytest.raises(IOError):
        restore(str(tmp_path), tree, verify_crc=True)


def test_rotation(tmp_path, tree):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, tree)
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps == [3, 4]


def test_async_save(tmp_path, tree):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(5, tree, async_=True)
    m.wait()
    got, _ = m.restore(tree)
    np.testing.assert_array_equal(got["params"]["w"], tree["params"]["w"])


def test_tmp_dir_never_visible(tmp_path, tree):
    save(str(tmp_path), 1, tree)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_shape_mismatch_raises(tmp_path, tree):
    save(str(tmp_path), 1, tree)
    wrong = jax.tree_util.tree_map(lambda x: x, tree)
    wrong["params"]["w"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError):
        restore(str(tmp_path), wrong)


def test_elastic_reshard_subprocess(tmp_path, tree):
    """Save on 1 device, restore re-sharded onto an 8-device mesh (dp=8) and
    onto dp=4 — the elastic-restart path."""
    import subprocess
    import sys
    import textwrap

    save(str(tmp_path), 3, tree)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh
        from repro.checkpoint.manager import restore, save
        like = {{
            "params": {{"w": jnp.zeros((8, 16)), "b": jnp.zeros(16)}},
            "opt": {{"mu": jnp.zeros((8, 16)), "step": jnp.int32(0)}},
        }}
        for dp in (8, 4, 2):
            mesh = make_mesh((dp,), ("data",))
            sh = {{
                "params": {{"w": NamedSharding(mesh, P("data", None)),
                           "b": NamedSharding(mesh, P())}},
                "opt": {{"mu": NamedSharding(mesh, P("data", None)),
                        "step": NamedSharding(mesh, P())}},
            }}
            got, _ = restore({str(tmp_path)!r}, like, shardings=sh)
            assert got["params"]["w"].sharding.num_devices == dp
            assert int(got["opt"]["step"]) == 7
        # sharded SAVE: per-shard host assembly must reproduce the logical
        # array bit-exactly (save the dp=2-sharded tree, restore, compare)
        ref, _ = restore({str(tmp_path)!r}, like)
        save({str(tmp_path)!r}, 9, got)
        back, _ = restore({str(tmp_path)!r}, like, step=9)
        for a, b in zip(jax.tree_util.tree_leaves(ref),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("ELASTIC OK")
    """
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ELASTIC OK" in out.stdout
