"""Optional-hypothesis shim (the importorskip fix, minus the collateral).

A bare ``pytest.importorskip("hypothesis")`` at module scope would skip the
WHOLE module — including plain unit tests.  Importing ``given/settings/st``
from here instead keeps unit tests running everywhere and turns each
``@given`` property test into a clean skip when hypothesis is absent.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:

    class _StubStrategies:
        """Accepts any strategy construction; never executed."""

        def __getattr__(self, name):
            def _stub(*args, **kwargs):
                return None
            return _stub

    st = _StubStrategies()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # replace with a zero-arg stub so pytest neither errors on the
            # strategy-named parameters nor runs the body
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pass  # pragma: no cover

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco
