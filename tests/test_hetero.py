"""C4 correctness: masked uniform batches == true unequal batches.

THE theorem that makes the SPMD adaptation faithful to the paper: gradients
through the masked global-mean loss with padded groups equal gradients of the
union batch, exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hetero


def _toy_grad_fn(params, x, row_mask):
    """Mean-squared loss with global mask normalization (same shape as the
    trainer's masked_mean_loss)."""

    def loss(p):
        pred = x @ p["w"] + p["b"]
        per_row = jnp.sum((pred - 1.0) ** 2, axis=-1)
        return jnp.sum(per_row * row_mask) / jnp.maximum(jnp.sum(row_mask), 1.0)

    return jax.grad(loss)(params)


@settings(max_examples=25, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 7), min_size=2, max_size=5),
    seed=st.integers(0, 2 ** 16),
)
def test_weighted_grad_equals_union_batch(sizes, seed):
    key = jax.random.PRNGKey(seed)
    d = 4
    params = {
        "w": jax.random.normal(key, (d, d)),
        "b": jnp.zeros((d,)),
    }
    xs = [
        jax.random.normal(jax.random.fold_in(key, i), (b, d))
        for i, b in enumerate(sizes)
    ]
    g_masked, g_union = hetero.weighted_grad_union_equivalence(
        _toy_grad_fn, params, xs
    )
    for a, b in zip(jax.tree_util.tree_leaves(g_masked),
                    jax.tree_util.tree_leaves(g_union)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_schedule_layout():
    s = hetero.BatchSchedule((315, 25, 25))
    assert s.max_local == 315
    assert s.global_rows == 945
    assert s.valid_rows == 365
    m = s.row_mask()
    assert m.shape == (945,)
    assert m.sum() == 365
    # group-major: first 315 valid, then 25 of 315, then 25 of 315
    assert m[:315].all() and m[315:340].all() and not m[340:630].any()


def test_schedule_retune_keeps_shape():
    s = hetero.BatchSchedule((16, 4, 4))
    s2 = s.with_batches((12, 8, 8))
    assert s2.max_local == s.max_local        # no recompile
    assert s2.global_rows == s.global_rows
    s3 = s.with_batches((32, 4, 4))           # growth beyond capacity
    assert s3.max_local == 32


def test_round_to():
    s = hetero.BatchSchedule((10, 3), round_to=8)
    assert s.max_local == 16


def test_masked_mean_loss_ignores_invalid_rows():
    loss = jnp.asarray([[1.0, 2.0], [100.0, 100.0]])
    mask = jnp.asarray([[1.0, 1.0], [0.0, 0.0]])
    got = hetero.masked_mean_loss(loss, mask)
    assert float(got) == pytest.approx(1.5)


def test_schedule_from_tune_expands_classes():
    sched, labels = hetero.schedule_from_tune(
        {"host": 100, "csd": 10}, {"host": 1, "csd": 3}
    )
    assert sched.group_batches == (10, 10, 10, 100)
    assert labels == ["csd/0", "csd/1", "csd/2", "host/0"]
