"""Session API contract: staged frozen/cached artifacts, the unified
elastic-event path (WorkerLost == old drop_workers semantics; DriftDetected
keeps compiled shapes — compile-count probe), the callback registry, and the
fleet-aware placement manifest."""
import dataclasses

import numpy as np
import pytest

from repro.api import (
    CallbackRegistry, DriftDetected, FleetSpec, Session, SessionConfig,
    TunePlan, WorkerJoined, WorkerLost,
)
from repro.configs import smoke_config
from repro.models.api import get_model
from repro.optim import adamw
from repro.storage import DataConfig


def _session(n_csds=2, steps=4, callbacks=None, seq_len=16):
    cfg = smoke_config("deepseek-7b")
    spec = FleetSpec.demo(n_csds)
    return Session(
        model=get_model(cfg),
        optimizer=adamw(),
        fleet=spec,
        data=DataConfig(vocab=cfg.vocab, seq_len=seq_len),
        shards=spec.shards(private_per_worker={"csd": 64}, public=4096),
        config=SessionConfig(total_steps=steps),
        callbacks=callbacks,
    )


# ---------------------------------------------------------------------------
# stage artifacts: cached, frozen, overridable
# ---------------------------------------------------------------------------


def test_stages_cached_and_frozen():
    s = _session()
    tp = s.tune()
    assert s.tune() is tp                      # memoized: same object
    assert s.plan() is s.plan()
    assert s.place() is s.place()
    with pytest.raises(dataclasses.FrozenInstanceError):
        tp.schedule = None                     # artifacts are immutable
    with pytest.raises(dataclasses.FrozenInstanceError):
        s.plan().steps_per_epoch = 0


def test_stages_lazy_until_accessed():
    s = _session()
    assert not s.cached("tune")
    s.plan()                                   # pulls tune() implicitly
    assert s.cached("tune") and s.cached("plan") and not s.cached("place")


def test_override_invalidates_downstream():
    s = _session()
    s.place()
    old_tp = s.tune()
    forced = TunePlan(
        result=old_tp.result,
        schedule=old_tp.schedule.with_batches(
            [max(1, b - 1) for b in old_tp.schedule.group_batches]
        ),
        group_workers=old_tp.group_workers,
    )
    s.override("tune", forced)
    assert s.tune() is forced
    assert not s.cached("plan") and not s.cached("place")
    # downstream stages rebuild against the override
    assert s.plan().imbalance_steps() == 0


def test_override_unknown_stage_rejected():
    with pytest.raises(KeyError):
        _session().override("nope", object())


# ---------------------------------------------------------------------------
# the unified elastic-event path
# ---------------------------------------------------------------------------


def test_worker_lost_matches_drop_workers_semantics():
    s = _session(n_csds=3)
    tp = s.tune()
    n_groups, max_local = tp.schedule.n_groups, tp.schedule.max_local
    res = s.apply(WorkerLost(["csd/1"]))
    tp2 = s.tune()
    assert tp2.schedule.n_groups == n_groups - 1
    assert "csd/1" not in tp2.group_workers
    assert s.plan().imbalance_steps() == 0     # Eq. 1 re-balanced
    # dead worker's private shard is gone — nobody else may read it
    assert res.dropped_shards == ("private-csd/1",)
    assert all(sh.owner != "csd/1" for sh in s.shards if sh.private)
    # the capacity fix: max_local survives the node loss (no avoidable
    # shape change beyond the group-count shrink)
    assert tp2.schedule.max_local == max_local


def test_worker_lost_unknown_worker_raises():
    s = _session()
    with pytest.raises(KeyError):
        s.apply(WorkerLost(["csd/99"]))


def test_worker_joined_grows_fleet_through_same_path():
    s = _session(n_csds=2)
    before = s.tune().schedule
    s.apply(WorkerJoined("csd", 2))
    after = s.tune()
    assert after.schedule.n_groups == before.n_groups + 2
    assert s.fleet.by_name("csd").count == 4
    assert s.plan().imbalance_steps() == 0
    # capacity never shrinks across events
    assert after.schedule.max_local >= before.max_local


def test_drift_retune_keeps_compiled_shapes():
    s = _session(steps=2)
    s.run()                                    # builds + uses the step
    compiled = s.compile()
    count = s.compile_count
    res = s.apply(DriftDetected())
    assert not res.recompiled                  # shapes pinned by capacity
    assert s.compile() is compiled             # same jitted step object
    assert s.compile_count == count            # the probe: zero rebuilds
    assert s.tune().schedule.global_rows == compiled.global_rows
    # and the pipeline still trains through the surviving step
    report = s.run(steps=1)
    assert np.isfinite(report.final_loss)


def test_drift_after_worker_lost_uses_shrunk_fleet():
    s = _session(n_csds=3)
    s.apply(WorkerLost(["csd/1"]))
    assert s.fleet.by_name("csd").count == 2   # fleet membership is live
    s.apply(DriftDetected())                   # must not resurrect csd/1
    assert s.tune().group_workers == ("csd/0", "csd/2", "host/0")
    assert s.plan().imbalance_steps() == 0


def test_worker_joined_after_loss_gets_fresh_label():
    s = _session(n_csds=3)
    s.apply(WorkerLost(["csd/1"]))
    s.apply(WorkerJoined("csd", 1))
    workers = s.tune().group_workers
    # survivors keep their identities; the joiner gets a never-used index,
    # so the dead worker's (gone) private shard is never re-pinned
    assert "csd/1" not in workers and "csd/3" in workers
    assert s.fleet.by_name("csd").count == 3


def test_worker_joined_never_recycles_highest_dead_index():
    s = _session(n_csds=3)
    s.apply(WorkerLost(["csd/2"]))       # the HIGHEST index dies
    s.apply(WorkerJoined("csd", 1))
    workers = s.tune().group_workers
    # the joiner must not be relabeled as the dead csd/2
    assert "csd/2" not in workers and "csd/3" in workers


def test_drift_preserves_dataset_cursors():
    s = _session()
    ds = s.dataset
    ds.next_batch()
    cursors = dict(ds._cursor)
    assert any(v > 0 for v in cursors.values())
    s.apply(DriftDetected())
    assert s.dataset is ds                     # same object, cursors intact
    assert ds._cursor == cursors
    assert ds.schedule is s.tune().schedule


def test_force_retune_after_loss_keeps_membership():
    s = _session(n_csds=3)
    s.apply(WorkerLost(["csd/1"]))
    s.tune(force=True)                         # explicit full re-tune
    assert s.tune().group_workers == ("csd/0", "csd/2", "host/0")
    # the surviving worker's private shard stays planned and placed
    placed = {a.shard_id for a in s.place().assignments}
    assert "private-csd/2" in placed


def test_join_after_override_gets_unique_labels():
    donor = _session(n_csds=2)
    tp = donor.tune()
    s = _session(n_csds=2)
    s.override("tune", tp)                     # external re-tuner hook
    s.apply(WorkerJoined("csd", 1))
    workers = s.tune().group_workers
    assert len(set(workers)) == len(workers)   # no duplicate labels
    assert "csd/2" in workers


def test_full_class_death_then_rejoin():
    s = _session(n_csds=1)
    s.tune()
    s.apply(WorkerLost(["csd/0"]))             # the whole csd class dies
    assert all(c.name != "csd" for c in s.fleet.classes)
    s.apply(WorkerJoined("csd", 1))            # replacement node arrives
    assert s.fleet.by_name("csd").count == 1
    workers = s.tune().group_workers
    assert "csd/1" in workers and "csd/0" not in workers
    assert s.plan().imbalance_steps() == 0


def test_force_retune_preserves_capacity_and_compiled_step():
    s = _session(steps=2)
    s.run()
    compiled = s.compile()
    count = s.compile_count
    max_local = s.tune().schedule.max_local
    s.tune(force=True)
    assert s.tune().schedule.max_local == max_local
    assert s.compile() is compiled             # shapes held: step survives
    assert s.compile_count == count


def test_config_edit_between_runs_takes_effect():
    s = _session(steps=2)
    r1 = s.run()
    s.config.base_lr = 123.0
    r2 = s.run()
    assert s.compile_count == 2                # config change rebuilds
    assert r2.history[0]["lr"] > r1.history[0]["lr"] * 100


def test_run_continuation_keeps_optimizer_and_lr_progress():
    s = _session(steps=3)
    r1 = s.run()
    r2 = s.run(r1.params, opt_state=r1.opt_state, steps=2)
    # the lr-schedule step counter lives in opt_state: warmup continues
    # monotonically across the two runs instead of replaying from step 0
    # (smoke batches < base_batch, so the Goyal ramp is strictly decreasing)
    lrs = [h["lr"] for h in r1.history] + [h["lr"] for h in r2.history]
    assert all(a > b for a, b in zip(lrs, lrs[1:])), lrs
    assert r2.history[0]["lr"] != r1.history[0]["lr"]


def test_worker_joined_rejects_nonpositive_count():
    with pytest.raises(ValueError):
        WorkerJoined("csd", 0)
    with pytest.raises(ValueError):
        WorkerJoined("csd", -1)


def test_plan_override_keeps_compiled_step():
    s = _session(steps=2)
    s.run()
    compiled = s.compile()
    s.override("plan", s.plan())          # rebalancer hook: shapes untouched
    assert s.compile() is compiled
    assert s.compile_count == 1


def test_drift_keeps_dataset_consistent_with_placement():
    from repro.storage import manifest_sources

    s = _session(n_csds=3)
    _ = s.dataset
    s.apply(DriftDetected())
    # the live iterator must sample exactly what place() says it samples
    expected = manifest_sources(s.place(), list(s.tune().group_workers))
    assert s.dataset.group_sources == expected


def test_worker_lost_then_run_recompiles_once():
    s = _session(n_csds=3, steps=2)
    s.run()
    count = s.compile_count
    res = s.apply(WorkerLost(["csd/0"]))
    assert res.recompiled                      # group count changed: expected
    report = s.run(steps=2)
    assert np.isfinite(report.final_loss)
    assert s.compile_count == count + 1


# ---------------------------------------------------------------------------
# callbacks
# ---------------------------------------------------------------------------


def test_callback_registry_fires_typed_hooks():
    cb = CallbackRegistry()
    seen = {"steps": [], "retunes": [], "fleet": []}
    cb.on_step(lambda i, m: seen["steps"].append(i))
    cb.on_retune(lambda e, tp: seen["retunes"].append(e))
    cb.on_fleet_change(lambda e, r: seen["fleet"].append(e))

    s = _session(n_csds=3, steps=2, callbacks=cb)
    s.run()
    assert seen["steps"] == [0, 1]
    s.apply(DriftDetected())
    assert len(seen["retunes"]) == 1 and not seen["fleet"]
    s.apply(WorkerLost(["csd/2"]))
    assert len(seen["fleet"]) == 1 and isinstance(seen["fleet"][0], WorkerLost)


# ---------------------------------------------------------------------------
# FleetSpec
# ---------------------------------------------------------------------------


def test_fleetspec_demo_and_shards():
    spec = FleetSpec.demo(3)
    fleet = spec.build()
    assert fleet.by_name("host").count == 1
    assert fleet.by_name("csd").count == 3
    shards = spec.shards(private_per_worker={"csd": 10}, public=100)
    priv = [sh for sh in shards if sh.private]
    assert [sh.owner for sh in priv] == ["csd/0", "csd/1", "csd/2"]
    assert sum(not sh.private for sh in shards) == 1


def test_fleetspec_paper_matches_topology_preset():
    from repro.core.topology import paper_fleet

    assert FleetSpec.paper(24, "nasnet").build() == paper_fleet(24, "nasnet")


def test_fleetspec_immutable_builder():
    base = FleetSpec.custom("x").add("a", 1, 1.0, 1, 4, active_power=1.0)
    grown = base.add("b", 2, 2.0, 1, 4, active_power=1.0)
    assert len(base.classes) == 1 and len(grown.classes) == 2
    with pytest.raises(ValueError):
        FleetSpec.custom("empty").build()


# ---------------------------------------------------------------------------
# fleet-aware placement manifest
# ---------------------------------------------------------------------------


def test_place_returns_fleet_manifest():
    from repro.core.privacy import PlacementManifest
    from repro.storage import FleetManifest

    s = _session(n_csds=2)
    m = s.place()
    assert isinstance(m, FleetManifest)
    assert isinstance(m, PlacementManifest)      # the core surface survives
    assert m.backend == "synthetic"
    workers = {d.worker for d in m.devices}
    assert workers == set(s.tune().group_workers)
    # every device's custody covers its own private shard
    for sh in s.shards:
        if sh.private:
            rec = m.device_for(sh.owner)
            assert rec is not None and sh.shard_id in rec.custody


def test_worker_lost_manifest_reflects_quarantine():
    s = _session(n_csds=3)
    s.place()
    s.apply(WorkerLost(["csd/1"]))
    m = s.place()
    assert "private-csd/1" in m.quarantined
    assert m.device_for("csd/1") is None
    # no assignment may reference the dead worker or its shard
    assert all(a.worker != "csd/1" for a in m.assignments)
    assert all(a.shard_id != "private-csd/1" for a in m.assignments)
